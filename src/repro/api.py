"""The unified Wattchmen surface: one session object, one verb set.

The paper's artifact is a trained per-instruction energy table that can
predict and attribute energy for *any* workload (§3.4–3.5).  ``EnergyModel``
packages that artifact with its device handle behind a coherent API so a
caller never hand-threads ``get_device`` → ``train_table`` → ``count_fn`` →
``dev.run`` → ``predict.predict(...)`` again:

    from repro.api import EnergyModel

    model = EnergyModel.from_store("sim-v5e-air")   # load or train-once
    cmp = model.compare(my_fn, *shape_args)         # measured vs predicted
    pred = model.attribute(my_fn, *shape_args)      # per-class breakdown

Construction:
    ``EnergyModel.train(system)``       train now (optionally persist)
    ``EnergyModel.load(path)``          from a saved table file
    ``EnergyModel.from_store(system)``  persistent ``TableStore``-backed —
                                        a trained table survives processes
                                        and ships to a serving fleet

Profiling is pluggable via ``ProfileSource``: anything with
``op_counts(isa_gen)`` — the jaxpr tracer (``profile``), the compiled-HLO
parser (``profile_hlo``), or raw counts (``profile_counts``).  Prediction
verbs (``predict``, ``predict_many``, ``attribute``, ``compare``,
``monitor``) all share one ``TablePredictor``, which resolves each op class
to its (energy, provenance) entry once and amortizes the table lookups
across every later call — the fleet-scale hot path.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import (Any, Callable, Iterable, List, Mapping, Optional,
                    Protocol, Sequence, Union, runtime_checkable)

from repro.core.opcount import OpCounts, count_jaxpr
from repro.core.predict import Prediction, TablePredictor
from repro.core.store import TableStore, default_store
from repro.core.table import EnergyTable
from repro.core.trainer import train_table
from repro.hw.device import Program, RunRecord, SimDevice
from repro.hw.systems import get_device


_UNSET = object()      # "keep the callee's default" sentinel


# ---------------------------------------------------------------------------
# Profile sources.
# ---------------------------------------------------------------------------
@runtime_checkable
class ProfileSource(Protocol):
    """Anything that can yield per-iteration op counts for a target gen."""

    def op_counts(self, isa_gen: int) -> OpCounts: ...


@dataclasses.dataclass
class JaxprSource:
    """Trace a JAX callable (with ShapeDtypeStruct/array args) to a jaxpr."""

    fn: Callable
    args: tuple = ()
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    axis_sizes: Optional[Mapping[str, int]] = None

    def trace(self):
        """The closed jaxpr — the countable (and digestible) artifact."""
        import jax
        return jax.make_jaxpr(self.fn)(*self.args, **dict(self.kwargs))

    def op_counts(self, isa_gen: int) -> OpCounts:
        return count_jaxpr(self.trace(), axis_sizes=self.axis_sizes,
                           isa_gen=isa_gen)


@dataclasses.dataclass
class HloSource:
    """Parse optimized HLO text (``compiled.as_text()``) into op counts."""

    text: str

    def op_counts(self, isa_gen: int) -> OpCounts:
        from repro.hlo.opcount import count_hlo_text
        return count_hlo_text(self.text, isa_gen=isa_gen)


@dataclasses.dataclass
class CountsSource:
    """Raw profiler counts — an ``OpCounts`` or a ``{class: units}`` map."""

    counts: Union[OpCounts, Mapping[str, float]]

    def op_counts(self, isa_gen: int) -> OpCounts:
        if isinstance(self.counts, OpCounts):
            return self.counts
        out = OpCounts()
        for cls, units in self.counts.items():
            out.add(cls, float(units))
        return out


@dataclasses.dataclass
class Profile:
    """Resolved per-iteration op counts, ready for predict/measure."""

    name: str
    counts: OpCounts

    def op_counts(self, isa_gen: int) -> OpCounts:   # ProfileSource
        return self.counts

    def scaled(self, mult: float) -> OpCounts:
        return self.counts.scaled(mult)


class ProfileCache:
    """Content-addressed ``OpCounts`` cache for a model's profile sources.

    Since prediction vectorized (~12 µs/call), *counting* dominates the
    serve path (~180 µs for a jaxpr walk, and re-tracing costs more
    still).  HLO sources key on a digest of their text; jaxpr sources key
    on the callable plus its abstract-value signature (shapes/dtypes —
    everything tracing can observe), so a hit skips the trace *and* the
    counting walk.  LRU-bounded; hit/miss counters surface via
    ``EnergyModel.stats()``.  The cache keeps a pristine copy of every
    entry and hands out copies, so callers may mutate what they receive
    without poisoning later lookups.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = int(maxsize)
        self._entries: "OrderedDict[tuple, OpCounts]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get_or_count(self, key: tuple, count: Callable[[], OpCounts]) -> OpCounts:
        cached = self._entries.get(key)
        if cached is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return cached.scaled(1.0)         # defensive copy (bitwise)
        self.misses += 1
        counts = count()
        self._entries[key] = counts.scaled(1.0)   # pristine copy retained
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return counts

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries), "maxsize": self.maxsize}


def _arg_signature(x):
    """A hashable stand-in for what tracing can observe of one argument.

    Arrays and ShapeDtypeStructs reduce to (shape, dtype, weak_type) —
    concrete values cannot influence a jaxpr beyond their aval.  Plain
    hashable Python values (static scalars, flags) key by value.  Returns
    ``None`` for anything else: the source is then uncacheable.
    """
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return ("aval", tuple(shape), str(dtype),
                bool(getattr(x, "weak_type", False)))
    try:
        hash(x)
    except TypeError:
        return None
    return ("val", x)


# ---------------------------------------------------------------------------
# Job / result containers.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PredictJob:
    """One unit of batched prediction (``EnergyModel.predict_many``)."""

    source: Union[ProfileSource, OpCounts]
    duration_s: float
    counters: Optional[Mapping[str, float]] = None
    mode: Optional[str] = None          # None -> the batch-level mode
    name: str = ""
    operating_point: Optional[object] = None  # None -> the batch-level point


@dataclasses.dataclass
class MicroscopeReport:
    """Kernel-level energy breakdown of one workload (``microscope``)."""

    summary: Any                      # telemetry.StreamSummary
    kernels: Mapping[str, dict]       # StreamSession.kernel_report()
    session: Any                      # the finished StreamSession

    @property
    def tiling_exact(self) -> bool:
        """Do the kernel windows tile every step's joules bitwise?"""
        for w in self.session.windows:
            if w.step < 0 or not w.children:
                continue
            if sum(c.measured_j for c in w.children) != w.measured_j:
                return False
        return True

    @property
    def attributed_j(self) -> float:
        return self.summary.attributed_j


@dataclasses.dataclass
class Comparison:
    """Measured-vs-predicted energy for one workload run."""

    record: RunRecord
    prediction: Prediction

    @property
    def measured_j(self) -> float:
        return self.record.energy_counter_j

    @property
    def predicted_j(self) -> float:
        return self.prediction.total_j

    @property
    def error_pct(self) -> float:
        if self.measured_j <= 0:
            return 0.0
        return 100.0 * (self.predicted_j / self.measured_j - 1.0)


# ---------------------------------------------------------------------------
# The facade.
# ---------------------------------------------------------------------------
class EnergyModel:
    """A trained Wattchmen session: table + device + prediction engine."""

    def __init__(self, table: EnergyTable, system: Optional[str] = None,
                 device: Optional[SimDevice] = None):
        self.table = table
        self.system = system or table.system
        self._device = device
        self.predictor = TablePredictor(table)
        self.predictor.warm()      # long-lived session: precompute vectors
        self.profile_cache = ProfileCache()

    # -- construction -------------------------------------------------------
    @classmethod
    def train(cls, system: str, *, store: Union[bool, TableStore] = False,
              resume: bool = False,
              profile_fraction: Optional[float] = None,
              donor: Union["EnergyModel", EnergyTable, str, None] = None,
              **train_kwargs) -> "EnergyModel":
        """Calibrate a table now through the staged pipeline.

        ``store=True`` persists the result.  ``resume=True`` runs the
        campaign against its persistent run directory (under the store), so
        an interrupted calibration continues from the completed measurement
        records.  ``profile_fraction`` + ``donor`` select the Fig. 14
        bootstrap: measure only the sampled fraction of the suite on this
        system and affine-map everything else from the donor table (an
        ``EnergyTable``, another ``EnergyModel``, or a system name resolved
        through the store).
        """
        from repro.core.calibrate import calibrate
        store_obj = (store if isinstance(store, TableStore)
                     else default_store() if store else None)
        run_dir = None
        if resume:
            run_dir = (store_obj or default_store()).run_dir(system)
            if profile_fraction is not None:
                # fractional campaigns measure a different (sampled) plan —
                # keep their records apart from the full-profile run
                run_dir = run_dir.with_name(
                    f"{run_dir.name}__frac{int(profile_fraction * 1000)}"
                    f"_s{train_kwargs.get('seed', 0)}")
        table = calibrate(system, profile_fraction=profile_fraction,
                          donor=donor, run_dir=run_dir, resume=resume,
                          on_plan_mismatch="discard", store=store_obj,
                          **train_kwargs)
        return cls(table, system=system)

    @classmethod
    def load(cls, path, system: Optional[str] = None) -> "EnergyModel":
        """From a table file previously written by ``save``."""
        return cls(EnergyTable.load(path), system=system)

    @classmethod
    def from_store(cls, system: str, store: Optional[TableStore] = None,
                   train_if_missing: bool = True) -> "EnergyModel":
        """Load the system's table from the persistent store.

        On a store miss (or stale schema) the table is trained once and
        written back, so the *next* process — or the next fleet node sharing
        the store — skips training entirely.  Training runs through the
        resumable calibration pipeline: its measurement records persist
        incrementally under the store, so even an interrupted first
        training continues instead of restarting.
        """
        store = store or default_store()
        if train_if_missing:
            table = store.get_or_train(
                system, lambda s: train_table(s, run_dir=store.run_dir(s),
                                              resume=True))
        else:
            table = store.get(system)
            if table is None:
                raise KeyError(
                    f"no stored table for {system!r} under {store.root}")
        return cls(table, system=system)

    # -- persistence --------------------------------------------------------
    def save(self, path) -> None:
        self.table.save(path)

    def to_store(self, store: Optional[TableStore] = None):
        """Persist this model's table; returns the written path."""
        return (store or default_store()).put(self.table)

    # -- device -------------------------------------------------------------
    @property
    def device(self) -> SimDevice:
        if self._device is None:
            self._device = get_device(self.system)
        return self._device

    @property
    def isa_gen(self) -> int:
        return self.table.isa_gen

    # -- profiling ----------------------------------------------------------
    def profile(self, fn: Callable, *args,
                axis_sizes: Optional[Mapping[str, int]] = None,
                name: Optional[str] = None, **kwargs) -> Profile:
        """Trace a JAX callable and count its per-iteration work."""
        src = JaxprSource(fn, args, kwargs, axis_sizes=axis_sizes)
        return Profile(name or getattr(fn, "__name__", "fn"),
                       self._cached_counts(src))

    def profile_hlo(self, text: str, name: str = "hlo") -> Profile:
        """Count work from optimized HLO text (compiled artifact path)."""
        return Profile(name, self._cached_counts(HloSource(text)))

    def profile_counts(self, counts: Union[OpCounts, Mapping[str, float]],
                       name: str = "counts") -> Profile:
        """Wrap raw profiler counts (``OpCounts`` or class->units map)."""
        return Profile(name, CountsSource(counts).op_counts(self.isa_gen))

    def _resolve(self, source: Union[ProfileSource, OpCounts]) -> OpCounts:
        if isinstance(source, OpCounts):
            return source
        if isinstance(source, (JaxprSource, HloSource)):
            return self._cached_counts(source)
        if isinstance(source, ProfileSource):
            return source.op_counts(self.isa_gen)
        if callable(source):
            raise TypeError(
                "got a bare callable; profile it first: "
                "model.predict(model.profile(fn, *args), ...)")
        raise TypeError(f"not a ProfileSource or OpCounts: {source!r}")

    def _cached_counts(self, source: Union["JaxprSource", "HloSource"],
                       ) -> OpCounts:
        """Counts for an addressable source, through the profile cache.

        HLO text keys on its digest (the text is already in hand, hashing
        is cheap).  A jaxpr source keys on the callable object plus the
        abstract-value signature of its arguments — the full input to
        tracing — so a hit skips both the re-trace and the counting walk;
        rendering the jaxpr just to digest it would cost more than the
        counting it saves.  The key holds a reference to the callable, so
        an entry can never be confused with a later object reusing its
        address.  Sources whose arguments defy a signature fall through to
        a direct (uncached) count.
        """
        gen = self.isa_gen
        if isinstance(source, HloSource):
            key = ("hlo", gen,
                   hashlib.sha256(source.text.encode()).hexdigest())
            return self.profile_cache.get_or_count(
                key, lambda: source.op_counts(gen))
        arg_sigs = tuple(_arg_signature(a) for a in source.args)
        kw_sigs = tuple((k, _arg_signature(v))
                        for k, v in sorted(source.kwargs.items()))
        try:
            hash(source.fn)
        except TypeError:
            return source.op_counts(gen)      # unhashable callable
        if any(s is None for s in arg_sigs) or \
                any(s is None for _, s in kw_sigs):
            return source.op_counts(gen)      # uncacheable arguments
        axes = (tuple(sorted(source.axis_sizes.items()))
                if source.axis_sizes else ())
        key = ("jaxpr", gen, axes, source.fn, arg_sigs, kw_sigs)
        return self.profile_cache.get_or_count(
            key, lambda: source.op_counts(gen))

    def stats(self) -> dict:
        """Session counters (JSON-safe): profile-cache hits/misses, table."""
        return {
            "system": self.system,
            "profile_cache": self.profile_cache.stats(),
            "classes": len(self.table.direct),
        }

    # -- prediction ---------------------------------------------------------
    def predict(self, source: Union[ProfileSource, OpCounts],
                duration_s: float,
                counters: Optional[Mapping[str, float]] = None,
                mode: str = "pred", operating_point=None) -> Prediction:
        """Energy prediction + attribution for one profiled run.

        ``operating_point`` prices the run at a (freq_mhz, power_cap_w)
        point of the table's calibrated frequency family — exact at
        calibrated members, interpolated between them.  ``None`` keeps the
        anchor (bitwise-legacy) path.
        """
        return self.predictor.predict(self._resolve(source), duration_s,
                                      counters=counters, mode=mode,
                                      operating_point=operating_point)

    def predict_many(self, jobs: Iterable[Union[PredictJob, tuple]],
                     mode: str = "pred",
                     operating_point=None) -> List[Prediction]:
        """Batched prediction over many workloads.

        Accepts ``PredictJob``s or ``(source, duration_s[, counters])``
        tuples.  The whole batch is assembled into one counts matrix and
        priced in a single vectorized pass over this model's class->energy
        vectors (``TablePredictor.predict_batch``) — the fleet-scale path.
        Totals are bitwise-identical to calling ``predict`` per job.

        ``operating_point`` sets a batch-level DVFS point; a job's own
        ``operating_point`` overrides it.  Mixed-point batches are split
        into one vectorized pass per distinct (mode, point) pair.
        """
        resolved = [job if isinstance(job, PredictJob) else PredictJob(*job)
                    for job in jobs]
        if not resolved:
            return []
        modes = [job.mode or mode for job in resolved]
        pts = [self.predictor._as_point(
                   job.operating_point if job.operating_point is not None
                   else operating_point)
               for job in resolved]
        uniform = all(p == pts[0] for p in pts)
        return self.predictor.predict_batch(
            [self._resolve(job.source) for job in resolved],
            [job.duration_s for job in resolved],
            [job.counters for job in resolved],
            mode=modes[0] if len(set(modes)) <= 1 else modes,
            operating_point=pts[0] if uniform else pts)

    def attribute(self, source: Union[ProfileSource, OpCounts, Callable],
                  *args, duration_s: Optional[float] = None,
                  counters: Optional[Mapping[str, float]] = None,
                  target_seconds: float = 30.0, **kwargs) -> Prediction:
        """Per-class/per-bucket energy breakdown (§5.3 case-study verb).

        With ``duration_s`` this is a pure prediction over the source; with
        a callable (or no duration) the workload is first run on the device
        so the breakdown reflects measured duration and counters.
        """
        if callable(source) and not isinstance(source, ProfileSource):
            source = self.profile(source, *args, **kwargs)
        if duration_s is not None:
            return self.predict(source, duration_s, counters=counters)
        counts = self._resolve(source)
        rec = self.measure(counts, target_seconds=target_seconds,
                           name=getattr(source, "name", "workload"))
        return self.predict(counts.scaled(rec.iters), rec.duration_s,
                            counters=counters if counters is not None
                            else rec.counters)

    # -- measurement (ground truth) ------------------------------------------
    def measure(self, source: Union[ProfileSource, OpCounts, Callable],
                *args, target_seconds: float = 30.0,
                iters: Optional[int] = None, name: Optional[str] = None,
                **kwargs) -> RunRecord:
        """Run the workload on the device; NVML-style telemetry back."""
        if callable(source) and not isinstance(source, ProfileSource):
            source = self.profile(source, *args, name=name, **kwargs)
        counts = self._resolve(source)
        dev = self.device
        if iters is None:
            iters = dev.iters_for_duration(counts, target_seconds)
        run_name = name or getattr(source, "name", "workload")
        return dev.run(Program(run_name, counts, iters=iters))

    def compare(self, source: Union[ProfileSource, OpCounts, Callable],
                *args, target_seconds: float = 30.0,
                iters: Optional[int] = None, mode: str = "pred",
                name: Optional[str] = None, **kwargs) -> Comparison:
        """Measure ground truth and predict from the same profile."""
        if callable(source) and not isinstance(source, ProfileSource):
            source = self.profile(source, *args, name=name, **kwargs)
        counts = self._resolve(source)
        rec = self.measure(counts, target_seconds=target_seconds,
                           iters=iters, name=name or
                           getattr(source, "name", "workload"))
        pred = self.predict(counts.scaled(rec.iters), rec.duration_s,
                            counters=rec.counters, mode=mode)
        return Comparison(record=rec, prediction=pred)

    # -- DVFS / frequency axis -----------------------------------------------
    def fork(self) -> "EnergyModel":
        """An independent copy of this model over a *copied* table.

        Drift repairs (``rescale_table``) mutate the bound table in place —
        correct for the long-lived fleet session, surprising for anything
        that wants to explore (re-run a workload, try operating points)
        without editing the shared published table.  The fork shares the
        device but owns a deep-copied table, so its recalibrations,
        rescales and family edits never leak back.
        """
        return EnergyModel(self.table.copy(), system=self.system,
                           device=self._device)

    def calibrate_points(self, points=None, *,
                         store: Union[bool, TableStore, None] = None,
                         resume: bool = True, **kwargs) -> "EnergyModel":
        """Calibrate DVFS operating points into this model's table family.

        Runs ``core.calibrate.calibrate_sweep`` with this table as the
        anchor: each (freq_mhz, power_cap_w) point gets its own staged,
        resumable calibration campaign and lands in the table's
        ``operating_points`` family, after which ``predict``/``sweep``/
        ``monitor`` can price any point on the grid.  ``points=None``
        sweeps three evenly spaced frequencies across the device's V/f
        range at the TDP cap.  Returns ``self``.
        """
        from repro.core.calibrate import calibrate_sweep
        store_obj = (store if isinstance(store, TableStore)
                     else default_store() if store else None)
        run_dir = None
        if store_obj is not None:
            run_dir = store_obj.run_dir(self.system).with_name(
                store_obj.run_dir(self.system).name + "__sweep")
        calibrate_sweep(self.system, points=points, base_table=self.table,
                        device=self.device, run_dir=run_dir, resume=resume,
                        store=store_obj, **kwargs)
        self.predictor.invalidate()
        return self

    def sweep(self, source: Union[ProfileSource, OpCounts], points=None,
              **kwargs):
        """Measure J/work and work/s across operating points (§sweet spot).

        Runs the workload once per candidate point through the streaming
        pipeline and returns a ``repro.dvfs.SweepResult`` — rows of
        measured J/work vs throughput, ``best()`` picking the exhaustive
        sweet spot (optionally under an SLA).  See
        ``repro.dvfs.sweep_operating_points`` for the knobs.
        """
        from repro.dvfs.sweep import sweep_operating_points
        return sweep_operating_points(self, self._resolve(source),
                                      points=points, **kwargs)

    def govern(self, source: Union[ProfileSource, OpCounts], governor,
               **kwargs):
        """Run the closed loop: governor proposes, sessions measure.

        Each round runs one streaming session at the governor's proposed
        point and feeds the measured J/work back.  Returns the
        ``repro.dvfs.GovernedRun`` trace the dashboard example renders.
        """
        from repro.dvfs.sweep import govern_workload
        return govern_workload(self, self._resolve(source), governor,
                               **kwargs)

    # -- streaming / evaluation ----------------------------------------------
    def monitor(self, live=False, step_counts=None, *,
                telemetry_chunk=_UNSET, operating_point=None, chaos=None,
                **kwargs):
        """A fleet ``EnergyMonitor`` bound to this model's predictor.

        ``step_counts`` sets the default per-step profile (one profile per
        program), so the hot loop calls ``monitor.observe(step, duration_s=dt)``
        without re-threading counts.

        ``live`` switches on measured telemetry: pass a profile source (or
        ``True`` to reuse ``step_counts``) and the monitor is wired to a
        ``telemetry.StreamSession`` (``monitor.live``) — the host loop marks
        steps via ``monitor.live.step(...)`` and ``monitor.live.finish()``
        aligns measured joules to every step, feeding them back into the
        monitor's records alongside the predictions.

        ``telemetry_chunk`` sets the live session's ingestion chunk size
        (``None`` selects the per-sample reference path; unset keeps the
        chunked default).

        ``operating_point`` pins the live session (and its attribution) at
        a calibrated/interpolated (freq_mhz, power_cap_w) point.

        ``chaos`` (a ``telemetry.ChaosPlan``) runs the live session's
        sampler behind the deterministic fault-injection layer — the
        sanitizer/gap-accounting path is exercised and the session's
        ``health()`` counters report exactly what was injected.
        """
        from repro.core.fleet import EnergyMonitor
        if step_counts is not None and not isinstance(step_counts, OpCounts):
            step_counts = self._resolve(step_counts)
        if telemetry_chunk is not _UNSET and (live is None or live is False):
            raise ValueError("telemetry_chunk= only applies to the live "
                             "stream session; pass live=True (or a source)")
        mon = EnergyMonitor(self, step_counts=step_counts, **kwargs)
        if live is not None and live is not False:
            source = step_counts if live is True else live
            if source is None:
                raise ValueError("monitor(live=True) needs step_counts=, or "
                                 "pass the profile source as live=")
            stream_kw = {} if telemetry_chunk is _UNSET \
                else {"chunk_size": telemetry_chunk}
            if operating_point is not None:
                stream_kw["operating_point"] = operating_point
            if chaos is not None:
                stream_kw["chaos"] = chaos
            mon.live = self.stream(source, monitor=mon, **stream_kw)
        return mon

    def stream(self, source: Union[ProfileSource, OpCounts], *,
               name: Optional[str] = None, monitor=None, service=None,
               store: Union[bool, "TableStore", None] = None, **kwargs):
        """A ``telemetry.StreamSession`` for this model on its device.

        The full streaming pipeline — background-style sampling, MTSM
        marker alignment, measured-vs-predicted attribution, drift
        detection and table recalibration:

            session = model.stream(model.profile(fn, *args))
            for i in range(N):
                ...                                   # real work
                session.step(i, duration_s=dt)
            summary = session.finish()                # align + attribute

        ``store=True`` lets a drift-triggered recalibration publish the
        corrected table to the default ``TableStore`` (or pass a store).
        ``service`` registers the session on a ``TelemetryService``.
        """
        from repro.telemetry.service import StreamSession
        if store is True:
            store = default_store()
        elif store is False:
            store = None
        session = StreamSession(
            self.predictor, self.device, self._resolve(source),
            name=name or getattr(source, "name", "workload"),
            monitor=monitor, store=store, **kwargs)
        if service is not None:
            service.register(session)
        return session

    def plane(self, n_shards: int = 2, *, runner: str = "thread",
              chaos=None, supervisor=None):
        """A sharded ``telemetry.TelemetryPlane`` — a drop-in
        ``TelemetryService`` whose registered sessions are partitioned
        across ``n_shards`` shards and whose snapshot is merged from
        per-shard summaries, bitwise-identical to the unsharded service:

            plane = model.plane(4)
            model.serve(counts_fn, service=plane, ...)
            ...
            print(plane.to_json())          # same bits, any shard count

        ``runner`` picks the drain substrate: ``"thread"`` (default),
        ``"serial"``, or ``"process"`` (spawned workers over
        shared-memory rings; a batch drain for unstarted sessions).

        ``chaos`` sabotages shard workers per the plan's
        ``crash_shards``/``hang_shards`` (process runner only);
        ``supervisor`` tunes the heartbeat/restart policy
        (``telemetry.SupervisorConfig``).
        """
        from repro.telemetry.plane import TelemetryPlane
        return TelemetryPlane(n_shards, runner=runner, chaos=chaos,
                              supervisor=supervisor)

    def serve(self, counts_fn=None, *, requests=None, **kwargs):
        """An energy-metered continuous-batching server on this model.

        Returns a ``serve.EnergyServer``: admission packs decode batches to
        a J/token budget (priced with this model's predictor), the drift
        detector can shed load, and every aligned step's measured and
        predicted joules land on individual requests in a conservation-
        exact ledger with per-tenant bills.

            server = model.serve(policy=EnergyPolicy(budget_j_per_token=...))
            report = server.run([Request("r0", "tenant-a", 128, 32), ...])
            print(report.table())

        ``counts_fn(kind, batch, tokens)`` supplies per-step op counts;
        when omitted, ``serve.synthetic_counts_fn()`` stands in (demos,
        tests).  Pass ``requests=[...]`` to run immediately and get the
        ``ServeReport`` instead of the server.
        """
        from repro.serve.scheduler import EnergyServer, synthetic_counts_fn
        server = EnergyServer(self, counts_fn or synthetic_counts_fn(),
                              **kwargs)
        if requests is not None:
            return server.run(requests)
        return server

    def evaluate(self, **kwargs):
        """Full workload-suite evaluation (paper Figs. 6-9 pipeline)."""
        from repro.core.evaluate import evaluate_system
        return evaluate_system(self.system, model=self, **kwargs)

    # -- kernel microscopy / autotuning ---------------------------------------
    def microscope(self, launches, *, steps: int = 4,
                   step_counts: Union[ProfileSource, OpCounts, None] = None,
                   name: str = "microscope", **stream_kw) -> MicroscopeReport:
        """Per-launch kernel energy breakdown of a repeated workload step.

        ``launches`` declares the kernels inside one logical step, in
        launch order; each item is a ``Profile`` (its counts become the
        launch's counts), a ``(name, source)`` /
        ``(name, source, variant)`` / ``(name, source, variant, config)``
        tuple, or a dict with those keys.  The model streams ``steps``
        identical steps on its device, subdivides every step's measured
        joules into per-launch kernel windows (plus an
        ``__unattributed__`` remainder), and returns a
        ``MicroscopeReport`` whose windows tile step energy bitwise:

            prof = model.profile(step_fn, *args)
            rep = model.microscope([("flash", model.profile(attn, q, k, v))],
                                   step_counts=prof)
            rep.kernels["flash"]["j_per_launch"]

        ``step_counts`` defaults to the sum of the launch counts (a step
        that is nothing but the declared kernels).
        """
        specs = []
        for item in launches:
            variant, config = "pallas", ()
            if isinstance(item, Profile):
                lname, src = item.name, item
            elif isinstance(item, dict):
                lname = item["name"]
                src = item.get("source", item.get("counts"))
                variant = item.get("variant", variant)
                config = tuple(item.get("config", ()) or ())
            else:
                lname, src, *rest = item
                if rest:
                    variant = rest[0]
                if len(rest) > 1:
                    config = tuple(rest[1] or ())
            specs.append((str(lname), self._resolve(src), variant, config))
        if not specs:
            raise ValueError("microscope() needs at least one launch")
        if step_counts is None:
            total = OpCounts()
            for _, c, _, _ in specs:
                total.merge(c, 1.0)
        else:
            total = self._resolve(step_counts)
        session = self.stream(total, name=name, **stream_kw)
        for lname, c, variant, config in specs:
            with session.kernel_scope(lname, variant=variant, config=config,
                                      counts=c):
                pass
        for i in range(steps):
            session.step(i)
        summary = session.finish()
        return MicroscopeReport(summary=summary,
                                kernels=session.kernel_report(),
                                session=session)

    def tune_kernel(self, kernel: str, *, store=None, **kwargs):
        """Search block configs for ``kernel``, minimizing measured J/op.

        Runs the staged micro-calibration autotuner
        (``repro.kernels.autotune``) on this model's device, persists the
        measured entries to the store's kernel-energy tier
        (``<system>__kernels__v1.json``) and activates them, so
        ``block_config="auto"`` on the shipped kernels picks the winner:

            result = model.tune_kernel("flash_attention")
            result.improvement          # 1 - winner J/op / default J/op
            ops.flash_attention(q, k, v, block_config="auto")

        Keyword arguments (``operating_point``, ``latency_ceiling_s``,
        ``shape``, ``exhaustive``, ...) pass through to
        ``autotune.tune``.  Returns the ``KernelTuneResult``.
        """
        from repro.kernels import autotune
        store_obj = store if isinstance(store, TableStore) else default_store()
        kwargs.setdefault(
            "run_dir", store_obj.root / "runs" / f"{self.system}__kernels")
        return autotune.tune_and_store(kernel, self.device, self.system,
                                       store=store_obj, **kwargs)

    def __repr__(self) -> str:
        return (f"EnergyModel(system={self.system!r}, "
                f"classes={len(self.table.direct)}, "
                f"p_const={self.table.p_const:.1f}W, "
                f"p_static={self.table.p_static:.1f}W)")
