"""Roofline terms from a compiled dry-run artifact (§Roofline).

    compute term    = HLO_FLOPs   / (peak_FLOP/s per chip)
    memory term     = HLO_bytes   / (HBM bandwidth per chip)
    collective term = wire bytes  / (ICI link bandwidth per chip)

``cost_analysis()`` on a partitioned executable already reports per-device
flops/bytes; collective bytes come from the HLO text (``collectives.py``).
Hardware constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(TPU v5e — ``repro.hw.spec.V5E``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.hlo.collectives import CollectiveStats, collective_bytes
from repro.hw.spec import ChipSpec, V5E


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_device: float      # 6·N·D style useful flops
    collectives: Optional[CollectiveStats] = None

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """Achieved fraction of the compute roofline at the lower-bound step
        time: useful FLOPs / (peak × step_time)."""
        denom = self.step_time_s
        if denom <= 0:
            return 0.0
        return self.compute_s / denom * self.useful_flops_ratio

    def as_row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, *, arch: str = "?", shape: str = "?",
                           mesh: str = "?", model_flops_total: float = 0.0,
                           n_devices: int = 1,
                           chip: ChipSpec = V5E,
                           hlo_text: Optional[str] = None,
                           program_flops_total: Optional[float] = None,
                           program_hbm_bytes_total: Optional[float] = None
                           ) -> RooflineTerms:
    """Derive the three terms from ``compiled`` (an XLA executable).

    XLA's ``cost_analysis`` counts while-loop bodies ONCE (scan-over-layers
    would be under-counted by ~n_layers), so callers pass jaxpr-exact
    ``program_flops_total`` / ``program_hbm_bytes_total`` (dynamic counts,
    trip-multiplied); cost_analysis is the fallback for loop-free programs.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    if program_flops_total is not None:
        flops = program_flops_total / max(n_devices, 1)
    else:
        flops = float(ca.get("flops", 0.0))
    if program_hbm_bytes_total is not None:
        mem_bytes = program_hbm_bytes_total / max(n_devices, 1)
    else:
        mem_bytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh,
        flops_per_device=flops,
        bytes_per_device=mem_bytes,
        wire_bytes_per_device=coll.wire_bytes_per_chip,
        compute_s=flops / chip.peak_bf16_flops,
        memory_s=mem_bytes / chip.hbm_bandwidth,
        collective_s=coll.wire_bytes_per_chip / chip.ici_link_bandwidth,
        model_flops_per_device=model_flops_total / max(n_devices, 1),
        collectives=coll,
    )
