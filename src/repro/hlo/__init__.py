from repro.hlo.parse import HloModule, parse_hlo_text, shape_bytes
from repro.hlo.collectives import CollectiveStats, collective_bytes
from repro.hlo.opcount import count_hlo_module, count_hlo_text
from repro.hlo.roofline import RooflineTerms, roofline_from_compiled

__all__ = ["HloModule", "parse_hlo_text", "shape_bytes",
           "CollectiveStats", "collective_bytes",
           "count_hlo_module", "count_hlo_text",
           "RooflineTerms", "roofline_from_compiled"]
