"""Op counting over compiled (optimized) HLO text — the post-XLA profiler.

``repro.core.opcount`` counts work on the *jaxpr* (pre-compilation); this
module is the complementary ``ProfileSource``: given the optimized HLO text
of a compiled executable (``compiled.as_text()``), it produces the same
``OpCounts`` currency.  That matters for programs only available as a
compiled artifact (a serving binary, a dry-run dump from another host) where
no Python callable exists to retrace.

This is the second *front-end* over the shared accumulation core
(``repro.core.counting``).  The front-end owns only what is HLO-specific:
the opcode tables, shape/operand extraction from the text, and the walk
(start at the entry computation, inline ``call``/``fusion`` bodies, multiply
``while`` bodies by their best-effort trip counts).  Every accounting
decision — dtype grouping, MMA-generation selection, convert classes,
collective wire bytes (computed here from *result* shapes, converted by the
core), worst-branch conditionals, trip-count multiplication, and the
boundary/fused traffic split — is the core's, shared verbatim with the
jaxpr counter.  Instructions inside a ``fusion`` contribute *fused* traffic
(VMEM/VREG resident); top-level operands/results are fusion-boundary
traffic.  Where an operand's shape cannot be resolved from the text, the
accounting degrades gracefully (result-shape-only estimate) rather than
failing.
"""
from __future__ import annotations

import math
import re
from typing import Dict, Optional

from repro.core import counting, isa
from repro.core.counting import OpCounts
from repro.hlo.parse import (HloComputation, HloInstr, HloModule,
                             _SHAPE_RE, parse_hlo_text, shape_bytes)

# HLO opcode -> jax-primitive-style head (folded by ``isa.group_class``).
_UNARY = {
    "exponential": "exp", "exponential-minus-one": "exp", "log": "log",
    "log-plus-one": "log", "tanh": "tanh", "logistic": "logistic",
    "rsqrt": "rsqrt", "sqrt": "sqrt", "cbrt": "rsqrt", "erf": "erf",
    "sine": "sin", "cosine": "cos", "tan": "sin", "negate": "sub",
    "abs": "max", "sign": "cmp", "floor": "max", "ceiling": "max",
    "round-nearest-afz": "max", "round-nearest-even": "max", "not": "xor",
    "is-finite": "cmp", "population-count": "add", "count-leading-zeros": "add",
}
_BINARY = {
    "add": "add", "multiply": "mul", "subtract": "sub", "divide": "div",
    "maximum": "max", "minimum": "min", "power": "pow", "remainder": "div",
    "and": "and", "or": "or", "xor": "xor", "atan2": "pow",
    "shift-left": "shift", "shift-right-logical": "shift",
    "shift-right-arithmetic": "shift",
}
_MOVE = {
    "broadcast": "bcast", "transpose": "transpose", "concatenate": "concat",
    "slice": "slice", "dynamic-slice": "slice", "reverse": "slice",
    "iota": "iota", "pad": "pad",
}
# Structural opcodes with no work units of their own.
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "reshape",
    "after-all", "partition-id", "replica-id", "opt-barrier",
    "get-dimension-size", "domain", "token",
}
# Collectives: HLO opcode -> canonical class.  Wire-bytes formulas are the
# core's; HLO observes *result* shapes, so the conversion to each formula's
# local-bytes reference happens in ``counting.collective_wire_bytes``.
_COLLECTIVE_CLASS: Dict[str, str] = {
    "all-reduce": "ici.all_reduce",
    "all-reduce-start": "ici.all_reduce",
    "all-gather": "ici.all_gather",
    "all-gather-start": "ici.all_gather",
    "reduce-scatter": "ici.reduce_scatter",
    "all-to-all": "ici.all_to_all",
    "collective-permute": "ici.permute",
    "collective-permute-start": "ici.permute",
}
_DONE = {"all-gather-done", "all-reduce-done", "collective-permute-done",
         "async-done"}

_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DIMS_ATTR_RE = re.compile(r"(\w+_contracting_dims)=\{([0-9,]*)\}")


def _shape_elems(type_str: str) -> float:
    total = 0.0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _dtype_tag(type_str: str) -> str:
    m = _SHAPE_RE.search(type_str)
    return counting.dtype_tag(m.group(1)) if m else "f32"


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _operands(ins: HloInstr):
    """Operand names of an instruction (best-effort from the raw text).

    Real ``as_text()`` output spells operands with their types
    (``dot(f32[256,512]{1,0} %Arg_0.1, ...)``); hand-written or abbreviated
    HLO uses bare names (``dot(%x, %w)``).  Prefer the ``%``-prefixed names
    when present so type tokens are never mistaken for operands.
    """
    _, _, rest = ins.raw.partition(ins.opcode + "(")
    args = rest.split(")", 1)[0]
    named = re.findall(r"%([\w.\-]+)", args)
    return named if named else re.findall(r"([\w.\-]+)", args)


def _group_size(raw: str) -> int:
    m = _GROUPS_V2_RE.search(raw)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(raw)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip()]
        if members:
            return len(members)
    # absent attribute, or XLA's `replica_groups={}` (= all replicas in one
    # group, count not recoverable from the text): conservative 2-chip group
    # so the collective's wire bytes are not dropped
    return 2


def _trip_count(module: HloModule, cond_name: Optional[str]) -> float:
    comp = module.get(cond_name) if cond_name else None
    if comp is None:
        return 1.0
    consts = []
    for ins in comp.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m:
                consts.append(int(m.group(1)))
    return float(max(consts)) if consts else 1.0


class _Walker:
    def __init__(self, module: HloModule, isa_gen: int):
        self.module = module
        self.isa_gen = isa_gen
        self.defs: Dict[str, HloInstr] = {}
        for comp in module.computations.values():
            for ins in comp.instrs:
                self.defs.setdefault(ins.name, ins)

    def _operand_type(self, name: str) -> Optional[str]:
        ins = self.defs.get(name)
        return ins.type_str if ins is not None else None

    def _dot(self, ins: HloInstr, out: OpCounts, mult: float) -> None:
        out_elems = _shape_elems(ins.type_str)
        ops = _operands(ins)
        k = batch = 1.0
        m = n = 128.0          # unresolvable -> assume MXU-aligned
        lhs_type = self._operand_type(ops[0]) if ops else None
        rhs_type = self._operand_type(ops[1]) if len(ops) > 1 else None
        dims_attrs = dict(_DIMS_ATTR_RE.findall(ins.raw))

        def _attr_dims(key: str):
            raw = dims_attrs.get(key)
            if raw is None:
                m_ = re.search(key + r"=\{([0-9,]*)\}", ins.raw)
                raw = m_.group(1) if m_ else None
            return ([int(d) for d in raw.split(",") if d]
                    if raw is not None else None)

        lhs_b = _attr_dims("lhs_batch_dims") or []
        rhs_b = _attr_dims("rhs_batch_dims") or []
        lhs_c = _attr_dims("lhs_contracting_dims")
        rhs_c = _attr_dims("rhs_contracting_dims") or []
        lhs_dims = _shape_dims(lhs_type) if lhs_type else None
        rhs_dims = _shape_dims(rhs_type) if rhs_type else None
        if lhs_dims is not None and lhs_c is not None \
                and all(d < len(lhs_dims) for d in lhs_c):
            k = float(math.prod(lhs_dims[d] for d in lhs_c) or 1)
            if all(d < len(lhs_dims) for d in lhs_b):
                batch = float(math.prod(
                    lhs_dims[d] for d in lhs_b) or 1)
                m = float(math.prod(
                    s for i, s in enumerate(lhs_dims)
                    if i not in lhs_c and i not in lhs_b) or 1)
        if rhs_dims is not None and all(d < len(rhs_dims)
                                        for d in rhs_c + rhs_b):
            n = float(math.prod(
                s for i, s in enumerate(rhs_dims)
                if i not in rhs_c and i not in rhs_b) or 1)
        counting.add_dot(out, isa_gen=self.isa_gen, dt=_dtype_tag(ins.type_str),
                         batch=batch, m=m, n=n, k=k,
                         macs=out_elems * k, mult=mult)

    def _instr_units(self, ins: HloInstr, out: OpCounts, mult: float) -> None:
        op = ins.opcode
        elems = _shape_elems(ins.type_str)
        dt = _dtype_tag(ins.type_str)
        if op == "dot":
            self._dot(ins, out, mult)
            return
        if op == "convolution":
            # result elems x (filter spatial x in-channels) unavailable
            # without layout metadata; approximate with result-elems MACs.
            counting.add_conv(out, dt=dt, macs=elems, mult=mult)
            return
        if op in _UNARY or op in _BINARY:
            head = _UNARY.get(op) or _BINARY[op]
            out.add(isa.group_class(f"{head}.{dt}"), mult * elems)
            out.flops += mult * elems
            return
        if op == "compare":
            out.add(isa.group_class(f"cmp.{dt}"), mult * elems)
            return
        if op == "select":
            out.add(isa.group_class(f"select.{dt}"), mult * elems)
            return
        if op == "clamp":
            out.add(isa.group_class(f"max.{dt}"), mult * 2 * elems)
            return
        if op == "convert":
            srcs = _operands(ins)
            src_t = self._operand_type(srcs[0]) if srcs else None
            src = _dtype_tag(src_t) if src_t else "f32"
            cls = counting.convert_class(src, dt)
            if cls is not None:
                out.add(isa.group_class(cls), mult * elems)
            return
        if op in _MOVE:
            out.add(_MOVE[op], mult * elems)
            return
        if op == "dynamic-update-slice":
            ops = _operands(ins)
            upd_t = self._operand_type(ops[1]) if len(ops) > 1 else None
            out.add("dus", mult * (_shape_elems(upd_t) if upd_t else elems))
            return
        if op == "gather":
            out.add("gather", mult * elems)
            return
        if op.startswith("scatter"):
            out.add(counting.scatter_class(self.isa_gen), mult * elems)
            return
        if op in ("reduce", "reduce-window"):
            ops = _operands(ins)
            in_t = self._operand_type(ops[0]) if ops else None
            n_in = _shape_elems(in_t) if in_t else elems
            # the to_apply computation tells add- from max-style reductions
            reducer = self.module.get(ins.attr("to_apply") or "")
            is_max = reducer is not None and any(
                i.opcode in ("maximum", "minimum") for i in reducer.instrs)
            counting.add_reduce(out, is_max, n_in, mult)
            return
        if op == "sort":
            ops = _operands(ins)
            in_t = self._operand_type(ops[0]) if ops else None
            n_in = _shape_elems(in_t) if in_t else elems
            dims = _shape_dims(in_t) if in_t else None
            last = float(dims[-1]) if dims else 2.0
            out.add("sort", mult * counting.sort_units(n_in, last))
            return
        if op in ("rng", "rng-bit-generator", "rng-get-and-update-state"):
            out.add("rng.bits", mult * max(elems, 1.0))
            return
        if op == "custom-call":
            # opaque kernel: emit a raw class for the bucketing machinery
            out.add(isa.group_class(f"custom.{dt}"), mult * max(elems, 1.0))
            return
        out.add(isa.group_class(f"{op.replace('-', '_')}.{dt}"),
                mult * max(elems, 1.0))

    def walk(self, comp: HloComputation, out: OpCounts, mult: float,
             in_fusion: bool, depth: int = 0) -> None:
        if depth > 32:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE or op in _DONE:
                continue
            if op == "while":
                trips = _trip_count(self.module, ins.attr("condition"))
                body = self.module.get(ins.attr("body") or "")
                body_counts = OpCounts()
                if body is not None:
                    self.walk(body, body_counts, 1.0, in_fusion, depth + 1)
                counting.merge_loop_body(out, body_counts, trips, mult)
                continue
            if op == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      ins.raw)
                names = []
                for grp, single in branches:
                    names += ([s.strip().lstrip("%") for s in grp.split(",")]
                              if grp else [single])
                branch_counts = []
                for name in filter(None, names):
                    sub = self.module.get(name)
                    if sub is None:
                        continue
                    c = OpCounts()
                    self.walk(sub, c, 1.0, in_fusion, depth + 1)
                    branch_counts.append(c)
                counting.merge_best_branch(out, branch_counts, mult)
                continue
            if op in ("fusion", "call", "async-start"):
                callee = ins.attr("calls") or ins.attr("to_apply")
                sub = self.module.get(callee) if callee else None
                if sub is not None:
                    self.walk(sub, out, mult,
                              in_fusion or op == "fusion", depth + 1)
                if not in_fusion:
                    # the fusion/call root's operands+result cross HBM/VMEM
                    self._boundary_io(ins, out, mult)
                    out.dispatch_count += mult
                continue
            if op in _COLLECTIVE_CLASS:
                counting.add_collective(out, _COLLECTIVE_CLASS[op],
                                        ins.result_bytes, _group_size(ins.raw),
                                        mult, from_result=True)
                continue
            self._instr_units(ins, out, mult)
            out.exec_count += mult
            if in_fusion:
                b = ins.result_bytes
                for o in _operands(ins):
                    t = self._operand_type(o)
                    if t is not None:
                        b += shape_bytes(t)
                out.add_fused_io(b, mult)
            else:
                self._boundary_io(ins, out, mult)
                out.dispatch_count += mult

    def _boundary_io(self, ins: HloInstr, out: OpCounts, mult: float) -> None:
        b_read = 0.0
        for o in _operands(ins):
            t = self._operand_type(o)
            if t is not None:
                b = shape_bytes(t)
                b_read += b
                out.note_buffer(b)
        b_write = ins.result_bytes
        out.note_buffer(b_write)
        out.add_io(b_read, b_write, 0.0, mult)


def count_hlo_module(module: HloModule, *, isa_gen: int = 0) -> OpCounts:
    """Count dynamic work units over a parsed HLO module."""
    out = OpCounts()
    entry = module.get(module.entry) if module.entry else None
    if entry is None and module.computations:
        # fall back: largest computation is almost always the entry
        entry = max(module.computations.values(), key=lambda c: len(c.instrs))
    if entry is not None:
        _Walker(module, isa_gen).walk(entry, out, 1.0, in_fusion=False)
    return out


def count_hlo_text(text: str, *, isa_gen: int = 0) -> OpCounts:
    """Count dynamic work units in optimized HLO text (``as_text()``)."""
    return count_hlo_module(parse_hlo_text(text), isa_gen=isa_gen)
