"""Collective-bytes accounting from compiled (SPMD-partitioned) HLO.

``cost_analysis()`` does not report collective traffic, so §Roofline's
collective term is derived here: walk the entry computation, multiply
through ``while`` trip counts (scan-over-layers!) and fusion calls, and sum
wire bytes for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, using replica-group sizes for the per-chip wire factor.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional

from repro.hlo.parse import HloComputation, HloModule, parse_hlo_text, shape_bytes

# opcode -> wire bytes per chip given (result_bytes, group_size)
_WIRE = {
    "all-gather": lambda b, n: b * (n - 1) / max(n, 1),
    "all-gather-start": lambda b, n: b * (n - 1) / max(n, 1),
    "all-reduce": lambda b, n: 2.0 * b * (n - 1) / max(n, 1),
    "all-reduce-start": lambda b, n: 2.0 * b * (n - 1) / max(n, 1),
    "reduce-scatter": lambda b, n: b * (n - 1),
    "all-to-all": lambda b, n: b * (n - 1) / max(n, 1),
    "ragged-all-to-all": lambda b, n: b * (n - 1) / max(n, 1),
    "collective-permute": lambda b, n: b,
    "collective-permute-start": lambda b, n: b,
}
_SKIP_DONE = {"all-gather-done", "all-reduce-done", "collective-permute-done"}

_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes_per_chip: float
    by_kind: Dict[str, float]
    count_by_kind: Dict[str, float]
    while_trips: Dict[str, float]

    def dominant_kind(self) -> Optional[str]:
        if not self.by_kind:
            return None
        return max(self.by_kind, key=self.by_kind.get)


def _group_size(raw: str) -> int:
    m = _GROUPS_V2_RE.search(raw)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(raw)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(members), 1)
    return 2


def _trip_count(module: HloModule, cond_name: str) -> float:
    """Best-effort while trip count from the condition computation."""
    comp = module.get(cond_name)
    if comp is None:
        return 1.0
    consts = []
    for ins in comp.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m:
                consts.append(int(m.group(1)))
    return float(max(consts)) if consts else 1.0


def _walk(module: HloModule, comp: HloComputation, mult: float,
          stats: CollectiveStats, seen_depth: int = 0) -> None:
    if seen_depth > 32:
        return
    for ins in comp.instrs:
        op = ins.opcode
        if op in _SKIP_DONE:
            continue
        if op in _WIRE:
            n = _group_size(ins.raw)
            if n <= 1:
                continue
            b = ins.result_bytes
            if op.startswith("reduce-scatter") or op == "all-to-all":
                pass  # result is the per-shard piece
            wire = _WIRE[op](b, n)
            kind = op.replace("-start", "")
            stats.by_kind[kind] += wire * mult
            stats.count_by_kind[kind] += mult
            stats.wire_bytes_per_chip += wire * mult
            continue
        if op == "while":
            body = ins.attr("body")
            cond = ins.attr("condition")
            trips = _trip_count(module, cond) if cond else 1.0
            stats.while_trips[body or "?"] = trips
            sub = module.get(body) if body else None
            if sub is not None:
                _walk(module, sub, mult * trips, stats, seen_depth + 1)
            continue
        if op in ("fusion", "call", "async-start"):
            callee = ins.attr("calls") or ins.attr("to_apply")
            sub = module.get(callee) if callee else None
            if sub is not None:
                _walk(module, sub, mult, stats, seen_depth + 1)
            continue
        if op == "conditional":
            for key in ("true_computation", "false_computation",
                        "branch_computations"):
                callee = ins.attr(key)
                sub = module.get(callee) if callee else None
                if sub is not None:
                    _walk(module, sub, mult, stats, seen_depth + 1)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    module = parse_hlo_text(hlo_text)
    stats = CollectiveStats(0.0, defaultdict(float), defaultdict(float), {})
    entry = module.get(module.entry) if module.entry else None
    if entry is None and module.computations:
        # fall back: the computation with the most instructions
        entry = max(module.computations.values(), key=lambda c: len(c.instrs))
    if entry is not None:
        _walk(module, entry, 1.0, stats)
    stats.by_kind = dict(stats.by_kind)
    stats.count_by_kind = dict(stats.count_by_kind)
    return stats
