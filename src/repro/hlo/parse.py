"""Minimal optimized-HLO text parser.

Extracts, per computation: the instruction list (opcode, result shape,
attributes) and the call graph (fusion ``calls=``, ``while`` body/condition,
``call to_apply=``, conditional branches), plus best-effort ``while`` trip
counts (scan-lowered loops compare an induction variable against an s32
constant in the condition computation).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (handles tuples by summation)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        size = _DTYPE_BYTES.get(dt)
        if size is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * size
    return total


@dataclasses.dataclass
class HloInstr:
    name: str
    opcode: str
    type_str: str
    raw: str

    @property
    def result_bytes(self) -> float:
        return shape_bytes(self.type_str)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=([%\w.\-]+)", self.raw)
        return m.group(1) if m else None


@dataclasses.dataclass
class HloComputation:
    name: str
    instrs: List[HloInstr]


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, HloComputation]
    entry: Optional[str]

    def get(self, name: str) -> Optional[HloComputation]:
        return self.computations.get(name.lstrip("%"))


# `  %name = type opcode(...)` or `  ROOT %name = ...`
# (tuple types may contain /*index=N*/ comments; they contain no parens)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(")
# `%name (params...) -> type {`  /  `ENTRY %name (...) -> ... {`
# (types may contain layout braces and /*index=N*/ comments)
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _is_comp_header(line: str) -> Optional[Tuple[bool, str]]:
    if not line.rstrip().endswith("{"):
        return None
    m = _COMP_HEAD_RE.match(line.lstrip())
    if not m:
        return None
    head = line.split("(", 1)[0]
    if "=" in head:          # `%x = type op(...) ... {` is an instruction
        return None
    return bool(m.group(1)), m.group(2)


def parse_hlo_text(text: str) -> HloModule:
    computations: Dict[str, HloComputation] = {}
    entry: Optional[str] = None
    current: Optional[HloComputation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if current is None:
            hdr = _is_comp_header(stripped)
            if hdr is not None:
                is_entry, name = hdr
                current = HloComputation(name=name, instrs=[])
                if is_entry:
                    entry = name
            continue
        if stripped.strip() == "}" or stripped.startswith("}"):
            computations[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(stripped)
        if m:
            current.instrs.append(HloInstr(
                name=m.group(1), type_str=m.group(2), opcode=m.group(3),
                raw=stripped))
    if current is not None:
        computations[current.name] = current
    return HloModule(computations=computations, entry=entry)
