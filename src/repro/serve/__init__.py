"""Energy-metered serving: ledger, billing, energy-aware batching.

The subsystem that turns the library from a profiler into a serving
platform: ``ledger`` attributes each aligned step's joules to in-flight
requests with bitwise conservation, ``billing`` rolls requests into
per-tenant bills, and ``scheduler`` runs continuous batching with energy
as a first-class admission signal (J/token budget, drift shedding).
``step`` holds the jitted model prefill/decode steps and is imported
lazily so the scheduling/accounting layer stays importable without jax.
"""
from repro.serve.billing import BillingReport, TenantBill, bill_tenants
from repro.serve.ledger import (ActiveShare, LedgerEntry, LedgerPolicy,
                                LedgerStep, RequestLedger, RequestTotals,
                                fold_residual, split_conserving)
from repro.serve.scheduler import (ContinuousBatchingScheduler, EnergyPolicy,
                                   EnergyServer, Phase, PhaseSummary, Request,
                                   RequestRow, ServeEvent, ServeReport,
                                   synthetic_counts_fn)

_STEP_NAMES = ("make_prefill_step", "make_serve_step", "greedy_generate")

__all__ = [
    "ActiveShare", "BillingReport", "ContinuousBatchingScheduler",
    "EnergyPolicy", "EnergyServer", "LedgerEntry", "LedgerPolicy",
    "LedgerStep", "Phase", "PhaseSummary", "Request", "RequestLedger",
    "RequestRow", "RequestTotals", "ServeEvent", "ServeReport", "TenantBill",
    "bill_tenants", "fold_residual", "split_conserving",
    "synthetic_counts_fn", *_STEP_NAMES,
]


def __getattr__(name):
    if name in _STEP_NAMES:
        from repro.serve import step
        return getattr(step, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
