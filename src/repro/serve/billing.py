"""Tenant aggregation: per-request ledger entries rolled into bills.

Tenant bills regroup the ledger's per-request entries, and regrouping a
float sum re-rounds it — so the bill column is *re-conserved* against the
run total with the same residual-folding discipline the ledger uses per
step (``ledger.fold_residual``): the ulp-scale regrouping residual lands
on the final bill in tenant-name order, and the left-to-right sum of
bills (same order) reproduces ``RequestLedger.measured_total_j``
bit-for-bit.  A bill never leaks or invents a joule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.serve.ledger import RequestLedger, fold_residual


@dataclasses.dataclass
class TenantBill:
    """One tenant's energy bill for a serving run."""

    tenant: str
    requests: int
    steps: int                   # ledger entries billed to this tenant
    tokens: float                # logical tokens (prompt + generated)
    scaled_tokens: float         # tokens × per-step work scale
    measured_j: float
    predicted_j: float

    @property
    def j_per_token(self) -> float:
        return self.measured_j / max(self.scaled_tokens, 1e-12)

    @property
    def residual_j(self) -> float:
        """Predicted-vs-measured gap — the model's exposure on this bill."""
        return self.measured_j - self.predicted_j

    def snapshot(self) -> dict:
        return {
            "requests": self.requests,
            "steps": self.steps,
            "tokens": self.tokens,
            "measured_j": self.measured_j,
            "predicted_j": self.predicted_j,
            "j_per_token": self.j_per_token,
            "residual_j": self.residual_j,
        }


@dataclasses.dataclass
class BillingReport:
    """All tenants' bills plus the conserved run totals."""

    bills: Dict[str, TenantBill]          # tenant -> bill, name-sorted
    measured_total_j: float               # == ledger.measured_total_j
    predicted_total_j: float

    def snapshot(self) -> dict:
        """JSON-safe form — the dashboard's billing pane."""
        return {
            "tenants": {t: b.snapshot() for t, b in self.bills.items()},
            "measured_total_j": self.measured_total_j,
            "predicted_total_j": self.predicted_total_j,
            "residual_j": self.measured_total_j - self.predicted_total_j,
        }


def bill_tenants(ledger: RequestLedger) -> BillingReport:
    """Aggregate a ledger into per-tenant bills (conserved, see module doc)."""
    order: List[str] = []
    agg: Dict[str, TenantBill] = {}
    req_seen: Dict[str, set] = {}
    for step in ledger.steps:
        for e in step.entries:
            b = agg.get(e.tenant)
            if b is None:
                b = agg[e.tenant] = TenantBill(
                    tenant=e.tenant, requests=0, steps=0, tokens=0.0,
                    scaled_tokens=0.0, measured_j=0.0, predicted_j=0.0)
                order.append(e.tenant)
                req_seen[e.tenant] = set()
            b.steps += 1
            b.tokens += e.tokens
            b.scaled_tokens += e.tokens * step.work_scale
            b.measured_j += e.measured_j
            b.predicted_j += e.predicted_j
            req_seen[e.tenant].add(e.request_id)
    for t, b in agg.items():
        b.requests = len(req_seen[t])

    measured_total = ledger.measured_total_j
    predicted_total = ledger.predicted_total_j
    names = sorted(order)
    if names:
        measured = fold_residual([agg[t].measured_j for t in names],
                                 measured_total)
        predicted = fold_residual([agg[t].predicted_j for t in names],
                                  predicted_total)
        for i, t in enumerate(names):
            agg[t].measured_j = measured[i]
            agg[t].predicted_j = predicted[i]
    return BillingReport(bills={t: agg[t] for t in names},
                         measured_total_j=measured_total,
                         predicted_total_j=predicted_total)
