"""Per-request energy ledger — step joules attributed to in-flight requests.

León-Vega et al. split a shared device's measured energy across the
processes occupying it; a serving batch is the same problem one level
down: every aligned prefill/decode step (``telemetry/align``) carries one
*measured* and one *predicted* joule figure for a batch of co-resident
requests, and billing needs those joules on individual requests.

The split is a blend of the three occupancy signals a serving runtime
actually has:

* **active-token share** — the compute a request put through the step
  (its prompt tokens in a prefill step, one token per decode step);
* **batch occupancy** — an even share of the step, the "seat rent";
* **KV-cache residency** — bytes of cache the request held during the
  step, the memory it denied everyone else.

The dynamic fraction of the step's energy (taken from the step's own
prediction) follows active tokens; the rest — the const/static floor the
batch pays for existing — is split between occupancy and residency
(``LedgerPolicy.residency_frac``).

**Conservation is bitwise**, the same tiling discipline as the aligner:
for every step, the left-to-right sum of per-request energies (in entry
order) equals the step's aligned total *exactly* — no joule is created or
lost to float round-off.  ``split_conserving`` owes that guarantee to a
residual-folding fixpoint: shares are computed by plain multiplication and
the ulp-scale summation residual is folded into the final entry until the
sum reproduces the total bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

_MAX_FOLD_ITERS = 64


def fold_residual(parts: Sequence[float], total: float) -> List[float]:
    """Nudge ``parts`` (ulp-scale) until ``sum(parts) == total`` *bitwise*.

    The left-to-right Python sum is the reference order.  A single
    residual carrier cannot do this in general: the running sum only moves
    in whole ulps of the carrier, and when those land on rounding *ties*,
    round-half-to-even skips an odd-mantissa total no matter how the
    carrier moves.  Instead the parts are rebuilt right-to-left: for each
    suffix target ``t``, the entry is set to ``x ≈ t - head`` and nudged
    (by single ulps, breaking tie alignment) until the float identity
    ``fl(fl(t - x) + x) == t`` holds; ``fl(t - x)`` becomes the target the
    remaining prefix must reach, and the identity telescopes — by
    induction the full left-to-right sum reproduces ``total`` exactly.
    (In the common case ``x`` is within a factor two of ``t`` and Sterbenz
    makes the subtraction exact, so no nudging is needed at all.)  Every
    entry stays within ulps of its proportional value.
    """
    parts = list(parts)
    n = len(parts)
    if n == 0:
        if total != 0.0:
            raise ValueError(f"cannot fold {total!r} into zero parts")
        return parts
    prefix = [0.0] * n                 # fl-sum of parts[:k], reference order
    acc = 0.0
    for k, p in enumerate(parts):
        prefix[k] = acc
        acc += p
    if acc == total:
        return parts
    t = float(total)
    for k in range(n - 1, 0, -1):
        x = t - prefix[k]
        for _ in range(_MAX_FOLD_ITERS):
            head = t - x
            got = head + x
            if got == t:
                break
            x = math.nextafter(x, math.inf if t > got else -math.inf)
        else:
            raise ArithmeticError(
                f"residual folding did not converge at entry {k}: "
                f"target {t!r}")
        parts[k] = x
        t = head
    parts[0] = t
    acc = 0.0
    for p in parts:
        acc += p
    if acc != total:                   # unreachable: the identity telescopes
        raise ArithmeticError(
            f"residual folding did not converge: sum {acc!r} != "
            f"total {total!r}")
    return parts


def split_conserving(total: float, weights: Sequence[float]) -> np.ndarray:
    """Split ``total`` proportionally to ``weights``; sums back bitwise.

    Returns one part per weight such that the left-to-right sum of the
    parts equals ``total`` exactly.  Zero (or degenerate) weight vectors
    fall back to an even split.  The ulp-scale float residual of the
    proportional multiplication is folded into the final part (see
    ``fold_residual`` for why it must be the last in summation order).
    """
    w = np.asarray(weights, dtype=float)
    n = w.size
    if n == 0:
        if total != 0.0:
            raise ValueError(f"cannot split {total!r} J across zero requests")
        return np.zeros(0)
    if n == 1:
        return np.asarray([float(total)])
    wsum = float(np.sum(w))
    if not np.isfinite(wsum) or wsum <= 0.0 or np.any(w < 0):
        w = np.ones(n)
        wsum = float(n)
    parts = [float(total) * (float(wi) / wsum) for wi in w]
    return np.asarray(fold_residual(parts, float(total)))


@dataclasses.dataclass(frozen=True)
class ActiveShare:
    """One request's occupancy of one step, as the scheduler saw it."""

    request_id: str
    tenant: str
    tokens: float            # active tokens this request processed this step
    kv_bytes: float          # KV-cache bytes resident during the step


@dataclasses.dataclass
class LedgerEntry:
    """One request's share of one aligned step."""

    step: int
    request_id: str
    tenant: str
    kind: str                # "prefill" | "decode"
    tokens: float
    kv_bytes: float
    weight: float            # normalized blend weight used for the split
    measured_j: float
    predicted_j: float

    @property
    def residual_j(self) -> float:
        return self.measured_j - self.predicted_j


@dataclasses.dataclass
class LedgerStep:
    """One aligned step's totals plus its per-request split.

    ``sum(e.measured_j for e in entries)`` (left-to-right, entry order)
    equals ``measured_j`` bitwise; same for the predicted column.
    ``work_scale`` is the number of device iterations each logical step
    spanned (``StreamSession.iterations_per_step``), so per-token figures
    stay true per-token: J/token = measured_j / (tokens * work_scale).
    """

    step: int
    kind: str
    duration_s: float
    measured_j: float
    predicted_j: float
    work_scale: float
    entries: List[LedgerEntry]

    @property
    def batch(self) -> int:
        return len(self.entries)

    @property
    def tokens(self) -> float:
        return sum(e.tokens for e in self.entries)


@dataclasses.dataclass(frozen=True)
class LedgerPolicy:
    """How a step's joules are prorated across its occupants.

    The step's dynamic fraction (from its own prediction) follows active
    tokens; the non-dynamic remainder is split ``residency_frac`` by
    KV-cache bytes and the rest evenly across the batch.
    """

    residency_frac: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.residency_frac <= 1.0:
            raise ValueError(f"residency_frac {self.residency_frac} "
                             f"outside [0, 1]")

    def weights(self, active: Sequence[ActiveShare],
                dynamic_frac: float) -> np.ndarray:
        n = len(active)
        dyn = min(max(float(dynamic_frac), 0.0), 1.0)
        toks = np.asarray([a.tokens for a in active], dtype=float)
        kv = np.asarray([a.kv_bytes for a in active], dtype=float)
        tok_share = toks / toks.sum() if toks.sum() > 0 else np.full(n, 1.0 / n)
        kv_share = kv / kv.sum() if kv.sum() > 0 else np.full(n, 1.0 / n)
        even = np.full(n, 1.0 / n)
        hold = self.residency_frac * kv_share + \
            (1.0 - self.residency_frac) * even
        return dyn * tok_share + (1.0 - dyn) * hold


@dataclasses.dataclass
class RequestTotals:
    """Ledger roll-up for one request (plain sums, entry order)."""

    request_id: str
    tenant: str
    steps: int = 0
    tokens: float = 0.0           # logical tokens (prompt + generated)
    scaled_tokens: float = 0.0    # tokens × work_scale (device iterations)
    measured_j: float = 0.0
    predicted_j: float = 0.0

    @property
    def j_per_token(self) -> float:
        return self.measured_j / max(self.scaled_tokens, 1e-12)

    @property
    def residual_j(self) -> float:
        return self.measured_j - self.predicted_j


class RequestLedger:
    """Accumulates aligned steps into per-request energy attributions.

    One ``record_step`` call per aligned step; the conservation invariant
    (module docstring) holds for every recorded step, measured and
    predicted alike.
    """

    def __init__(self, policy: Optional[LedgerPolicy] = None):
        self.policy = policy or LedgerPolicy()
        self.steps: List[LedgerStep] = []

    def record_step(self, *, step: int, kind: str, duration_s: float,
                    measured_j: float, predicted_j: float,
                    dynamic_frac: float,
                    active: Sequence[ActiveShare],
                    work_scale: float = 1.0) -> LedgerStep:
        """Split one aligned step's joules across its active requests."""
        if not active:
            raise ValueError(f"step {step}: no active requests to bill")
        w = self.policy.weights(active, dynamic_frac)
        measured = split_conserving(measured_j, w)
        predicted = split_conserving(predicted_j, w)
        entries = [LedgerEntry(step=step, request_id=a.request_id,
                               tenant=a.tenant, kind=kind, tokens=a.tokens,
                               kv_bytes=a.kv_bytes, weight=float(w[i]),
                               measured_j=float(measured[i]),
                               predicted_j=float(predicted[i]))
                   for i, a in enumerate(active)]
        rec = LedgerStep(step=step, kind=kind, duration_s=duration_s,
                         measured_j=measured_j, predicted_j=predicted_j,
                         work_scale=work_scale, entries=entries)
        self.steps.append(rec)
        return rec

    # -- roll-ups ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.steps)

    @property
    def entries(self) -> List[LedgerEntry]:
        return [e for s in self.steps for e in s.entries]

    @property
    def measured_total_j(self) -> float:
        """Left-to-right sum of step totals — the run's attributed joules."""
        return sum(s.measured_j for s in self.steps)

    @property
    def predicted_total_j(self) -> float:
        return sum(s.predicted_j for s in self.steps)

    def per_request(self) -> Dict[str, RequestTotals]:
        """Roll-up per request id, in first-seen order."""
        out: Dict[str, RequestTotals] = {}
        for s in self.steps:
            for e in s.entries:
                tot = out.get(e.request_id)
                if tot is None:
                    tot = out[e.request_id] = RequestTotals(
                        request_id=e.request_id, tenant=e.tenant)
                tot.steps += 1
                tot.tokens += e.tokens
                tot.scaled_tokens += e.tokens * s.work_scale
                tot.measured_j += e.measured_j
                tot.predicted_j += e.predicted_j
        return out
