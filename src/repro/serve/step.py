"""Serving steps: batched prefill + decode against a KV/state cache."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def make_prefill_step(cfg: ModelConfig, attn_fn=None):
    """prefill(params, batch) -> (last-token logits, aux).

    Lowered for the ``prefill_*`` shapes: the full-sequence forward is the
    dominant cost; cache materialization is the decode path's first update.
    """
    def prefill(params, batch):
        logits, aux = model_mod.forward(params, batch, cfg, attn_fn=attn_fn)
        return logits[:, -1:], aux
    return prefill


def make_serve_step(cfg: ModelConfig, attn_fn=None):
    """serve_step(params, cache, tokens[B,1]) -> (next token ids, cache)."""
    def serve_step(params, cache, tokens):
        logits, cache = model_mod.decode_step(params, cache, tokens, cfg,
                                              attn_fn=attn_fn)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache
    return serve_step


# ModelConfig is a frozen dataclass and attn_fn a stable callable, so the
# pair keys compiled serve steps across generate calls — one jit per
# (config, kernel), not one per invocation.
_SERVE_STEP_CACHE: Dict[Tuple[ModelConfig, Any], Any] = {}


def jitted_serve_step(cfg: ModelConfig, attn_fn=None):
    """The jitted decode step for ``cfg``, compiled once and reused."""
    key = (cfg, attn_fn)
    step = _SERVE_STEP_CACHE.get(key)
    if step is None:
        step = _SERVE_STEP_CACHE[key] = jax.jit(make_serve_step(cfg, attn_fn))
    return step


def greedy_generate(params, cfg: ModelConfig, prompt: jnp.ndarray,
                    max_new: int, max_seq: int, attn_fn=None):
    """Greedy decode loop (example/serving driver path)."""
    b = prompt.shape[0]
    cache = model_mod.init_cache(cfg, b, max_seq)
    step = jitted_serve_step(cfg, attn_fn)
    # teacher-force the prompt through the decode path
    tok = prompt[:, :1]
    out = [tok]
    for i in range(prompt.shape[1] - 1):
        _, cache = step(params, cache, prompt[:, i:i + 1])
        out.append(prompt[:, i + 1:i + 2])
    tok = prompt[:, -1:]
    for _ in range(max_new):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
