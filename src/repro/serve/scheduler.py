"""Energy-aware continuous batching: admission, eviction, J/token budget.

The serving loop this module models is the paper's §5 posture turned into
a scheduler: fine-grained energy attribution is only worth computing if
something *acts* on it.  Requests arrive staggered, join and leave the
decode batch at step boundaries, and the admission policy is energy-aware:

* **budget packing** — a candidate admission is priced first
  (``EnergyModel.predict`` over the would-be batch's decode counts) and
  deferred if the resulting predicted J/token exceeds the budget;
* **drift shedding** — when the streaming drift detector
  (``telemetry/attrib``) flags the device running hot against its table,
  admissions pause and the newest in-flight request is shed back to the
  queue (its KV residency is dropped; it re-prefills on re-admission —
  shedding has an honest energy cost).

Execution is *phase-wise*: between two membership boundaries the batch is
constant, so each phase runs as one ``telemetry.StreamSession`` (one
device program, MTSM markers per step) and every aligned step lands in the
``RequestLedger`` with bitwise conservation.  One ``OnlineAttributor`` is
shared across phases, so the drift baseline — and any recalibration —
carries over the whole serving run, exactly the long-lived fleet posture.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.counting import OpCounts
from repro.serve.billing import BillingReport, bill_tenants
from repro.serve.ledger import (ActiveShare, LedgerPolicy, RequestLedger,
                                RequestTotals)

CountsFn = Callable[[str, int, int], OpCounts]
# (kind "prefill"|"decode", batch size, tokens per sequence) -> per-step counts


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request as submitted."""

    id: str
    tenant: str
    prompt_len: int
    max_new: int
    arrival_step: int = 0

    def __post_init__(self):
        if self.prompt_len < 1 or self.max_new < 1:
            raise ValueError(f"request {self.id!r}: prompt_len and max_new "
                             f"must be >= 1")


@dataclasses.dataclass(frozen=True)
class EnergyPolicy:
    """Admission policy knobs for the continuous-batching scheduler."""

    max_batch: int = 8
    budget_j_per_token: Optional[float] = None
    shed_on_drift: bool = True
    max_phase_steps: int = 8        # drift re-check cadence during decode

    def __post_init__(self):
        if self.max_batch < 1 or self.max_phase_steps < 1:
            raise ValueError("max_batch and max_phase_steps must be >= 1")


@dataclasses.dataclass
class ServeEvent:
    """One scheduling decision, for the report's audit trail."""

    step: int
    event: str            # admit | defer | evict | shed | idle
    request_id: Optional[str] = None
    detail: str = ""


class _Slot:
    """Runtime state of one in-flight (or re-queued) request."""

    __slots__ = ("req", "prefill_done", "generated", "kv_tokens",
                 "admitted_step", "completed_step", "sheds")

    def __init__(self, req: Request):
        self.req = req
        self.prefill_done = False
        self.generated = 0
        self.kv_tokens = 0
        self.admitted_step: Optional[int] = None
        self.completed_step: Optional[int] = None
        self.sheds = 0

    @property
    def remaining(self) -> int:
        return self.req.max_new - self.generated

    @property
    def finished(self) -> bool:
        return self.prefill_done and self.remaining <= 0


@dataclasses.dataclass
class Phase:
    """A run of steps with constant batch membership.

    ``members`` snapshots each occupant at phase start: request id,
    tenant, the tokens it actively processes per step (prompt length in
    its prefill step, 1 per decode step, 0 while resident-but-stalled),
    and its KV residency in tokens at phase start.
    """

    index: int
    kind: str                 # "prefill" | "decode"
    step0: int                # global step of the first step in the phase
    n_steps: int
    pad_tokens: int           # sequence length the device executes per row
    members: List[dict]       # {"request_id","tenant","tokens","kv0_tokens"}
    kv_bytes_per_token: float

    @property
    def batch(self) -> int:
        return len(self.members)

    def step_tokens(self, i: int) -> float:
        return sum(m["tokens"] for m in self.members)

    def shares(self, i: int) -> List[ActiveShare]:
        """Per-request occupancy of step ``i`` of the phase."""
        bpt = self.kv_bytes_per_token
        out = []
        for m in self.members:
            kv = m["kv0_tokens"]
            if self.kind == "decode":
                kv += i                      # cache grown so far this phase
            out.append(ActiveShare(request_id=m["request_id"],
                                   tenant=m["tenant"], tokens=m["tokens"],
                                   kv_bytes=kv * bpt))
        return out


class ContinuousBatchingScheduler:
    """Step-boundary admission/eviction with the energy-aware policy.

    Pure scheduling: energy enters only through the two injected
    callables — ``j_per_token(batch)`` prices a candidate decode batch and
    ``drift_flag()`` reads the live drift detector — so the policy logic
    is testable without a device.
    """

    def __init__(self, requests: Sequence[Request], policy: EnergyPolicy,
                 *, j_per_token: Callable[[int], float],
                 drift_flag: Callable[[], bool],
                 kv_bytes_per_token: float = 1.0):
        self.policy = policy
        self.j_per_token = j_per_token
        self.drift_flag = drift_flag
        self.kv_bytes_per_token = float(kv_bytes_per_token)
        self.now = 0
        self.events: List[ServeEvent] = []
        self.slots: Dict[str, _Slot] = {}
        self.pending: List[_Slot] = []
        self.active: List[_Slot] = []        # admission order
        self._phase_idx = 0
        seen = set()
        for r in sorted(requests, key=lambda r: (r.arrival_step, r.id)):
            if r.id in seen:
                raise ValueError(f"duplicate request id {r.id!r}")
            seen.add(r.id)
            slot = _Slot(r)
            self.slots[r.id] = slot
            self.pending.append(slot)

    # -- boundary decisions --------------------------------------------------
    def _evict_finished(self) -> None:
        for slot in [s for s in self.active if s.finished]:
            self.active.remove(slot)
            slot.completed_step = self.now
            self.events.append(ServeEvent(
                self.now, "evict", slot.req.id,
                f"completed: {slot.generated} tokens generated"))

    def _shed_if_hot(self) -> None:
        if not (self.policy.shed_on_drift and len(self.active) > 1
                and self.drift_flag()):
            return
        slot = self.active.pop()             # newest admission pays first
        slot.prefill_done = False            # KV dropped; re-prefill later
        slot.kv_tokens = 0
        slot.sheds += 1
        self.pending.insert(0, slot)
        self.events.append(ServeEvent(
            self.now, "shed", slot.req.id,
            "drift flagged: device running hot against its table"))

    def _admit(self) -> None:
        while self.pending and self.pending[0].req.arrival_step <= self.now:
            slot = self.pending[0]
            if len(self.active) >= self.policy.max_batch:
                self.events.append(ServeEvent(
                    self.now, "defer", slot.req.id,
                    f"batch full ({self.policy.max_batch})"))
                return
            if self.active:                  # never starve an idle device
                if self.drift_flag():
                    self.events.append(ServeEvent(
                        self.now, "defer", slot.req.id,
                        "drift flagged: admissions paused"))
                    return
                budget = self.policy.budget_j_per_token
                if budget is not None:
                    jpt = self.j_per_token(len(self.active) + 1)
                    if jpt > budget:
                        self.events.append(ServeEvent(
                            self.now, "defer", slot.req.id,
                            f"predicted {jpt:.3e} J/token > budget "
                            f"{budget:.3e}"))
                        return
            self.pending.pop(0)
            self.active.append(slot)
            slot.admitted_step = self.now
            self.events.append(ServeEvent(
                self.now, "admit", slot.req.id,
                f"batch {len(self.active)}"))

    # -- phase generation ----------------------------------------------------
    def next_phase(self) -> Optional[Phase]:
        """Advance to the next membership-constant run of steps."""
        while True:
            self._evict_finished()
            self._shed_if_hot()
            self._admit()
            if self.active:
                break
            if not self.pending:
                return None
            arrival = self.pending[0].req.arrival_step
            if arrival <= self.now:
                # admission blocked (drift) with an idle device: admit the
                # head unconditionally rather than deadlock
                slot = self.pending.pop(0)
                self.active.append(slot)
                slot.admitted_step = self.now
                self.events.append(ServeEvent(
                    self.now, "admit", slot.req.id, "starvation override"))
                break
            self.events.append(ServeEvent(
                self.now, "idle", None, f"next arrival at step {arrival}"))
            self.now = arrival

        prefilling = [s for s in self.active if not s.prefill_done]
        if prefilling:
            phase = self._prefill_phase(prefilling)
        else:
            phase = self._decode_phase()
        self._phase_idx += 1
        self.now += phase.n_steps
        return phase

    def _prefill_phase(self, prefilling: List[_Slot]) -> Phase:
        pad = max(s.req.prompt_len for s in prefilling)
        members = []
        for s in self.active:
            new = s in prefilling
            members.append({"request_id": s.req.id, "tenant": s.req.tenant,
                            "tokens": float(s.req.prompt_len) if new else 0.0,
                            "kv0_tokens": (s.req.prompt_len if new
                                           else s.kv_tokens)})
        for s in prefilling:                 # prefill emits the first token
            s.prefill_done = True
            s.kv_tokens = s.req.prompt_len
            s.generated += 1
        return Phase(index=self._phase_idx, kind="prefill", step0=self.now,
                     n_steps=1, pad_tokens=pad, members=members,
                     kv_bytes_per_token=self.kv_bytes_per_token)

    def _decode_phase(self) -> Phase:
        n = min(s.remaining for s in self.active)
        arrivals = [s.req.arrival_step for s in self.pending
                    if s.req.arrival_step > self.now]
        if arrivals:
            n = min(n, min(arrivals) - self.now)
        n = max(1, min(n, self.policy.max_phase_steps))
        members = [{"request_id": s.req.id, "tenant": s.req.tenant,
                    "tokens": 1.0, "kv0_tokens": s.kv_tokens}
                   for s in self.active]
        for s in self.active:
            s.generated += n
            s.kv_tokens += n
        return Phase(index=self._phase_idx, kind="decode", step0=self.now,
                     n_steps=n, pad_tokens=1, members=members,
                     kv_bytes_per_token=self.kv_bytes_per_token)


# ---------------------------------------------------------------------------
# The serving-energy engine: scheduler × telemetry × ledger.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PhaseSummary:
    index: int
    kind: str
    step0: int
    n_steps: int
    batch: int
    work_scale: float          # device iterations per logical step
    measured_j: float
    predicted_j: float
    startup_j: float
    freq_mhz: Optional[float] = None       # DVFS point the phase ran at
    power_cap_w: Optional[float] = None


@dataclasses.dataclass
class RequestRow:
    """One finished request: spec, schedule, and its ledger roll-up."""

    request: Request
    totals: RequestTotals
    admitted_step: Optional[int]
    completed_step: Optional[int]
    generated: int
    sheds: int

    @property
    def tokens(self) -> float:
        return self.totals.tokens

    @property
    def measured_j(self) -> float:
        return self.totals.measured_j

    @property
    def predicted_j(self) -> float:
        return self.totals.predicted_j

    @property
    def j_per_token(self) -> float:
        return self.totals.j_per_token


@dataclasses.dataclass
class ServeReport:
    """What one energy-metered serving run produced."""

    name: str
    requests: List[RequestRow]
    billing: BillingReport
    ledger: RequestLedger
    phases: List[PhaseSummary]
    events: List[ServeEvent]
    overhead_j: float            # per-phase startup energy, outside the steps
    mape_pct: float
    recalibrations: List[float]
    health: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def measured_total_j(self) -> float:
        return self.ledger.measured_total_j

    @property
    def predicted_total_j(self) -> float:
        return self.ledger.predicted_total_j

    def snapshot(self) -> dict:
        """JSON-safe report — what ``TelemetryService`` exposes as billing."""
        return {
            "name": self.name,
            "billing": self.billing.snapshot(),
            "requests": {
                r.request.id: {
                    "tenant": r.request.tenant,
                    "arrival_step": r.request.arrival_step,
                    "admitted_step": r.admitted_step,
                    "completed_step": r.completed_step,
                    "prompt_tokens": r.request.prompt_len,
                    "generated_tokens": r.generated,
                    "sheds": r.sheds,
                    "measured_j": r.measured_j,
                    "predicted_j": r.predicted_j,
                    "j_per_token": r.j_per_token,
                } for r in self.requests},
            "steps": len(self.ledger),
            "phases": len(self.phases),
            "measured_total_j": self.measured_total_j,
            "predicted_total_j": self.predicted_total_j,
            "overhead_j": self.overhead_j,
            "mape_pct": self.mape_pct,
            "recalibrations": list(self.recalibrations),
            "health": dict(self.health),
            "events": [{"step": e.step, "event": e.event,
                        "request": e.request_id, "detail": e.detail}
                       for e in self.events],
        }

    def table(self) -> str:
        """The per-request ledger table, formatted for a terminal."""
        hdr = (f"{'request':<10} {'tenant':<10} {'arr':>4} {'prompt':>6} "
               f"{'gen':>4} {'measured J':>12} {'predicted J':>12} "
               f"{'J/token':>10} {'resid%':>7}")
        lines = [hdr, "-" * len(hdr)]
        for r in self.requests:
            resid = (100.0 * (r.predicted_j / r.measured_j - 1.0)
                     if r.measured_j > 0 else 0.0)
            lines.append(
                f"{r.request.id:<10} {r.request.tenant:<10} "
                f"{r.request.arrival_step:>4} {r.request.prompt_len:>6} "
                f"{r.generated:>4} {r.measured_j:>12.4e} "
                f"{r.predicted_j:>12.4e} {r.j_per_token:>10.3e} "
                f"{resid:>+7.1f}")
        lines.append("-" * len(hdr))
        lines.append(
            f"{'total':<10} {'':<10} {'':>4} {'':>6} {'':>4} "
            f"{self.measured_total_j:>12.4e} "
            f"{self.predicted_total_j:>12.4e}")
        return "\n".join(lines)


class EnergyServer:
    """Continuous-batching serving with energy as a scheduling input.

    ``counts_fn(kind, batch, tokens)`` supplies the per-step op counts the
    device executes and the predictor prices — ``launch.serve`` builds it
    from traced model steps; tests and examples can hand in synthetic
    counts.  Everything else is assembled from the model: its device runs
    the phases, its predictor prices them, and one shared
    ``OnlineAttributor`` watches for drift across the whole run.
    """

    def __init__(self, model, counts_fn: CountsFn, *,
                 policy: Optional[EnergyPolicy] = None,
                 ledger_policy: Optional[LedgerPolicy] = None,
                 kv_bytes_per_token: float = 1.0,
                 min_phase_seconds: float = 5.0,
                 name: str = "serve",
                 recalibrate="rescale",
                 detector=None,
                 drift_flag: Optional[Callable[[], bool]] = None,
                 telemetry_chunk: Optional[int] = None,
                 service=None,
                 operating_point=None,
                 governor=None,
                 chaos=None,
                 gap_threshold_s: Optional[float] = None):
        from repro.telemetry.attrib import OnlineAttributor
        from repro.telemetry.sampler import DEFAULT_CHUNK
        self.model = model
        self.counts_fn = counts_fn
        self.policy = policy or EnergyPolicy()
        self.ledger_policy = ledger_policy or LedgerPolicy()
        self.kv_bytes_per_token = float(kv_bytes_per_token)
        self.min_phase_seconds = float(min_phase_seconds)
        self.name = name
        self.telemetry_chunk = (int(telemetry_chunk) if telemetry_chunk
                                else DEFAULT_CHUNK)
        self.service = service
        self.chaos = chaos               # ChaosPlan: phases run faulted
        self.gap_threshold_s = gap_threshold_s
        self.attributor = OnlineAttributor(
            model.predictor, recalibrate=recalibrate, detector=detector)
        self._drift_flag = drift_flag or \
            (lambda: self.attributor.drift.drifting)
        # DVFS: a static pin (operating_point=) or a closed-loop governor
        # proposing a point per phase; the governor inherits this server's
        # drift flag so it pauses exactly when admissions pause
        self.operating_point = model.predictor._as_point(operating_point)
        self.governor = governor
        if governor is not None and governor.drift_flag is None:
            governor.drift_flag = self._drift_flag
        self._counts_cache: Dict[tuple, OpCounts] = {}
        self._jpt_cache: Dict[tuple, float] = {}

    # -- pricing -------------------------------------------------------------
    def _counts(self, kind: str, batch: int, tokens: int) -> OpCounts:
        key = (kind, batch, tokens)
        c = self._counts_cache.get(key)
        if c is None:
            c = self._counts_cache[key] = self.counts_fn(kind, batch, tokens)
        return c

    def _phase_point(self):
        """The operating point the next phase should run at (None: anchor)."""
        if self.governor is not None:
            return self.governor.propose()
        return self.operating_point

    def predict_j_per_token(self, batch: int,
                            operating_point=None) -> float:
        """Predicted J/token of a decode step at this batch size.

        Priced at ``operating_point`` (default: the governor's current
        point / the server's static pin), so budget packing and the
        governor see consistent numbers.  Cached per (batch, point).
        """
        point = operating_point
        if point is None:
            point = (self.governor.current if self.governor is not None
                     else self.operating_point)
        else:
            point = self.model.predictor._as_point(point)
        key = (batch, point)
        jpt = self._jpt_cache.get(key)
        if jpt is None:
            counts = self._counts("decode", batch, 1)
            iters = self.model.device.iters_for_duration(counts, 1.0)
            t_step = 1.0 / max(iters, 1)
            pred = self.model.predict(counts, t_step, operating_point=point)
            jpt = self._jpt_cache[key] = pred.total_j / batch
        return jpt

    # -- the serving run -----------------------------------------------------
    def run(self, requests: Sequence[Request]) -> ServeReport:
        from repro.telemetry.service import StreamSession
        sched = ContinuousBatchingScheduler(
            requests, self.policy, j_per_token=self.predict_j_per_token,
            drift_flag=self._drift_flag,
            kv_bytes_per_token=self.kv_bytes_per_token)
        ledger = RequestLedger(self.ledger_policy)
        phases: List[PhaseSummary] = []
        overhead = 0.0
        health = {"samples": 0, "quarantined": 0, "stale_suspects": 0,
                  "n_gaps": 0, "gap_s": 0.0, "gap_j": 0.0,
                  "low_confidence_windows": 0}

        while (phase := sched.next_phase()) is not None:
            counts = self._counts(phase.kind, phase.batch, phase.pad_tokens)
            point = self._phase_point()      # DVFS switch: phase boundary
            session = StreamSession(
                self.model.predictor, self.model.device, counts,
                name=f"{self.name}/p{phase.index}.{phase.kind}x{phase.batch}",
                attributor=self.attributor,
                min_duration_s=self.min_phase_seconds,
                chunk_size=self.telemetry_chunk,
                operating_point=point,
                chaos=self.chaos,
                gap_threshold_s=self.gap_threshold_s)
            if self.service is not None:
                self.service.register(session)
            for i in range(phase.n_steps):
                session.step(i, work_units=phase.step_tokens(i))
            summary = session.finish()
            group = session.iterations_per_step
            for i, att in enumerate(session.attributions):
                pred = att.prediction
                dyn_frac = (pred.dynamic_j / pred.total_j
                            if pred.total_j > 0 else 1.0)
                ledger.record_step(
                    step=phase.step0 + i, kind=phase.kind,
                    duration_s=att.duration_s, measured_j=att.measured_j,
                    predicted_j=att.predicted_j, dynamic_frac=dyn_frac,
                    active=phase.shares(i), work_scale=group)
            overhead += summary.startup_j
            health["samples"] += summary.n_samples
            health["quarantined"] += summary.quarantined_samples
            health["stale_suspects"] += summary.stale_suspects
            health["n_gaps"] += summary.n_gaps
            health["gap_s"] += summary.gap_s
            health["gap_j"] += summary.gap_j
            health["low_confidence_windows"] += summary.low_confidence_windows
            atts = session.attributions
            if self.governor is not None and point is not None:
                # tokens the phase processed: per-step work × the device
                # iterations folded into each logical step
                tokens = sum(phase.step_tokens(i)
                             for i in range(phase.n_steps)) * group
                self.governor.observe(
                    point, float(sum(a.measured_j for a in atts)),
                    float(sum(a.duration_s for a in atts)), tokens)
            phases.append(PhaseSummary(
                index=phase.index, kind=phase.kind, step0=phase.step0,
                n_steps=phase.n_steps, batch=phase.batch, work_scale=group,
                measured_j=sum(a.measured_j for a in atts),
                predicted_j=sum(a.predicted_j for a in atts),
                startup_j=summary.startup_j,
                freq_mhz=None if point is None else point[0],
                power_cap_w=None if point is None else point[1]))

        totals = ledger.per_request()
        rows = []
        for rid, slot in sched.slots.items():
            tot = totals.get(rid) or RequestTotals(request_id=rid,
                                                   tenant=slot.req.tenant)
            rows.append(RequestRow(
                request=slot.req, totals=tot,
                admitted_step=slot.admitted_step,
                completed_step=slot.completed_step,
                generated=slot.generated, sheds=slot.sheds))
        rows.sort(key=lambda r: (r.request.arrival_step, r.request.id))
        report = ServeReport(
            name=self.name, requests=rows, billing=bill_tenants(ledger),
            ledger=ledger, phases=phases, events=sched.events,
            overhead_j=overhead, mape_pct=self.attributor.mape(),
            recalibrations=list(self.attributor.recalibrations),
            health=health)
        if self.service is not None:
            snap = report.snapshot()
            self.service.register_billing(self.name, lambda: snap)
            if self.governor is not None:
                self.service.register_governor(self.name, self.governor)
        return report


def synthetic_counts_fn(base_units: float = 1e7,
                        interference: float = 0.0) -> CountsFn:
    """A device-only ``counts_fn`` for tests, demos and benchmarks.

    Per-step work scales with ``batch × tokens``; ``interference > 0``
    adds a superlinear per-batch term (cross-request cache interference),
    which makes predicted J/token *rise* with batch size — the regime
    where a J/token budget genuinely caps packing.
    """
    def counts(kind: str, batch: int, tokens: int) -> OpCounts:
        work = batch * tokens * (1.0 + interference * max(batch - 1, 0))
        c = OpCounts()
        c.add("dot.bf16", base_units * work)
        c.mxu_macs_total = c.mxu_macs_aligned = base_units * work
        c.add("add.f32", 0.02 * base_units * work)
        c.add("exp.f32", 0.002 * base_units * work)
        c.boundary_read_bytes = 0.02 * base_units * work
        c.boundary_write_bytes = 0.01 * base_units * work
        c.fused_bytes = 0.01 * base_units * work
        c.max_buffer_bytes = 4e6
        c.dispatch_count = 3
        return c
    return counts
