"""Mamba2 SSD intra-chunk kernel — Pallas TPU.

One program per (batch, chunk, head-block): computes the quadratic
intra-chunk output and the chunk's contribution to the inter-chunk state in
VMEM.  The [L, L] decay matrix (L = 256 chunk) is built once per head in
f32 VREG/VMEM — ~256 KiB, well under VMEM — and both contractions are
MXU-shaped ([L, L] x [L, P] and [L, N]^T x [L, P]).  The linear inter-chunk
recurrence stays in XLA (tiny, bandwidth-trivial).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    """Blocks: x [1,L,1,P], dt [1,L,1], a [1], b/c [1,L,N];
    outputs y [1,L,1,P], st [1,1,P,N]."""
    l, p = x_ref.shape[1], x_ref.shape[3]
    n = b_ref.shape[2]
    x = x_ref[0, :, 0, :].astype(jnp.float32)           # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # [L]
    a = a_ref[0]
    bm = b_ref[0].astype(jnp.float32)                   # [L, N]
    cm = c_ref[0].astype(jnp.float32)                   # [L, N]

    da = dt * a                                         # [L]
    da_cs = jnp.cumsum(da)                              # [L]
    # decay[t, s] = exp(da_cs[t] - da_cs[s]) for s <= t
    diff = da_cs[:, None] - da_cs[None, :]              # [L, L]
    ti = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.where(ti >= si, jnp.exp(diff), 0.0)

    # scores[t, s] = (C[t]·B[s]) * decay[t, s] * dt[s]
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, P]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # chunk state: sum_s exp(da_cs[-1]-da_cs[s]) dt[s] B[s] x[s] -> [P, N]
    decay_end = jnp.exp(da_cs[-1] - da_cs) * dt         # [L]
    st = jax.lax.dot_general(x, bm * decay_end[:, None],
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]
    st_ref[0, 0, :, :] = st


def ssd_chunk(x, dt, a, b_mat, c_mat, *, interpret: bool = False):
    """Intra-chunk SSD over independent chunks.

    x [B,L,H,P], dt [B,L,H], a [H], b_mat/c_mat [B,L,N]
    -> (y [B,L,H,P] f32, states [B,H,P,N] f32)
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    grid = (bsz, h)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, 1, p), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((1, l, 1), lambda b_, h_: (b_, 0, h_)),
            pl.BlockSpec((1,), lambda b_, h_: (h_,)),
            pl.BlockSpec((1, l, n), lambda b_, h_: (b_, 0, 0)),
            pl.BlockSpec((1, l, n), lambda b_, h_: (b_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, 1, p), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, st


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, h0=None, *,
                interpret: bool = False):
    """Drop-in for ``repro.models.ssm.ssd_chunked_ref`` using the kernel for
    the intra-chunk part; inter-chunk recurrence in XLA."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    # Non-divisible tails: zero-pad the sequence to a chunk multiple.  Every
    # padded row carries dt = 0, so it contributes exp(0) = 1 decay and a
    # zero dt-weighted update — the inter-chunk state and all real rows are
    # exact, and the padded y rows are sliced off.  The divisible path takes
    # no pad branch (bitwise-preserving).
    s_out = s
    if s % chunk != 0:
        s = -(-s // chunk) * chunk
        pz = s - s_out
        x = jnp.pad(x, [(0, 0), (0, pz), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pz), (0, 0)])
        b_mat = jnp.pad(b_mat, [(0, 0), (0, pz), (0, 0)])
        c_mat = jnp.pad(c_mat, [(0, 0), (0, pz), (0, 0)])
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    def per_chunk(args):
        xi, di, bi, ci = args
        return ssd_chunk(xi, di, a, bi, ci, interpret=interpret)

    # fold chunks into the batch dim for one big kernel launch
    xf = xc.transpose(0, 1, 2, 3, 4).reshape(bsz * nc, chunk, h, p)
    df = dtc.reshape(bsz * nc, chunk, h)
    bf = bc.reshape(bsz * nc, chunk, n)
    cf = cc.reshape(bsz * nc, chunk, n)
    y_diag, states = ssd_chunk(xf, df, a, bf, cf, interpret=interpret)
    y_diag = y_diag.reshape(bsz, nc, chunk, h, p)
    states = states.reshape(bsz, nc, h, p, n)

    da = dtc.astype(jnp.float32) * a[None, None, None, :]
    da_cs = jnp.cumsum(da, axis=2)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry

    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    last, prev = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(states, 1, 0),
                        jnp.moveaxis(chunk_decay, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                     # [B,NC,H,P,N]
    state_decay = jnp.exp(da_cs)                        # [B,NC,L,H]
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       cc.astype(jnp.float32), prev, state_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    if s != s_out:
        y = y[:, :s_out]
    return y, last
