"""GQA decode attention — Pallas TPU kernel.

One program per (batch, kv-head): the query group [G, D] stays in VREGs,
the KV cache streams through VMEM in [BK, D] blocks, invalid (beyond
``length``) positions are masked.  This is the HBM-bandwidth-bound hot loop
of serving (decode_32k / long_500k shapes): arithmetic intensity ~G MACs
per cache byte, so the tiling goal is purely streaming efficiency.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 1024
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    g, d = q_ref.shape[-2], q_ref.shape[-1]
    s = k_ref.shape[1]
    length = len_ref[0]
    q = q_ref[0, 0, :, :].astype(jnp.float32) / math.sqrt(d)    # [G, D]

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.dslice(i * block_k, block_k), 0, :]     # [BK, D]
        v = v_ref[0, pl.dslice(i * block_k, block_k), 0, :]
        scores = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                 # [G, BK]
        k_pos = (i * block_k
                 + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1))
        scores = jnp.where(k_pos < length, scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((g, d), jnp.float32)
    m0 = jnp.full((g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g, 1), jnp.float32)
    n_k = s // block_k
    acc, m, l = jax.lax.fori_loop(0, n_k, body, (acc0, m0, l0))
    o_ref[0, 0, :, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False):
    """q [B,H,D]; caches [B,S,KV,D]; lengths [B] -> [B,H,D]."""
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    block_k = min(block_k, s)
    # Non-divisible tails: zero-pad the cache to a block multiple.  Padded
    # positions sit at k_pos >= s >= length, so the existing validity mask
    # already excludes them; the divisible path is untouched (bitwise).
    if s % block_k != 0:
        s_pad = -(-s // block_k) * block_k
        widths = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
        s = s_pad
    qg = q.reshape(b, kvh, g, d)
    grid = (b, kvh)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, kv: (b_,)),
            pl.BlockSpec((1, 1, g, d), lambda b_, kv: (b_, kv, 0, 0)),
            pl.BlockSpec((1, s, 1, d), lambda b_, kv: (b_, 0, kv, 0)),
            pl.BlockSpec((1, s, 1, d), lambda b_, kv: (b_, 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, kv: (b_, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
