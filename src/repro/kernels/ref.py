"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None):
    """q,k,v [B,S,H,D] (same kv heads) -> [B,S,H,D]; plain softmax."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """GQA decode: q [B,H,D]; caches [B,S,KV,D]; lengths [B] valid lens."""
    b, s, kvh, d = k_cache.shape
    h = q.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    valid = jnp.arange(s)[None, :] < lengths[:, None]          # [B,S]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache)
    return out.reshape(b, h, d)


def ssd_chunk_ref(x, dt, a, b_mat, c_mat):
    """Intra-chunk SSD (one chunk): x [B,L,H,P], dt [B,L,H], a [H],
    b_mat/c_mat [B,L,N] -> (y [B,L,H,P], state [B,H,P,N]).

    y[t]     = sum_{s<=t} C[t]·B[s] exp(sum_{r in (s,t]} dt[r]a) dt[s] x[s]
    state    = sum_s exp(sum_{r in (s,L)} dt[r]a) dt[s] B[s] x[s]
    """
    bsz, l, h, p = x.shape
    da = dt * a[None, None, :]                          # [B,L,H]
    da_cs = jnp.cumsum(da, axis=1)
    diff = da_cs[:, :, None, :] - da_cs[:, None, :, :]  # [B,T,S,H]
    idx = jnp.arange(l)
    mask = idx[:, None] >= idx[None, :]
    decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
    y = jnp.einsum("btn,bsn,btsh,bsh,bshp->bthp",
                   c_mat, b_mat, decay, dt, x)
    decay_end = jnp.exp(da_cs[:, -1:, :] - da_cs)       # [B,L,H]
    state = jnp.einsum("bsn,bsh,bsh,bshp->bhpn",
                       b_mat, decay_end, dt, x)
    return y, state
