"""Flash attention — Pallas TPU kernel.

Blockwise online-softmax attention: the [Sq, Sk] score matrix never
materializes in HBM (the 224 GiB/device buffer of the naive path).  Tiling
is TPU-native: query blocks of 512 rows live in VMEM, K/V stream through
VMEM blocks of 512, MXU-aligned [BQ, D] x [D, BK] partial products, with
running (max, sum) rescaling in f32 VMEM scratch.

Supports causal masking, sliding windows (gemma2/danube) and logit softcap
(gemma2).  Same-kv-head layout: GQA callers broadcast kv heads in the ops
wrapper (cheap: D is small) or pass grouped heads.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float,
                  causal: bool, window: Optional[int],
                  softcap: Optional[float], block_k: int, q_offset_blocks: int,
                  kv_len: Optional[int] = None):
    """One (batch, head, q-block) program: stream K/V blocks."""
    bq, d = q_ref.shape[1], q_ref.shape[3]
    s = k_ref.shape[1]
    q_idx = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * sm_scale       # [BQ, D]
    q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_k = s // block_k

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.dslice(i * block_k, block_k), 0, :]     # [BK, D]
        v = v_ref[0, pl.dslice(i * block_k, block_k), 0, :]
        scores = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                 # [BQ, BK]
        if softcap is not None:
            scores = jnp.tanh(scores / softcap) * softcap
        k_pos = (i * block_k
                 + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1))
        mask = jnp.ones((bq, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        if kv_len is not None:          # padded tail: positions >= kv_len
            mask &= k_pos < kv_len
        scores = jnp.where(mask, scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)         # [BQ,1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    # causal early exit: only K blocks that intersect the mask
    if causal:
        upper = jnp.minimum((q_idx + 1) * bq + block_k - 1, s) // block_k
    else:
        upper = n_k
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[0, :, 0, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q,k,v [B,S,H,D] (kv heads already expanded to H) -> [B,S,H,D]."""
    b, s, h, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    # Non-divisible tails: pad S up to a common block multiple and mask the
    # padded kv positions in-kernel.  The divisible path takes no pad branch
    # and builds the exact same jaxpr as before (bitwise-preserving).
    tile = math.lcm(block_q, block_k)
    s_pad = s if s % tile == 0 else -(-s // tile) * tile
    kv_len = None
    if s_pad != s:
        widths = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        kv_len = s
    grid = (b, h, s_pad // block_q)
    kernel = functools.partial(
        _flash_kernel, sm_scale=1.0 / math.sqrt(d), causal=causal,
        window=window, softcap=softcap, block_k=block_k, q_offset_blocks=0,
        kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, s_pad, 1, d), lambda b_, h_, i: (b_, 0, h_, 0)),
            pl.BlockSpec((1, s_pad, 1, d), lambda b_, h_, i: (b_, 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h_, i: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    if s_pad != s:
        out = out[:, :s]
    return out
