"""J/op autotuner: block-size search that makes the kernels cheaper.

The class table prices op classes; this stage measures *whole kernel
launches* per candidate block configuration on the target device — the
micro-calibration analogue of ``core.calibrate``, reusing its protocol
piece by piece: steady-state runs sized by ``iters_for_duration``,
deterministic per-(spec, repeat) sensor-noise substreams
(``calib:{spec_id}:r{r}``), medians over repeats, and optional atomic
per-spec record persistence for resumable campaigns.

Search is grid + successive halving: every candidate (block config ×
ref-vs-pallas variant) is measured under a short protocol, the better half
advances to the full protocol, and the winner is the feasible (latency ≤
ceiling) entry with minimum measured J/op.  Winners persist in the
``KernelEnergyTable`` tier of the ``TableStore`` and are read back by the
``block_config="auto"`` path of ``repro.kernels.ops`` — which falls back
to the shipped defaults bitwise when no entry exists.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import pathlib
import tempfile
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import measure as measure_mod
from repro.core.kernel_table import KernelEnergyTable, KernelEntry
from repro.core.opcount import OpCounts, count_fn
from repro.hw.device import Program, SimDevice

RECORD_VERSION = 1

ROUND_DURATION_S = (6.0, 24.0)     # successive-halving protocol per round
ROUND_REPEATS = (1, 3)


# ---------------------------------------------------------------------------
# Search spaces: candidate grids + canonical benchmark shapes.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """One kernel's candidate grid and measurement recipe."""

    kernel: str
    configs: Tuple[Tuple[int, ...], ...]   # pallas candidates (defaults incl.)
    default: Tuple[int, ...]
    shape: Dict[str, int]                  # canonical benchmark shape
    counts: Callable[..., OpCounts]        # (variant, config, **shape)
    ops_per_call: Callable[..., float]     # (**shape) — config-independent


def _flash_args(b, s, h, d, **_):
    import jax.numpy as jnp
    z = jnp.zeros((b, s, h, d), jnp.float32)
    return z, z, z


def _flash_counts(variant: str, config, **shape) -> OpCounts:
    from repro.kernels import flash_attention as _fa
    from repro.kernels import ref
    if variant == "ref":
        fn = functools.partial(ref.flash_attention_ref, causal=True)
    else:
        bq, bk = config
        fn = functools.partial(_fa.flash_attention, causal=True,
                               block_q=bq, block_k=bk, interpret=True)
    return count_fn(fn, *_flash_args(**shape))


def _flash_ops(b, s, h, d, **_) -> float:
    # two [S,S]x[S,D] contractions, 2 flops per MAC
    return float(4 * b * h * s * s * d)


def _decode_args(b, s, h, d, kvh, **_):
    import jax.numpy as jnp
    q = jnp.zeros((b, h, d), jnp.float32)
    kc = jnp.zeros((b, s, kvh, d), jnp.float32)
    lengths = jnp.full((b,), s, jnp.int32)
    return q, kc, kc, lengths


def _decode_counts(variant: str, config, **shape) -> OpCounts:
    from repro.kernels import decode_attention as _dec
    from repro.kernels import ref
    if variant == "ref":
        fn = ref.decode_attention_ref
    else:
        (bk,) = config
        fn = functools.partial(_dec.decode_attention, block_k=bk,
                               interpret=True)
    return count_fn(fn, *_decode_args(**shape))


def _decode_ops(b, s, h, d, **_) -> float:
    return float(4 * b * h * s * d)


def _ssd_args(b, s, h, p, n, **_):
    import jax.numpy as jnp
    x = jnp.zeros((b, s, h, p), jnp.float32)
    dt = jnp.full((b, s, h), 0.1, jnp.float32)
    a = -jnp.ones((h,), jnp.float32)
    bm = jnp.zeros((b, s, n), jnp.float32)
    return x, dt, a, bm, bm


def _ssd_counts(variant: str, config, **shape) -> OpCounts:
    from repro.kernels import ssd_scan as _ssd
    if variant == "ref":
        from repro.models.ssm import ssd_chunked_ref
        fn = functools.partial(ssd_chunked_ref,
                               chunk=SEARCH_SPACES["ssd_chunked"].default[0])
    else:
        (chunk,) = config
        fn = functools.partial(_ssd.ssd_chunked, chunk=chunk, interpret=True)
    return count_fn(fn, *_ssd_args(**shape))


def _ssd_ops(b, s, h, p, n, **_) -> float:
    # state update + output contraction per timestep
    return float(4 * b * s * h * p * n)


SEARCH_SPACES: Dict[str, SearchSpace] = {
    "flash_attention": SearchSpace(
        kernel="flash_attention",
        configs=tuple((bq, bk) for bq in (128, 256, 512)
                      for bk in (128, 256, 512)),
        default=(512, 512),
        shape={"b": 1, "s": 1024, "h": 4, "d": 64},
        counts=_flash_counts, ops_per_call=_flash_ops),
    "decode_attention": SearchSpace(
        kernel="decode_attention",
        configs=((128,), (256,), (512,), (1024,)),
        default=(1024,),
        shape={"b": 4, "s": 4096, "h": 4, "d": 64, "kvh": 1},
        counts=_decode_counts, ops_per_call=_decode_ops),
    "ssd_chunked": SearchSpace(
        kernel="ssd_chunked",
        configs=((64,), (128,), (256,)),
        default=(256,),
        shape={"b": 2, "s": 1024, "h": 4, "p": 64, "n": 64},
        counts=_ssd_counts, ops_per_call=_ssd_ops),
}


def point_tag(operating_point, device=None) -> Optional[str]:
    """Canonical tag for an operating point (None at nominal)."""
    if operating_point is None:
        return None
    if isinstance(operating_point, str):
        return operating_point
    tag = getattr(operating_point, "tag", None)
    if tag:
        return tag
    from repro.dvfs.interp import as_point
    f, c = as_point(operating_point)
    if c is None and device is not None:
        c = float(device.chip.tdp_watts)
    return f"f{f:g}c{c:g}" if c is not None else f"f{f:g}"


# ---------------------------------------------------------------------------
# Measurement: calibrate-style records, one per (candidate, protocol).
# ---------------------------------------------------------------------------
def _spec_id(kernel: str, variant: str, config, duration_s: float,
             tag: Optional[str]) -> str:
    cfg = "x".join(str(c) for c in config) if config else "ref"
    suffix = f"@{tag}" if tag else ""
    return f"kern:{kernel}:{variant}:{cfg}:d{duration_s:g}{suffix}"


def _record_path(run_dir, spec_id: str) -> pathlib.Path:
    return (pathlib.Path(run_dir) / "records"
            / (spec_id.replace(":", "__") + ".json"))


def _load_record(run_dir, spec_id: str) -> Optional[Dict[str, Any]]:
    if run_dir is None:
        return None
    path = _record_path(run_dir, spec_id)
    if not path.exists():
        return None
    rec = json.loads(path.read_text())
    return rec if rec.get("record_version") == RECORD_VERSION else None


def _save_record(run_dir, rec: Dict[str, Any]) -> None:
    if run_dir is None:
        return
    path = _record_path(run_dir, rec["spec_id"])
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(rec, f, indent=1)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def measure_candidate(device: SimDevice, kernel: str, variant: str,
                      config, counts: OpCounts, ops_per_call: float, *,
                      duration_s: float, repeats: int,
                      tag: Optional[str] = None,
                      run_dir=None) -> KernelEntry:
    """Measure one launch config to steady state; median over repeats.

    Sensor noise draws from the same deterministic substreams the
    calibration pipeline uses (``calib:{spec_id}:r{r}``), so records are
    order-independent and an interrupted campaign resumes bit-identically.
    """
    spec_id = _spec_id(kernel, variant, config, duration_s, tag)
    rec = _load_record(run_dir, spec_id)
    if rec is None:
        iters = device.iters_for_duration(counts, duration_s)
        reps = []
        for r in range(repeats):
            run = device.run(Program(spec_id, counts, iters=iters),
                             noise_key=f"calib:{spec_id}:r{r}")
            reps.append({"total_j": measure_mod.total_energy(run),
                         "duration_s": float(run.duration_s),
                         "iters": int(run.iters)})
        rec = {"record_version": RECORD_VERSION, "spec_id": spec_id,
               "kernel": kernel, "variant": variant, "config": list(config),
               "repeats": reps}
        _save_record(run_dir, rec)
    reps = rec["repeats"]
    med = int(np.argsort([r["total_j"] for r in reps])[len(reps) // 2])
    rep = reps[med]
    iters = max(int(rep["iters"]), 1)
    j_call = rep["total_j"] / iters
    return KernelEntry(
        kernel=kernel, variant=variant, config=tuple(config), point=tag,
        j_per_op=j_call / max(ops_per_call, 1.0), j_per_call=j_call,
        latency_s=rep["duration_s"] / iters, ops_per_call=ops_per_call,
        energy_j=rep["total_j"], duration_s=rep["duration_s"], iters=iters,
        spec_id=spec_id)


# ---------------------------------------------------------------------------
# The search.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class KernelTuneResult:
    """What one tuning campaign found."""

    kernel: str
    winner: KernelEntry
    default: KernelEntry               # the shipped default, same protocol
    entries: List[KernelEntry]         # every final-round measurement
    rounds: List[List[str]]            # spec ids surviving each round

    @property
    def improvement(self) -> float:
        """Fractional J/op saving of the winner over the shipped default."""
        return 1.0 - self.winner.j_per_op / max(self.default.j_per_op, 1e-300)


def _rank_key(entry: KernelEntry, ceiling: Optional[float]):
    infeasible = ceiling is not None and entry.latency_s > ceiling
    return (infeasible, entry.j_per_op, entry.latency_s)


def tune(kernel: str, device: SimDevice, *,
         operating_point=None,
         latency_ceiling_s: Optional[float] = None,
         shape: Optional[Dict[str, int]] = None,
         configs: Optional[Sequence[Tuple[int, ...]]] = None,
         include_ref: bool = True,
         exhaustive: bool = False,
         durations: Sequence[float] = ROUND_DURATION_S,
         repeats: Sequence[int] = ROUND_REPEATS,
         run_dir=None) -> KernelTuneResult:
    """Grid / successive-halving search minimizing measured J/op.

    Every round re-measures the surviving candidates under a longer
    protocol; ``exhaustive=True`` keeps all candidates through every round
    (the oracle the halving path is validated against).  The shipped
    default config is pinned into the final round regardless of earlier
    ranking, so ``winner.j_per_op <= default.j_per_op`` holds by
    construction under the shared protocol.
    """
    if kernel not in SEARCH_SPACES:
        raise KeyError(f"unknown kernel {kernel!r}: "
                       f"expected one of {sorted(SEARCH_SPACES)}")
    space = SEARCH_SPACES[kernel]
    shape = dict(space.shape, **(shape or {}))
    grid = [tuple(c) for c in (configs if configs is not None
                               else space.configs)]
    if tuple(space.default) not in grid:
        grid.append(tuple(space.default))
    cands: List[Tuple[str, Tuple[int, ...]]] = [("pallas", c) for c in grid]
    if include_ref:
        cands.append(("ref", ()))
    tag = point_tag(operating_point, device)
    ops = space.ops_per_call(**shape)
    counts = {c: space.counts(c[0], c[1], **shape) for c in cands}

    restore = None
    if operating_point is not None:
        from repro.dvfs.interp import as_point
        f, cap = as_point(operating_point)
        restore = device.operating_point
        device.set_operating_point(f, power_cap_w=cap)
    try:
        rounds: List[List[str]] = []
        entries: Dict[Tuple[str, Tuple[int, ...]], KernelEntry] = {}
        alive = list(cands)
        for i, (dur, rep) in enumerate(zip(durations, repeats)):
            final = i == len(durations) - 1
            if final and ("pallas", tuple(space.default)) not in alive:
                alive.append(("pallas", tuple(space.default)))
            measured = {
                c: measure_candidate(device, kernel, c[0], c[1], counts[c],
                                     ops, duration_s=float(dur),
                                     repeats=int(rep), tag=tag,
                                     run_dir=run_dir)
                for c in alive}
            ranked = sorted(alive,
                            key=lambda c: _rank_key(measured[c],
                                                    latency_ceiling_s))
            if final:
                entries = measured
            elif not exhaustive:
                alive = ranked[:max(-(-len(ranked) // 2), 2)]
            rounds.append([measured[c].spec_id for c in ranked])
    finally:
        if restore is not None:
            device.set_operating_point(restore)

    default = entries[("pallas", tuple(space.default))]
    feasible = [e for e in entries.values()
                if latency_ceiling_s is None
                or e.latency_s <= latency_ceiling_s]
    pool = feasible or [default]
    winner = min(pool, key=lambda e: (e.j_per_op, e.latency_s))
    return KernelTuneResult(kernel=kernel, winner=winner, default=default,
                            entries=sorted(entries.values(),
                                           key=lambda e: e.j_per_op),
                            rounds=rounds)


# ---------------------------------------------------------------------------
# Persistence + the "auto" lookup used by repro.kernels.ops.
# ---------------------------------------------------------------------------
_ACTIVE: Optional[KernelEnergyTable] = None


def set_active(ktable: Optional[KernelEnergyTable]) -> None:
    """Install the process-level table ``block_config="auto"`` consults."""
    global _ACTIVE
    _ACTIVE = ktable


def get_active() -> Optional[KernelEnergyTable]:
    return _ACTIVE


def load(system: str, store=None) -> Optional[KernelEnergyTable]:
    """Load a system's persisted kernel table and make it active."""
    if store is None:
        from repro.core.store import default_store
        store = default_store()
    ktable = store.get_kernel_table(system)
    if ktable is not None:
        set_active(ktable)
    return ktable


def best_config(kernel: str, operating_point=None,
                latency_ceiling_s: Optional[float] = None
                ) -> Optional[Tuple[int, ...]]:
    """The active table's best *pallas* config, or None (→ defaults).

    This is the whole contract behind ``block_config="auto"``: with no
    active table, no entry for the kernel, or a ref-variant-only table,
    the caller falls back to the shipped defaults — building the exact
    same jaxpr as an untuned call (bitwise).
    """
    if _ACTIVE is None:
        return None
    entry = _ACTIVE.best(kernel, point=point_tag(operating_point),
                         latency_ceiling_s=latency_ceiling_s,
                         variant="pallas")
    return tuple(entry.config) if entry is not None else None


def tune_and_store(kernel: str, device: SimDevice, system: str, *,
                   store=None, **kwargs) -> KernelTuneResult:
    """Tune, merge the measurements into the system's persisted table,
    publish atomically, and activate the result for ``"auto"`` callers."""
    if store is None:
        from repro.core.store import default_store
        store = default_store()
    result = tune(kernel, device, **kwargs)
    ktable = store.get_kernel_table(system) or KernelEnergyTable(system)
    for e in result.entries:
        ktable.put(e)
    store.put_kernel_table(ktable)
    set_active(ktable)
    return result
