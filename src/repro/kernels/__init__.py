"""Pallas TPU kernels for the framework's compute hot spots.

flash_attention   — blockwise online-softmax attention (train/prefill)
decode_attention  — streaming GQA decode over the KV cache
ssd_scan          — Mamba2 SSD intra-chunk kernel

Each has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in ``ops.py``
(interpret-mode on CPU, compiled on TPU).  The paper itself contributes
measurement infrastructure, not kernels — these serve the workload side.
"""
