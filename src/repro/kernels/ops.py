"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to auto: compiled on TPU, interpret-mode (Python
execution of the kernel body) everywhere else — which is how the kernels
are validated in this CPU container.  ``make_attn_fn`` adapts flash
attention to the model layer's ``attn_fn`` hook (GQA broadcast included).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, lengths, *,
                     interpret: Optional[bool] = None):
    return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int = 256, h0=None, *,
                interpret: Optional[bool] = None):
    return _ssd.ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h0,
                            interpret=_auto_interpret(interpret))


def make_attn_fn(interpret: Optional[bool] = None):
    """Adapter for ``ModelConfig.attention_impl == 'pallas'``: the model
    layer calls attn_fn(q, k, v, cfg) on the full-sequence path."""
    def attn_fn(q, k, v, cfg):
        h, kvh = q.shape[2], k.shape[2]
        if kvh != h:
            k = jnp.repeat(k, h // kvh, axis=2)
            v = jnp.repeat(v, h // kvh, axis=2)
        window = cfg.sliding_window
        return flash_attention(q, k, v, causal=True, window=window,
                               softcap=cfg.attn_logit_softcap,
                               interpret=interpret)
    return attn_fn
