"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to auto: compiled on TPU, interpret-mode (Python
execution of the kernel body) everywhere else — which is how the kernels
are validated in this CPU container.  ``make_attn_fn`` adapts flash
attention to the model layer's ``attn_fn`` hook (GQA broadcast included).

Block configuration is resolved *outside* the jitted inner functions, so
each distinct config compiles once and the default path builds the exact
same jaxpr as an explicit-default call:

    block_config=None     — kernel defaults (bitwise-identical to before)
    block_config="auto"   — the autotuner's persisted winner for this
                            kernel (``repro.kernels.autotune``); falls back
                            to the defaults bitwise when no entry exists
    block_config=(...)    — explicit block sizes, e.g. ``(256, 512)`` for
                            flash ``(block_q, block_k)``
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _resolve_blocks(kernel: str, block_config, defaults: Tuple[int, ...],
                    operating_point=None) -> Tuple[int, ...]:
    """Map a ``block_config`` argument to concrete block sizes."""
    if block_config is None:
        return defaults
    if isinstance(block_config, str):
        if block_config != "auto":
            raise ValueError(f"unknown block_config {block_config!r}: "
                             "expected None, 'auto', or a tuple of ints")
        from repro.kernels import autotune     # lazy: avoid import cycle
        cfg = autotune.best_config(kernel, operating_point=operating_point)
        return tuple(cfg) if cfg else defaults
    if isinstance(block_config, int):
        return (block_config,)
    cfg = tuple(int(c) for c in block_config)
    if len(cfg) != len(defaults):
        raise ValueError(f"{kernel} block_config needs {len(defaults)} "
                         f"entries, got {cfg!r}")
    return cfg


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret", "block_q",
                                             "block_k"))
def _flash_jit(q, k, v, *, causal: bool, window: Optional[int],
               softcap: Optional[float], interpret: bool,
               block_q: int, block_k: int):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None,
                    block_config=None, operating_point=None):
    block_q, block_k = _resolve_blocks(
        "flash_attention", block_config,
        (_fa.DEFAULT_BLOCK_Q, _fa.DEFAULT_BLOCK_K), operating_point)
    return _flash_jit(q, k, v, causal=causal, window=window, softcap=softcap,
                      interpret=_auto_interpret(interpret),
                      block_q=block_q, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("interpret", "block_k"))
def _decode_jit(q, k_cache, v_cache, lengths, *, interpret: bool,
                block_k: int):
    return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                 block_k=block_k, interpret=interpret)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     interpret: Optional[bool] = None,
                     block_config=None, operating_point=None):
    (block_k,) = _resolve_blocks("decode_attention", block_config,
                                 (_dec.DEFAULT_BLOCK_K,), operating_point)
    return _decode_jit(q, k_cache, v_cache, lengths,
                       interpret=_auto_interpret(interpret), block_k=block_k)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, dt, a, b_mat, c_mat, h0, *, chunk: int, interpret: bool):
    return _ssd.ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h0,
                            interpret=interpret)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int = 256, h0=None, *,
                interpret: Optional[bool] = None,
                block_config=None, operating_point=None):
    if block_config is not None:
        (chunk,) = _resolve_blocks("ssd_chunked", block_config, (chunk,),
                                   operating_point)
    return _ssd_jit(x, dt, a, b_mat, c_mat, h0, chunk=chunk,
                    interpret=_auto_interpret(interpret))


def make_attn_fn(interpret: Optional[bool] = None, block_config=None):
    """Adapter for ``ModelConfig.attention_impl == 'pallas'``: the model
    layer calls attn_fn(q, k, v, cfg) on the full-sequence path."""
    def attn_fn(q, k, v, cfg):
        h, kvh = q.shape[2], k.shape[2]
        if kvh != h:
            k = jnp.repeat(k, h // kvh, axis=2)
            v = jnp.repeat(v, h // kvh, axis=2)
        window = cfg.sliding_window
        return flash_attention(q, k, v, causal=True, window=window,
                               softcap=cfg.attn_logit_softcap,
                               interpret=interpret, block_config=block_config)
    return attn_fn
