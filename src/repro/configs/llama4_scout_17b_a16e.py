"""llama4-scout-17b-a16e — MoE w/ early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192, MoE 16 experts top-1 + shared
expert, vocab=202048.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, n_experts=16, moe_top_k=1, moe_dense_residual=True,
    rope_theta=500000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=128, vocab=256, n_experts=4, moe_top_k=1,
    remat=False)
