"""arctic-480b — dense-residual MoE [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) dense d_ff=4864, MoE 128 experts top-2
(expert d_ff=4864) with a dense residual MLP, vocab=32000.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, n_experts=128, moe_top_k=2, moe_dense_residual=True,
    optimizer_dtype="bfloat16",   # 480B params: bf16 m/v to fit HBM
)

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-480b-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=128, vocab=256, n_experts=8, moe_top_k=2,
    remat=False)
