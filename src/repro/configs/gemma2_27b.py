"""gemma2-27b — local/global alternating attention + logit softcaps
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; head_dim=128,
attn softcap 50, final softcap 30, local window 4096 on even layers.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256000, head_dim=128, local_global=True, local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    mlp_act="gelu", post_norms=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-27b-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=256, head_dim=16, local_window=16,
    remat=False)
