"""qwen2-vl-7b — M-RoPE VLM backbone [arXiv:2409.12191].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064; vision frontend is
a stub (``input_specs`` supplies patch embeddings + 3D position ids).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, qkv_bias=True, mrope=True, n_vision_tokens=1024,
    rope_theta=1000000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-vl-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=256, n_vision_tokens=8, remat=False)
