"""mamba2-2.7b — SSD state-space model, attention-free [arXiv:2405.21060].

64L d_model=2560 vocab=50280; ssm_state=128, head_dim=64, expand=2.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8, remat=False)
