"""Model/run configuration system.

One frozen ``ModelConfig`` per architecture (exact published dims in
``repro.configs.<arch>``), plus the assigned input-shape set and
``input_specs()`` builders used by smoke tests, the dry-run and the
launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # attention features
    qkv_bias: bool = False
    sliding_window: Optional[int] = None        # SWA width (danube)
    local_global: bool = False                  # gemma2 alternation
    local_window: int = 4096
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    mlp_act: str = "silu"                       # silu | gelu
    mlp_gated: bool = True
    post_norms: bool = False                    # gemma2 post-block norms

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_dense_residual: bool = False            # arctic: dense MLP + MoE
    moe_dispatch: str = "scatter"               # scatter | index (§Perf)

    # MLA (minicpm3)
    mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    rope_head_dim: int = 32
    nope_head_dim: int = 64

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    encoder_layers: int = 0
    n_audio_frames: int = 1500

    # VLM (qwen2-vl)
    mrope: bool = False
    n_vision_tokens: int = 0

    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | save_collectives (§Perf A6/B4)
    seq_parallel: bool = False   # residual sharded on (model, seq) — §Perf
    attention_impl: str = "xla"                 # xla | pallas
    optimizer_dtype: str = "float32"            # adam m/v dtype

    # ---------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_decode(self) -> bool:
        return True    # all assigned archs have a decoder

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility: sub-quadratic state (SSM/hybrid) or
        windowed/local attention.  Pure full-attention archs skip it."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.local_global)

    def param_count(self) -> float:
        """Analytic parameter count (for 6·N·D MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0.0
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            per_layer = (d * (2 * d_in + 2 * self.ssm_state + nheads)
                         + self.ssm_conv * (d_in + 2 * self.ssm_state)
                         + d_in * d + 2 * nheads)
        else:
            if self.mla:
                attn = (d * self.q_lora_rank
                        + self.q_lora_rank * self.n_heads
                        * (self.nope_head_dim + self.rope_head_dim)
                        + d * (self.kv_lora_rank + self.rope_head_dim)
                        + self.kv_lora_rank * self.n_heads
                        * (self.nope_head_dim + self.nope_head_dim)
                        + self.n_heads * self.nope_head_dim * d)
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            if self.n_experts:
                mlp = self.n_experts * 3 * d * ff
                if self.moe_dense_residual:
                    mlp += 3 * d * ff
            else:
                mlp = 3 * d * ff
            per_layer = attn + mlp + 2 * d
        n = emb + self.n_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            n += (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                  + self.n_heads * hd * d + 3 * d * ff)
        if self.family == "encdec":
            # encoder layers + cross attention in decoder
            enc = self.encoder_layers * (4 * d * d + 3 * d * ff + 2 * d)
            cross = self.n_layers * 4 * d * d
            n += enc + cross
        return float(n)

    def active_param_count(self) -> float:
        """Active params (MoE: top-k experts only) for 6·N_active·D."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        total = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * ff
        moe_active = self.n_layers * self.moe_top_k * 3 * d * ff
        return float(total - moe_all + moe_active)


# ---------------------------------------------------------------------------
# Assigned input shapes.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — see DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention architecture: a 512k-token "
                       "decode KV cache with no windowing/state is skipped "
                       "per assignment")
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input ShapeDtypeStructs for an assigned shape (dry-run entry)."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        # decode lowers serve_step: one new token against a seq_len cache
        return token_inputs(cfg, ShapeSpec(shape.name, 1, shape.global_batch,
                                           "decode"), for_train=False)
    return token_inputs(cfg, shape, for_train=shape.kind == "train")


def token_inputs(cfg: ModelConfig, shape: ShapeSpec,
                 for_train: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "encdec":
        # frontend stub: precomputed audio-frame embeddings
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), cfg.activation_dtype)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    elif cfg.family == "vlm":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), cfg.activation_dtype)
        specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    if for_train:
        specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs
