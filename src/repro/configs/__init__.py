"""Architecture registry: ``get_config(arch_id)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, input_specs  # noqa: F401
from repro.configs import (arctic_480b, gemma2_27b, h2o_danube3_4b,
                           llama4_scout_17b_a16e, mamba2_2_7b, minicpm3_4b,
                           qwen2_0_5b, qwen2_vl_7b, whisper_small, zamba2_2_7b)

_MODULES = {
    "whisper-small": whisper_small,
    "arctic-480b": arctic_480b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "gemma2-27b": gemma2_27b,
    "h2o-danube-3-4b": h2o_danube3_4b,
    "minicpm3-4b": minicpm3_4b,
    "qwen2-0.5b": qwen2_0_5b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "mamba2-2.7b": mamba2_2_7b,
    "zamba2-2.7b": zamba2_2_7b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
