"""minicpm3-4b — multi-head latent attention (MLA) [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448; q_lora 768, kv_lora 256,
rope_head_dim 32, nope_head_dim 64.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, mla=True, q_lora_rank=768, kv_lora_rank=256,
    rope_head_dim=32, nope_head_dim=64, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="minicpm3-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, q_lora_rank=32, kv_lora_rank=16,
    rope_head_dim=8, nope_head_dim=16, remat=False)
