"""zamba2-2.7b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

54L d_model=2560 32H (MHA kv=32) d_ff=10240 vocab=32000; ssm_state=64;
one shared attention block applied every 6 layers.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=8, shared_attn_every=2, remat=False)
