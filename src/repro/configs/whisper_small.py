"""whisper-small — enc-dec audio backbone [arXiv:2212.04356].

12L d_model=768 12H (kv=12, i.e. MHA) d_ff=3072 vocab=51865; conv frontend
is a stub (``input_specs`` supplies precomputed frame embeddings).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, encoder_layers=12, n_audio_frames=1500,
    mlp_act="gelu", mlp_gated=False, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-small-smoke", n_layers=2, encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    n_audio_frames=32, remat=False)
