"""Checkpointing: atomic, resumable, keep-last-k.

Arrays are gathered to host, written as one .npz per checkpoint plus a JSON
manifest, staged in a temp directory and atomically renamed — a crash never
leaves a half-written checkpoint visible.  ``latest_step``/``restore`` give
the restart path used by the launcher after simulated node failures.
(Production deployments would swap the .npz backend for tensorstore/OCDBT;
the commit protocol is the same.)
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]
    return named, treedef


def save(ckpt_dir, step: int, tree, *, keep: int = 3,
         async_: bool = False) -> pathlib.Path:
    """Write checkpoint for ``step``; returns the final path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    named, _ = _flatten(tree)
    host = {}
    dtypes = {}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        dtypes[name] = str(arr.dtype)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: exact f32 up-cast
            arr = arr.astype(np.float32)
        host[name] = arr

    def _write():
        tmp = pathlib.Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
        try:
            np.savez(tmp / _ARRAYS, **{k: v for k, v in host.items()})
            manifest = {"step": step,
                        "names": list(host.keys()),
                        "dtypes": dtypes,
                        "shapes": {k: list(v.shape) for k, v in host.items()}}
            (tmp / _MANIFEST).write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)           # atomic commit
        finally:
            if tmp.exists():
                shutil.rmtree(tmp, ignore_errors=True)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        t.join()     # bounded async: host copy already snapshotted above
    else:
        _write()
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s:010d}", ignore_errors=True)


def all_steps(ckpt_dir) -> List[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and (p / _MANIFEST).exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, tree_like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like`` (values ignored)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:010d}"
    data = np.load(path / _ARRAYS)
    manifest = json.loads((path / _MANIFEST).read_text())
    named, treedef = _flatten(tree_like)
    leaves = []
    for (name, like) in named:
        arr = jax.numpy.asarray(data[name],
                                manifest["dtypes"].get(name) or None)
        leaves.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    else:
        restored = jax.tree.map(
            lambda a, l: jax.numpy.asarray(a, getattr(l, "dtype", None)),
            restored, tree_like)
    return restored, step
