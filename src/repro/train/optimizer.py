"""AdamW with warmup+cosine schedule, global-norm clipping, and configurable
moment dtype (bf16 moments for the 480B-parameter MoE to fit HBM)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    mv_dtype: str = "float32"
    master_fp32: bool = True       # keep fp32 master copy of bf16 params


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * decay


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    mv = jnp.dtype(cfg.mv_dtype)
    state = {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mv), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, mv), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        # copy=True: f32 params would otherwise alias their master copy and
        # break argument donation (same buffer donated twice)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def opt_state_specs(param_specs, cfg: OptConfig):
    """PSpec tree for the optimizer state (for sharded dry-run init)."""
    from repro.models.layers import PSpec
    mv = cfg.mv_dtype

    def mom(sp):
        return PSpec(sp.shape, sp.axes, mv, init="zeros")

    state = {
        "mu": jax.tree.map(mom, param_specs,
                           is_leaf=lambda x: isinstance(x, PSpec)),
        "nu": jax.tree.map(mom, param_specs,
                           is_leaf=lambda x: isinstance(x, PSpec)),
        "step": PSpec((), (), "int32", init="zeros"),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(
            lambda sp: PSpec(sp.shape, sp.axes, "float32", init=sp.init),
            param_specs, is_leaf=lambda x: isinstance(x, PSpec))
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, opt_state, cfg: OptConfig):
    """One AdamW step -> (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    mv = jnp.dtype(cfg.mv_dtype)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    base = opt_state.get("master", params)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu32 / b1c
        vhat = nu32 / b2c
        p32 = p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        return p32 - lr * delta, mu32.astype(mv), nu32.astype(mv)

    out = jax.tree.map(upd, base, grads, opt_state["mu"], opt_state["nu"])
    new_master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype),
                              new_master, params)
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    if "master" in opt_state:
        new_state["master"] = new_master
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
