"""Train-step builder: loss + grad + AdamW, with optional gradient
accumulation (microbatching) and a gradient-compression hook."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.train import optimizer as opt_mod


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any

    def tree_flatten(self):
        return (self.params, self.opt), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, c: TrainState(params=c[0], opt=c[1]))


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, opt_cfg: opt_mod.OptConfig,
                    *, microbatches: int = 1,
                    grad_transform: Optional[Callable] = None,
                    attn_fn=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_transform``: hook applied to the averaged grads before the
    optimizer (gradient compression, custom all-reduce schedules...).
    ``microbatches``: gradient accumulation over the leading batch split.
    """

    def loss(params, batch):
        return model_mod.loss_fn(params, batch, cfg, attn_fn=attn_fn)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def acc_fn(carry, mb):
                (l, aux), g = grad_fn(state.params, mb)
                carry = jax.tree.map(jnp.add, carry, g)
                return carry, l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, losses = jax.lax.scan(acc_fn, zeros, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss_val = jnp.mean(losses)
        else:
            (loss_val, aux), grads = grad_fn(state.params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        new_params, new_opt, om = opt_mod.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss_val, **om}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def init_state(cfg: ModelConfig, opt_cfg: opt_mod.OptConfig,
               key: jax.Array) -> TrainState:
    params = model_mod.init_params(cfg, key)
    return TrainState(params=params, opt=opt_mod.init_opt_state(params, opt_cfg))
