"""Elastic scaling + failure handling.

On a node failure the job restarts on a smaller (or repaired) mesh:
``reshard`` moves a checkpointed state onto the new mesh's shardings, and
``scale_batch`` adjusts the per-device batch so the global batch is
preserved when possible (or reduced to the nearest divisible size).
``StragglerMonitor`` implements the step-time-based mitigation policy:
persistent stragglers trigger a rebalance event (in production: reassign
the slow host's data shard and exclude it at the next elastic restart).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.parallel import sharding as sh


def reshard(tree, specs, new_mesh: Mesh, *, fsdp: bool = True):
    """Re-place a (host-resident or differently-sharded) pytree onto a new
    mesh according to the logical rules."""
    shardings = sh.param_shardings(specs, new_mesh, fsdp=fsdp)
    return jax.tree.map(jax.device_put, tree, shardings)


def scale_batch(global_batch: int, old_devices: int, new_devices: int) -> int:
    """Keep the global batch if divisible on the new mesh, else round down."""
    if global_batch % new_devices == 0:
        return global_batch
    per = max(global_batch // new_devices, 1)
    return per * new_devices


def fold_shard_loss(plane, shard_id: int, *, rehome: bool = True):
    """Retire a telemetry shard with exact energy accounting.

    The elastic-membership half of the sharded telemetry plane: when the
    host running a shard leaves the job (failure, scale-down), its
    *finished* history is frozen into a ``ShardSummary`` that every later
    plane snapshot still merges — no joule ever leaves the books — and
    its unfinished sessions are rehomed onto the least-loaded survivors
    so their runs complete there.  Returns ``(final_summary,
    rehomed_keys)``; the summary's per-session totals tile into the
    post-fold snapshot exactly (the merge is the same sorted-key
    ``fleet_block`` the unsharded service computes).

    ``plane`` is duck-typed (anything with ``shard``/``detach_shard``) so
    this module keeps no telemetry import at module scope.
    """
    shard = plane.shard(shard_id)
    rehomed = sorted(k for k, s in shard.sessions.items()
                     if s.summary is None) if rehome else []
    final = plane.detach_shard(shard_id, rehome=rehome)
    return final, rehomed


@dataclasses.dataclass
class RebalanceEvent:
    step: int
    reason: str
    slow_factor: float


class StragglerMonitor:
    """Detects persistent stragglers from step times.

    On real multi-host deployments each host reports its step time; a host
    whose time exceeds ``threshold`` x the fleet median for ``patience``
    consecutive windows triggers a rebalance event.
    """

    def __init__(self, threshold: float = 1.35, patience: int = 3,
                 window: int = 8):
        self.threshold = threshold
        self.patience = patience
        self.window = window
        self._times: List[float] = []
        self._strikes = 0
        self.events: List[RebalanceEvent] = []

    def record(self, step: int, step_time_s: float) -> Optional[RebalanceEvent]:
        self._times.append(step_time_s)
        hist = self._times[-self.window:]
        if len(hist) < self.window:
            return None
        med = float(np.median(hist[:-1]))
        if med > 0 and hist[-1] > self.threshold * med:
            self._strikes += 1
        else:
            self._strikes = 0
        if self._strikes >= self.patience:
            ev = RebalanceEvent(step=step, reason="persistent straggler",
                                slow_factor=hist[-1] / med)
            self.events.append(ev)
            self._strikes = 0
            return ev
        return None
