"""Decoder-only model assembly (dense / MoE / MLA / VLM / SSM / hybrid).

Scan-over-layers with stacked parameters keeps the HLO compact (one layer
body compiled once regardless of depth) — essential for the 40-cell × 512-
device dry-run.  Per-layer behaviour variation (gemma2's local/global
alternation, zamba2's shared-attention applications) is carried by scanned
flag arrays rather than unrolled branches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.parallel.act_sharding import BATCH, MODEL, constrain
from repro.models.layers import (PSpec, attention, attention_specs, embed,
                                 embed_specs, lm_head, mla_attention,
                                 mla_specs, mlp, mlp_specs, rms_norm)

BIG_WINDOW = 1 << 30
MROPE_SECTIONS = (16, 24, 24)     # qwen2-vl frequency split (head_dim 128)


def _stack(specs, n: int, axis_name: str = "layers"):
    return jax.tree.map(
        lambda sp: PSpec((n,) + sp.shape, (axis_name,) + sp.axes, sp.dtype,
                         sp.init),
        specs, is_leaf=lambda x: isinstance(x, PSpec))


def _norm_spec(cfg: ModelConfig) -> PSpec:
    return PSpec((cfg.d_model,), ("embed",), "float32", init="zeros")


# ---------------------------------------------------------------------------
# Param specs.
# ---------------------------------------------------------------------------
def decoder_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        out = {"ln1": _norm_spec(cfg), "ssm": ssm_mod.ssm_specs(cfg)}
        if cfg.family == "hybrid":
            return out
        return out
    out: Dict[str, Any] = {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg)}
    if cfg.mla:
        out["attn"] = mla_specs(cfg)
    else:
        out["attn"] = attention_specs(cfg)
    if cfg.family == "encdec":
        out["ln_cross"] = _norm_spec(cfg)
        out["cross"] = attention_specs(cfg)
    if cfg.n_experts:
        out["moe"] = moe_mod.moe_specs(cfg)
        if cfg.moe_dense_residual:
            out["mlp"] = mlp_specs(cfg)
    else:
        out["mlp"] = mlp_specs(cfg)
    if cfg.post_norms:
        out["ln1_post"] = _norm_spec(cfg)
        out["ln2_post"] = _norm_spec(cfg)
    return out


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": embed_specs(cfg),
        "layers": _stack(decoder_layer_specs(cfg), cfg.n_layers),
        "final_norm": _norm_spec(cfg),
    }
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        specs["shared_attn"] = {
            "ln": _norm_spec(cfg),
            "attn": attention_specs(cfg),
            "ln2": _norm_spec(cfg),
            "mlp": mlp_specs(cfg),
        }
    return specs


# ---------------------------------------------------------------------------
# Per-layer flags.
# ---------------------------------------------------------------------------
def layer_flags(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    ln = cfg.n_layers
    if cfg.local_global:
        # even layers local sliding window, odd layers global (gemma2)
        window = np.where(np.arange(ln) % 2 == 0, cfg.local_window,
                          BIG_WINDOW)
    elif cfg.sliding_window:
        window = np.full(ln, cfg.sliding_window)
    else:
        window = np.full(ln, BIG_WINDOW)
    flags = {"window": jnp.asarray(window, jnp.int32)}
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        apply = np.arange(ln) % cfg.shared_attn_every == 0
        slot = np.cumsum(apply) - 1
        flags["shared_apply"] = jnp.asarray(apply)
        flags["shared_slot"] = jnp.asarray(np.maximum(slot, 0), jnp.int32)
    return flags


def n_shared_apps(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid" or not cfg.shared_attn_every:
        return 0
    return int(np.sum(np.arange(cfg.n_layers) % cfg.shared_attn_every == 0))


# ---------------------------------------------------------------------------
# KV / state caches.
# ---------------------------------------------------------------------------
def init_cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStruct tree for the decode cache."""
    dt = jnp.dtype(cfg.dtype)
    ln = cfg.n_layers
    sds = jax.ShapeDtypeStruct
    cache: Dict[str, Any] = {"pos": sds((), jnp.int32)}
    if cfg.family == "ssm":
        d_in, h, n = ssm_mod.ssm_dims(cfg)
        cache["state"] = sds((ln, batch, h, cfg.ssm_head_dim, n), jnp.float32)
        cache["conv"] = sds((ln, batch, cfg.ssm_conv - 1, d_in + 2 * n), dt)
        return cache
    if cfg.family == "hybrid":
        d_in, h, n = ssm_mod.ssm_dims(cfg)
        cache["state"] = sds((ln, batch, h, cfg.ssm_head_dim, n), jnp.float32)
        cache["conv"] = sds((ln, batch, cfg.ssm_conv - 1, d_in + 2 * n), dt)
        apps = n_shared_apps(cfg)
        hd = cfg.head_dim_
        cache["shared_k"] = sds((apps, batch, max_seq, cfg.n_kv_heads, hd), dt)
        cache["shared_v"] = sds((apps, batch, max_seq, cfg.n_kv_heads, hd), dt)
        return cache
    if cfg.mla:
        cache["latent"] = sds((ln, batch, max_seq, cfg.kv_lora_rank), dt)
        cache["k_rope"] = sds((ln, batch, max_seq, cfg.rope_head_dim), dt)
        return cache
    hd = cfg.head_dim_
    cache["k"] = sds((ln, batch, max_seq, cfg.n_kv_heads, hd), dt)
    cache["v"] = sds((ln, batch, max_seq, cfg.n_kv_heads, hd), dt)
    if cfg.family == "encdec":
        cache["cross_k"] = sds((ln, batch, cfg.n_audio_frames,
                                cfg.n_kv_heads, hd), dt)
        cache["cross_v"] = sds((ln, batch, cfg.n_audio_frames,
                                cfg.n_kv_heads, hd), dt)
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_specs(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Forward (train / prefill).
# ---------------------------------------------------------------------------
def _dense_layer(x, lp, cfg, positions, window, mrope_sections, attn_fn):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, _ = mla_attention(h, lp["attn"], cfg, positions)
    else:
        a, _ = attention(h, lp["attn"], cfg, positions, window=window,
                         mrope_sections=mrope_sections, attn_fn=attn_fn)
    # name the post-collective activations so the save_collectives remat
    # policy keeps them: the backward then never re-runs the TP all-reduces
    # / MoE all-to-alls of the forward (§Perf A6/B4)
    a = checkpoint_name(a, "attn_out")
    if cfg.post_norms:
        a = rms_norm(a, lp["ln1_post"], cfg.norm_eps)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        b, s, d = h.shape
        m, aux = moe_mod.moe_mlp(h.reshape(b * s, d), lp["moe"], cfg)
        m = m.reshape(b, s, d)
        if cfg.moe_dense_residual:
            m = m + mlp(h, lp["mlp"], cfg)
    else:
        m, aux = mlp(h, lp["mlp"], cfg), None
    m = checkpoint_name(m, "mlp_out")
    if cfg.post_norms:
        m = rms_norm(m, lp["ln2_post"], cfg.norm_eps)
    return x + m, aux


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            attn_fn=None):
    """Full-sequence forward -> logits [B,S,V] (train & prefill path)."""
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    x = embed(tokens, params["embed"], cfg)
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))
        positions = batch["positions"]
        mrope_sections = MROPE_SECTIONS if cfg.mrope else None
    else:
        positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                                     (bsz, seq))
        mrope_sections = None
    flags = layer_flags(cfg)
    shared = params.get("shared_attn")
    aux_sum = jnp.zeros((), jnp.float32)

    def body(x, scanned):
        lp = scanned["params"]
        # sequence parallelism: the residual lives seq-sharded on the model
        # axis between layers; TP matmuls gather/reduce-scatter around it
        x = constrain(x, [BATCH, MODEL if cfg.seq_parallel else None, None])
        aux_local = jnp.zeros((), jnp.float32)
        if cfg.family in ("ssm", "hybrid"):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, _ = ssm_mod.ssm_forward(h, lp["ssm"], cfg)
            x = x + y
            if cfg.family == "hybrid":
                def with_attn(x):
                    h2 = rms_norm(x, shared["ln"], cfg.norm_eps)
                    a, _ = attention(h2, shared["attn"], cfg, positions,
                                     window=scanned["window"])
                    x = x + a
                    h2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
                    return x + mlp(h2, shared["mlp"], cfg)
                x = jax.lax.cond(scanned["shared_apply"], with_attn,
                                 lambda x: x, x)
        else:
            x, aux = _dense_layer(x, lp, cfg, positions, scanned["window"],
                                  mrope_sections, attn_fn)
            if aux is not None:
                aux_local = (aux["load_balance"]
                             + 1e-3 * aux["router_z"]).astype(jnp.float32)
        return x, aux_local

    if cfg.remat:
        policy = None
        if cfg.remat_policy == "save_collectives":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out", "moe_dispatch")
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    scanned = {"params": params["layers"], "window": flags["window"]}
    if "shared_apply" in flags:
        scanned["shared_apply"] = flags["shared_apply"]
    x, aux_per_layer = jax.lax.scan(body, x, scanned)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(x, params["embed"], cfg)
    return logits, jnp.sum(aux_per_layer)


# ---------------------------------------------------------------------------
# Decode (one new token against a cache).
# ---------------------------------------------------------------------------
def decode_step(params, cache, tokens, cfg: ModelConfig,
                positions_override=None, attn_fn=None):
    """tokens [B, 1] -> (logits [B,1,V], new cache).

    ``attn_fn`` reaches the attention layer with the same contract as the
    forward path: a fused kernel that takes over when attention runs
    without a KV cache.  The cached decode path keeps the reference
    attention (today's flash hook is full-sequence only), so threading the
    hook here is signature parity with ``forward`` — callers configure one
    kernel once for both paths.
    """
    bsz = tokens.shape[0]
    pos = cache["pos"]
    x = embed(tokens, params["embed"], cfg)
    positions = (positions_override if positions_override is not None
                 else jnp.full((bsz, 1), pos, jnp.int32))
    flags = layer_flags(cfg)
    shared = params.get("shared_attn")

    if cfg.family in ("ssm", "hybrid"):
        scanned = {"params": params["layers"],
                   "state": cache["state"], "conv": cache["conv"]}
        if cfg.family == "hybrid":
            scanned.update(shared_apply=flags["shared_apply"],
                           shared_slot=flags["shared_slot"],
                           window=flags["window"])

        def body(carry, sc):
            x, sk, sv = carry
            h = rms_norm(x, sc["params"]["ln1"], cfg.norm_eps)
            y, (st, cv) = ssm_mod.ssm_forward(
                h, sc["params"]["ssm"], cfg, state=sc["state"],
                conv_state=sc["conv"])
            x = x + y
            if cfg.family == "hybrid":
                slot = sc["shared_slot"]

                def with_attn(args):
                    x, sk, sv = args
                    h2 = rms_norm(x, shared["ln"], cfg.norm_eps)
                    kc = jax.lax.dynamic_index_in_dim(sk, slot, 0, False)
                    vc = jax.lax.dynamic_index_in_dim(sv, slot, 0, False)
                    a, nc = attention(h2, shared["attn"], cfg, positions,
                                      kv_cache={"k": kc, "v": vc},
                                      cache_pos=pos, window=sc["window"],
                                      attn_fn=attn_fn)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, nc["k"], slot, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, nc["v"], slot, 0)
                    x = x + a
                    h2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
                    return x + mlp(h2, shared["mlp"], cfg), sk, sv

                x, sk, sv = jax.lax.cond(sc["shared_apply"], with_attn,
                                         lambda a: a, (x, sk, sv))
            return (x, sk, sv), (st, cv)

        sk0 = cache.get("shared_k", jnp.zeros((0,), cfg.activation_dtype))
        sv0 = cache.get("shared_v", jnp.zeros((0,), cfg.activation_dtype))
        (x, sk, sv), (states, convs) = jax.lax.scan(body, (x, sk0, sv0),
                                                    scanned)
        new_cache = dict(cache, pos=pos + 1, state=states, conv=convs)
        if cfg.family == "hybrid":
            new_cache.update(shared_k=sk, shared_v=sv)
    else:
        scanned = {"params": params["layers"], "window": flags["window"]}
        if cfg.mla:
            scanned.update(latent=cache["latent"], k_rope=cache["k_rope"])
        else:
            scanned.update(k=cache["k"], v=cache["v"])
        if cfg.family == "encdec":
            scanned.update(cross_k=cache["cross_k"], cross_v=cache["cross_v"])

        def body(x, sc):
            lp = sc["params"]
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if cfg.mla:
                a, nc = mla_attention(h, lp["attn"], cfg, positions,
                                      kv_cache={"latent": sc["latent"],
                                                "k_rope": sc["k_rope"]},
                                      cache_pos=pos)
                out_caches = (nc["latent"], nc["k_rope"])
            else:
                a, nc = attention(h, lp["attn"], cfg, positions,
                                  kv_cache={"k": sc["k"], "v": sc["v"]},
                                  cache_pos=pos, window=sc["window"],
                                  attn_fn=attn_fn)
                out_caches = (nc["k"], nc["v"])
            if cfg.post_norms:
                a = rms_norm(a, lp["ln1_post"], cfg.norm_eps)
            x = x + a
            if cfg.family == "encdec":
                h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
                a, _ = attention(h, lp["cross"], cfg, positions,
                                 kv_override=(sc["cross_k"], sc["cross_v"]))
                x = x + a
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                b2, s2, d2 = h.shape
                m, _ = moe_mod.moe_mlp(h.reshape(b2 * s2, d2), lp["moe"], cfg)
                m = m.reshape(b2, s2, d2)
                if cfg.moe_dense_residual:
                    m = m + mlp(h, lp["mlp"], cfg)
            else:
                m = mlp(h, lp["mlp"], cfg)
            if cfg.post_norms:
                m = rms_norm(m, lp["ln2_post"], cfg.norm_eps)
            return x + m, out_caches

        x, out_caches = jax.lax.scan(body, x, scanned)
        new_cache = dict(cache, pos=pos + 1)
        if cfg.mla:
            new_cache.update(latent=out_caches[0], k_rope=out_caches[1])
        else:
            new_cache.update(k=out_caches[0], v=out_caches[1])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(x, params["embed"], cfg)
    return logits, new_cache
