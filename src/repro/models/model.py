"""Top-level model API: specs / init / forward / loss / decode per config."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import init_from_specs, sds_from_specs


def model_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.model_specs(cfg)
    return transformer.model_specs(cfg)


def init_params(cfg: ModelConfig, key: jax.Array):
    return init_from_specs(model_specs(cfg), key)


def params_sds(cfg: ModelConfig):
    return sds_from_specs(model_specs(cfg))


def forward(params, batch, cfg: ModelConfig, attn_fn=None):
    if cfg.family == "encdec":
        return encdec.forward(params, batch, cfg, attn_fn=attn_fn)
    return transformer.forward(params, batch, cfg, attn_fn=attn_fn)


def decode_step(params, cache, tokens, cfg: ModelConfig, attn_fn=None):
    return transformer.decode_step(params, cache, tokens, cfg,
                                   attn_fn=attn_fn)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return transformer.init_cache(cfg, batch, max_seq)


def init_cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    return transformer.init_cache_specs(cfg, batch, max_seq)


def cross_entropy(logits, targets, z_loss: float = 1e-4):
    """Token-mean CE with optional z-loss; logits f32 [B,S,V]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    if z_loss:
        ce = ce + z_loss * jnp.mean(lse ** 2)
    return ce


def loss_fn(params, batch, cfg: ModelConfig, attn_fn=None):
    logits, aux = forward(params, batch, cfg, attn_fn=attn_fn)
    loss = cross_entropy(logits, batch["targets"])
    if cfg.n_experts:
        loss = loss + 1e-2 * aux
    return loss, {"ce": loss, "aux": aux}
