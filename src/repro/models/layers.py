"""Shared layer library: norms, RoPE/M-RoPE, GQA/SWA/softcap attention, MLA,
gated MLPs, embeddings.  Spec-first parameter construction so the same code
path builds real arrays (smoke tests), ShapeDtypeStructs (dry-run) and
sharding specs (launcher).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.act_sharding import BATCH, MODEL, constrain


# ---------------------------------------------------------------------------
# Spec-first parameters.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis name per dim
    dtype: str = "bfloat16"
    init: str = "normal"                # normal | zeros | ones

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_from_specs(specs, key, scale: float = 0.02):
    """Materialize a PSpec tree into arrays."""
    leaves, treedef = jax.tree.flatten(specs,
                                       is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, sp in zip(keys, leaves):
        dt = jnp.dtype(sp.dtype)
        if sp.init == "zeros":
            vals.append(jnp.zeros(sp.shape, dt))
        elif sp.init == "ones":
            vals.append(jnp.ones(sp.shape, dt))
        else:
            fan_in = sp.shape[-2] if len(sp.shape) >= 2 else sp.shape[-1]
            std = scale if fan_in <= 0 else min(scale, 1.0 / math.sqrt(fan_in))
            vals.append((jax.random.normal(k, sp.shape, jnp.float32)
                         * std).astype(dt))
    return jax.tree.unflatten(treedef, vals)


def sds_from_specs(specs):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda sp: jax.ShapeDtypeStruct(sp.shape, jnp.dtype(sp.dtype)),
        specs, is_leaf=lambda x: isinstance(x, PSpec))


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE.
# ---------------------------------------------------------------------------
def _rope_angles(positions, head_dim: int, theta: float):
    """positions [..., S] -> (sin, cos) [..., S, head_dim/2] in f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float = 10000.0,
               mrope_sections: Optional[Tuple[int, ...]] = None):
    """x [B, S, H, D]; positions [B, S] or [3, B, S] (M-RoPE)."""
    d = x.shape[-1]
    half = d // 2
    if mrope_sections is not None:
        # Qwen2-VL M-RoPE: frequency bands split across (t, h, w) position ids
        sin_parts, cos_parts = [], []
        for i, sec in enumerate(mrope_sections):
            s, c = _rope_angles(positions[i], d, theta)
            sin_parts.append(s)
            cos_parts.append(c)
        idx = []
        off = 0
        for i, sec in enumerate(mrope_sections):
            idx.append((i, off, off + sec))
            off += sec
        sin = jnp.concatenate([sin_parts[i][..., a:b] for i, a, b in idx], -1)
        cos = jnp.concatenate([cos_parts[i][..., a:b] for i, a, b in idx], -1)
    else:
        sin, cos = _rope_angles(positions, d, theta)
    sin = sin[:, :, None, :]      # [B, S, 1, half]
    cos = cos[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + sliding window + logit softcap).
# ---------------------------------------------------------------------------
def attention_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype
    out = {
        "wq": PSpec((d, h, hd), ("embed", "q_heads", "head_dim"), dt),
        "wk": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": PSpec((h, hd, d), ("q_heads", "head_dim", "embed"), dt),
    }
    if cfg.qkv_bias:
        out["bq"] = PSpec((h, hd), ("q_heads", "head_dim"), dt, init="zeros")
        out["bk"] = PSpec((kv, hd), ("kv_heads", "head_dim"), dt, init="zeros")
        out["bv"] = PSpec((kv, hd), ("kv_heads", "head_dim"), dt, init="zeros")
    return out


def _softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def _attn_weights(q, k, cfg: ModelConfig, q_pos, k_pos, window, causal=True):
    """q [B,Sq,H,D] k [B,Sk,KV,D] -> probs [B,KV,G,Sq,Sk] (f32)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    # scores [B,KV,G,Sq,Sk]: model axis on kv-heads, else q-groups, else Sq
    scores = constrain(scores, [BATCH, MODEL, MODEL, MODEL, None])
    scores = scores / math.sqrt(d)
    scores = _softcap(scores, cfg.attn_logit_softcap)
    mask = k_pos[:, None, :] <= q_pos[:, :, None] if causal else \
        (k_pos[:, None, :] < jnp.iinfo(jnp.int32).max)        # [B,Sq,Sk]
    if window is not None:
        mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = constrain(probs, [BATCH, MODEL, MODEL, MODEL, None])
    return probs     # [B,KV,G,Sq,Sk]


def attention(x, p, cfg: ModelConfig, positions, *, kv_cache=None,
              cache_pos=None, window=None, mrope_sections=None,
              kv_override=None, attn_fn=None, causal=True):
    """Returns (out [B,S,d], new_kv_cache).

    ``kv_cache``: dict(k=[B,Smax,KV,D], v=...) updated at ``cache_pos``.
    ``kv_override``: precomputed (k, v) for cross-attention.
    ``attn_fn``: optional fused kernel (flash attention) for the
    no-cache full-sequence path.
    """
    b, s, d_model = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if kv_override is not None:
        k, v = kv_override
        k_pos = jnp.broadcast_to(jnp.arange(k.shape[1], dtype=jnp.int32)[None],
                                 (b, k.shape[1]))
        q = apply_rope(q, positions, cfg.rope_theta, mrope_sections)
        new_cache = kv_cache
        # cross attention: no causal mask
        kvh = k.shape[2]
        group = cfg.n_heads // kvh
        qg = q.reshape(b, s, kvh, group, q.shape[-1])
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
        scores = scores / math.sqrt(q.shape[-1])
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        out = out.reshape(b, s, cfg.n_heads, -1)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta, mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, mrope_sections)

    if kv_cache is not None:
        ck, cv = kv_cache["k"], kv_cache["v"]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k_full, v_full = ck, cv
        k_pos = jnp.broadcast_to(
            jnp.arange(ck.shape[1], dtype=jnp.int32)[None], (b, ck.shape[1]))
        valid = k_pos <= (cache_pos + s - 1)
        k_pos = jnp.where(valid, k_pos, jnp.iinfo(jnp.int32).max)
    else:
        new_cache = None
        k_full, v_full = k, v
        k_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if attn_fn is not None:
            out = attn_fn(q, k, v, cfg)
            return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), None

    probs = _attn_weights(q, k_full, cfg, positions if positions.ndim == 2
                          else positions[0], k_pos, window, causal=causal)
    kvh = k_full.shape[2]
    group = cfg.n_heads // kvh
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(x.dtype), v_full)
    out = out.reshape(b, s, cfg.n_heads, -1)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek style).
# ---------------------------------------------------------------------------
def mla_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    h = cfg.n_heads
    dt = cfg.dtype
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd = cfg.nope_head_dim, cfg.rope_head_dim
    return {
        "wq_a": PSpec((d, qr), ("embed", "lora"), dt),
        "q_norm": PSpec((qr,), ("lora",), "float32", init="zeros"),
        "wq_b": PSpec((qr, h, nd + rd), ("lora", "q_heads", "head_dim"), dt),
        "wkv_a": PSpec((d, kvr + rd), ("embed", "lora"), dt),
        "kv_norm": PSpec((kvr,), ("lora",), "float32", init="zeros"),
        "wk_b": PSpec((kvr, h, nd), ("lora", "q_heads", "head_dim"), dt),
        "wv_b": PSpec((kvr, h, nd), ("lora", "q_heads", "head_dim"), dt),
        "wo": PSpec((h, nd, d), ("q_heads", "head_dim", "embed"), dt),
    }


def mla_attention(x, p, cfg: ModelConfig, positions, *, kv_cache=None,
                  cache_pos=None):
    """MLA: the cache stores the compressed latent + rope key only."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nd, rd, kvr = cfg.nope_head_dim, cfg.rope_head_dim, cfg.kv_lora_rank

    q_lat = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]                                   # [B,S,kvr+rd]
    latent = rms_norm(kv[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, kvr:], positions, cfg.rope_theta)
    k_rope = k_rope[..., 0, :]                            # [B,S,rd]

    if kv_cache is not None:
        lat_c = jax.lax.dynamic_update_slice(
            kv_cache["latent"], latent.astype(kv_cache["latent"].dtype),
            (0, cache_pos, 0))
        kr_c = jax.lax.dynamic_update_slice(
            kv_cache["k_rope"], k_rope.astype(kv_cache["k_rope"].dtype),
            (0, cache_pos, 0))
        new_cache = {"latent": lat_c, "k_rope": kr_c}
        latent_full, k_rope_full = lat_c, kr_c
        smax = lat_c.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(smax, dtype=jnp.int32)[None],
                                 (b, smax))
        k_pos = jnp.where(k_pos <= (cache_pos + s - 1), k_pos,
                          jnp.iinfo(jnp.int32).max)
    else:
        new_cache = None
        latent_full, k_rope_full = latent, k_rope
        k_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    k_nope = jnp.einsum("bsr,rhk->bshk", latent_full, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", latent_full, p["wv_b"])

    scale = 1.0 / math.sqrt(nd + rd)
    sc = (jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope)
          + jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope_full)
          ).astype(jnp.float32) * scale
    # [B,H,Sq,Sk]: model axis on heads if divisible, else query seq
    sc = constrain(sc, [BATCH, MODEL, MODEL, None])
    causal = k_pos[:, None, :] <= positions[:, :, None]
    sc = jnp.where(causal[:, None, :, :], sc, -1e30)
    probs = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    probs = constrain(probs, [BATCH, MODEL, MODEL, None])
    out = jnp.einsum("bhqs,bshk->bqhk", probs, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None,
              gated: Optional[bool] = None) -> Dict[str, PSpec]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.dtype
    gated = cfg.mlp_gated if gated is None else gated
    out = {
        "w_in": PSpec((d, ff), ("embed", "ff"), dt),
        "w_out": PSpec((ff, d), ("ff", "embed"), dt),
    }
    if gated:
        out["w_gate"] = PSpec((d, ff), ("embed", "ff"), dt)
    return out


def mlp(x, p, cfg: ModelConfig):
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = act(x @ p["w_in"])
    h = constrain(h, [BATCH] + [None] * (h.ndim - 2) + [MODEL])
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Embeddings / LM head.
# ---------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    out = {"tok": PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                        cfg.dtype)}
    if not cfg.tie_embeddings:
        out["head"] = PSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                            cfg.dtype)
    return out


def embed(tokens, p, cfg: ModelConfig):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.family == "encdec" or cfg.mlp_act == "gelu":
        x = x * math.sqrt(cfg.d_model)       # gemma/whisper-style scaling
    return x.astype(cfg.activation_dtype)


def lm_head(x, p, cfg: ModelConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w.astype(x.dtype)
    logits = constrain(logits, [BATCH, None, MODEL])
    logits = _softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits
