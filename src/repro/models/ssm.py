"""Mamba2 — state-space duality (SSD) layer, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear state recurrence across chunks); decode is the O(1) stateful
recurrence.  The intra-chunk computation has a Pallas kernel
(``repro.kernels.ssd_scan``) selected via ``cfg.attention_impl=='pallas'``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import PSpec, rms_norm
from repro.parallel.act_sharding import BATCH, MODEL, constrain


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def ssm_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    d_in, h, n = ssm_dims(cfg)
    conv_ch = d_in + 2 * n                     # x, B, C go through the conv
    dt = cfg.dtype
    return {
        "in_proj": PSpec((d, 2 * d_in + 2 * n + h), ("embed", "ssm_inner"), dt),
        "conv_w": PSpec((cfg.ssm_conv, conv_ch), ("conv", "ssm_inner"), dt),
        "conv_b": PSpec((conv_ch,), ("ssm_inner",), dt, init="zeros"),
        "a_log": PSpec((h,), ("ssm_heads",), "float32", init="zeros"),
        "d_skip": PSpec((h,), ("ssm_heads",), "float32", init="ones"),
        "dt_bias": PSpec((h,), ("ssm_heads",), "float32", init="zeros"),
        "norm_w": PSpec((d_in,), ("ssm_inner",), "float32", init="zeros"),
        "out_proj": PSpec((d_in, d), ("ssm_inner", "embed"), dt),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  x [B,S,C], w [K,C].  With ``state``
    ([B,K-1,C]) performs the streaming update and returns (y, new_state)."""
    k = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)       # [B, K-1+S, C]
        new_state = window[:, -(k - 1):]
    else:
        window = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = None
    y = sum(window[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return y + b, new_state


def _segsum(a):
    """Stable segment-sum: a [..., L] -> [..., L, L] lower-tri cumulative."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(l)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(x, dt, a, b_mat, c_mat, chunk: int,
                    h0: Optional[jnp.ndarray] = None):
    """Reference chunked SSD.

    x  [B,S,H,P]  inputs (already dt-scaled NOT applied; we apply here)
    dt [B,S,H]    softplus'd step sizes
    a  [H]        negative decay rates
    b_mat, c_mat [B,S,N]
    Returns (y [B,S,H,P], last_state [B,H,P,N]).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    xc = constrain(xc, [BATCH, None, None, MODEL, None])
    dtc = dt.reshape(bsz, nc, chunk, h)
    dtc = constrain(dtc, [BATCH, None, None, MODEL])
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]                     # [B,NC,L,H]
    da_cs = jnp.cumsum(da, axis=2)                        # [B,NC,L,H]

    # intra-chunk (quadratic in chunk length); heads on the model axis
    lmat = jnp.exp(_segsum(jnp.moveaxis(da, 2, 3)))       # [B,NC,H,L,L]
    lmat = constrain(lmat, [BATCH, None, MODEL, None, None])
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcsh,bcshp->bclhp",
                        cc, bc, lmat, dtc, xc)
    y_diag = constrain(y_diag, [BATCH, None, None, MODEL, None])

    # chunk -> state contribution
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)   # [B,NC,L,H]
    states = jnp.einsum("bcln,bclh,bclh,bclhp->bchpn",
                        bc, decay_to_end, dtc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])             # [B,NC,H]

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry

    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    last, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # [B,NC,H,P,N]

    # inter-chunk output
    state_decay = jnp.exp(da_cs)                          # [B,NC,L,H]
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       cc, prev_states.astype(cc.dtype), state_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, last


def ssm_forward(x, p, cfg: ModelConfig, *, state=None, conv_state=None,
                ssd_fn=None):
    """Full Mamba2 block.  ``state``/``conv_state`` given -> decode mode
    (S small, typically 1); returns (y, (state, conv_state))."""
    bsz, s, _ = x.shape
    d_in, h, n = ssm_dims(cfg)
    hd = cfg.ssm_head_dim

    z_x_bc_dt = x @ p["in_proj"]
    z = z_x_bc_dt[..., :d_in]
    xbc = z_x_bc_dt[..., d_in:2 * d_in + 2 * n]
    dt_raw = z_x_bc_dt[..., 2 * d_in + 2 * n:]

    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(bsz, s, h, hd)
    b_mat = xbc[..., d_in:d_in + n]
    c_mat = xbc[..., d_in + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])

    if state is not None:
        # O(1) decode recurrence (S == 1 expected)
        xs1 = xs[:, 0].astype(jnp.float32)                 # [B,H,P]
        dt1 = dt[:, 0]                                     # [B,H]
        da = jnp.exp(dt1 * a[None, :])                     # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xs1,
                         b_mat[:, 0].astype(jnp.float32))
        new_state = state * da[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", new_state,
                       c_mat[:, 0].astype(jnp.float32))
        y = y + p["d_skip"][None, :, None] * xs1
        y = y.reshape(bsz, 1, d_in).astype(x.dtype)
        carry = (new_state, new_conv)
    else:
        fn = ssd_fn or ssd_chunked_ref
        y4, last = fn(xs, dt, a, b_mat, c_mat, cfg.ssm_chunk)
        y4 = y4 + p["d_skip"][None, None, :, None] * xs.astype(y4.dtype)
        y = y4.reshape(bsz, s, d_in).astype(x.dtype)
        carry = (last, new_conv)

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], carry


def init_ssm_state(cfg: ModelConfig, batch: int):
    d_in, h, n = ssm_dims(cfg)
    conv_ch = d_in + 2 * n
    return (jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch),
                      jnp.dtype(cfg.dtype)))
