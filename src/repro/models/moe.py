"""Mixture-of-Experts layer — sort-based (MegaBlocks-style) dispatch.

Dense one-hot dispatch ([T, E, C] einsums) is memory-infeasible at
128-expert/1M-token scale, so tokens are sorted by expert id, packed into an
[E, C, d] buffer (capacity-dropped), run through batched expert matmuls and
combined back through the inverse permutation.  Under GSPMD with experts
sharded on the "model" axis this lowers to the expected all-to-all pattern.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import PSpec
from repro.parallel.act_sharding import BATCH, MODEL, constrain


def moe_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.dtype
    return {
        "router": PSpec((d, e), ("embed", "experts"), "float32"),
        "w_in": PSpec((e, d, ff), ("experts", "embed", "ff"), dt),
        "w_gate": PSpec((e, d, ff), ("experts", "embed", "ff"), dt),
        "w_out": PSpec((e, ff, d), ("experts", "ff", "embed"), dt),
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.moe_capacity_factor * n_tokens * cfg.moe_top_k
                      / cfg.n_experts))
    return max((c + 7) // 8 * 8, 8)


def moe_mlp(x, p, cfg: ModelConfig):
    """x [T, d] -> [T, d] plus aux losses dict."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    c = capacity(cfg, t)
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu

    logits = (x.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                      # [T, k]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                                 # [T*k]
    order = jnp.argsort(flat_e)                               # sort by expert
    sorted_e = flat_e[order]
    tok_idx = order // k

    counts = jnp.bincount(flat_e, length=e)                   # [E]
    starts = jnp.cumsum(counts) - counts
    pos_in_grp = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_grp < c
    dest = jnp.where(keep, sorted_e * c + pos_in_grp, e * c)  # drop row

    if cfg.moe_dispatch == "index":
        # §Perf "moe-index": scatter only 4-byte token indices into the
        # slot map, then GATHER the d-wide rows — GSPMD lowers the sharded
        # gather as the dispatch all-to-all instead of materializing a
        # replicated [E*C, d] scatter operand.
        slot_tok = jnp.full((e * c + 1,), t, jnp.int32).at[dest].set(tok_idx)
        x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
        xe = x_pad[slot_tok[:-1]].reshape(e, c, d)
    else:
        buf = jnp.zeros((e * c + 1, d), x.dtype).at[dest].set(x[tok_idx])
        xe = buf[:-1].reshape(e, c, d)
    # expert-parallel: the [E, C, d] buffer lives expert-sharded; getting
    # tokens into it is the all-to-all under GSPMD
    xe = constrain(xe, [MODEL, None, None])
    # checkpointable under the save_collectives policy: the backward then
    # reuses the dispatched buffer instead of re-running the all-to-all
    from jax.ad_checkpoint import checkpoint_name
    xe = checkpoint_name(xe, "moe_dispatch")

    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    h = constrain(h, [MODEL, None, None])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"]).reshape(e * c, d)

    if cfg.moe_dispatch == "index":
        # combine mirrors dispatch: scatter expert rows back to token-major
        # order (model-sharded updates -> data-sharded buffer == all-to-all),
        # instead of gathering from the expert-sharded buffer.
        slot_orig = jnp.full((e * c + 1,), t * k, jnp.int32).at[dest].set(
            order.astype(jnp.int32))
        ycomb = jnp.zeros((t * k, d), x.dtype).at[slot_orig[:-1]].set(
            ye, mode="drop")
        y_flat = ycomb.reshape(t, k, d)
    else:
        y_sorted = ye[jnp.where(keep, dest, 0)] * keep[:, None].astype(x.dtype)
        inv = jnp.argsort(order)
        y_flat = y_sorted[inv].reshape(t, k, d)
    y = jnp.einsum("tkd,tk->td", y_flat, gate.astype(x.dtype))

    # aux: load-balancing loss (Switch-style) + router z-loss
    me = probs.mean(axis=0)                                   # [E]
    ce = (counts / max(t * k, 1)).astype(jnp.float32)
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "drop_fraction": 1.0 - keep.mean(),
    }
    return y, aux
