"""Encoder-decoder model (Whisper backbone; conv frontend is a stub —
``input_specs()`` supplies precomputed audio-frame embeddings)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import (attention, attention_specs, embed, lm_head,
                                 mlp, mlp_specs, rms_norm)


def encoder_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": tfm._norm_spec(cfg),
        "attn": attention_specs(cfg),
        "ln2": tfm._norm_spec(cfg),
        "mlp": mlp_specs(cfg),
    }


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs = tfm.model_specs(cfg)
    specs["encoder"] = tfm._stack(encoder_layer_specs(cfg),
                                  cfg.encoder_layers, "enc_layers")
    specs["enc_final_norm"] = tfm._norm_spec(cfg)
    return specs


def encode(params, encoder_embeds, cfg: ModelConfig):
    """Bidirectional encoder over the (stubbed) audio-frame embeddings."""
    bsz, frames, _ = encoder_embeds.shape
    x = encoder_embeds.astype(cfg.activation_dtype)
    positions = jnp.broadcast_to(jnp.arange(frames, dtype=jnp.int32)[None],
                                 (bsz, frames))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = attention(h, lp["attn"], cfg, positions, causal=False)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp(h, lp["mlp"], cfg), ()

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            attn_fn=None):
    """Encoder + causal decoder with cross attention -> logits."""
    enc = encode(params, batch["encoder_embeds"], cfg)
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    x = embed(tokens, params["embed"], cfg)
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                                 (bsz, seq))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = attention(h, lp["attn"], cfg, positions, attn_fn=attn_fn)
        x = x + a
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        ck = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"])
        a, _ = attention(h, lp["cross"], cfg, positions,
                         kv_override=(ck, cv))
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp(h, lp["mlp"], cfg), ()

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_head(x, params["embed"], cfg), jnp.zeros((), jnp.float32)


def prefill_cross_cache(params, encoder_embeds, cfg: ModelConfig):
    """Precompute per-layer cross K/V from the encoder output (decode path)."""
    enc = encode(params, encoder_embeds, cfg)

    def body(_, lp):
        ck = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"])
        return None, (ck, cv)

    _, (cks, cvs) = jax.lax.scan(body, None, params["layers"])
    return cks, cvs
