"""Deterministic synthetic data pipeline.

Tokens are a stateless hash of (stream seed, step, position) — any host can
regenerate any shard of any step, which makes the pipeline trivially
resumable after restarts/elastic events (no data-loader state to
checkpoint) and gives every data-parallel shard an independent stream.
A background prefetch thread keeps ``steps_ahead`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab: int = 32000
    seq_len: int = 2048
    global_batch: int = 8
    host_id: int = 0
    n_hosts: int = 1


def _hash_tokens(seed: int, step: int, batch_ids: np.ndarray,
                 seq_len: int, vocab: int) -> np.ndarray:
    """SplitMix64-style stateless token generator (mod-2^64 wraparound)."""
    with np.errstate(over="ignore"):
        pos = np.arange(seq_len, dtype=np.uint64)[None, :]
        b = batch_ids.astype(np.uint64)[:, None]
        x = (np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
             + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9)
             + b * np.uint64(0x94D049BB133111EB) + pos)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
        return (x % np.uint64(vocab)).astype(np.int32)


def host_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """This host's shard of the global batch for ``step`` (deterministic)."""
    per_host = cfg.global_batch // cfg.n_hosts
    ids = np.arange(per_host) + cfg.host_id * per_host
    toks = _hash_tokens(cfg.seed, step, ids, cfg.seq_len + 1, cfg.vocab)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def model_batch(mcfg: ModelConfig, shape: ShapeSpec, dcfg: DataConfig,
                step: int) -> Dict[str, np.ndarray]:
    """Full model-input batch (adds stub frontend tensors where needed)."""
    base = host_batch(dataclasses.replace(
        dcfg, vocab=mcfg.vocab, seq_len=shape.seq_len,
        global_batch=shape.global_batch), step)
    b = base["tokens"].shape[0]
    if mcfg.family == "encdec":
        rng = np.random.default_rng(dcfg.seed + step)
        base["encoder_embeds"] = rng.standard_normal(
            (b, mcfg.n_audio_frames, mcfg.d_model), np.float32).astype(
                np.dtype(mcfg.dtype)) * 0.02
    if mcfg.family == "vlm":
        rng = np.random.default_rng(dcfg.seed + step)
        base["vision_embeds"] = rng.standard_normal(
            (b, mcfg.n_vision_tokens, mcfg.d_model), np.float32).astype(
                np.dtype(mcfg.dtype)) * 0.02
        pos = np.broadcast_to(np.arange(shape.seq_len, dtype=np.int32),
                              (3, b, shape.seq_len)).copy()
        base["positions"] = pos
    return base


class Prefetcher:
    """Background-thread prefetch of deterministic batches."""

    def __init__(self, make_batch, start_step: int = 0, steps_ahead: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=steps_ahead)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()

    def stop(self):
        self._stop.set()
