"""End-to-end training driver with fault tolerance + energy monitoring.

Runs a (reduced or full) config for N steps on the available mesh:
checkpoint/restart (atomic, keep-k), simulated failure injection, straggler
monitoring, elastic re-mesh on device loss, and the Wattchmen fleet monitor
attributing per-step energy (the paper as a production feature).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.api import EnergyModel
from repro.configs.base import ShapeSpec
from repro.core.opcount import count_fn
from repro.data.pipeline import DataConfig, model_batch
from repro.launch.mesh import make_mesh
from repro.models import model as model_mod
from repro.parallel import sharding as sh
from repro.train import checkpoint as ckpt_mod
from repro.train import optimizer as opt_mod
from repro.train.elastic import StragglerMonitor
from repro.train.step import TrainState, init_state, make_train_step


def run(arch: str, *, smoke: bool = True, steps: int = 20,
        seq_len: int = 64, global_batch: int = 4,
        ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
        fail_at: Optional[int] = None, microbatches: int = 1,
        energy_system: Optional[str] = "sim-v5e-air",
        energy_donor: Optional[str] = None,
        energy_profile_fraction: Optional[float] = None,
        telemetry_chunk: Optional[int] = 4096,
        freq_mhz: Optional[float] = None, governor: bool = False,
        sla_tokens_per_s: Optional[float] = None,
        telemetry_shards: Optional[int] = None,
        chaos_profile: Optional[str] = None, chaos_seed: int = 0,
        seed: int = 0, verbose: bool = True):
    cfg = cfgs.get_smoke_config(arch) if smoke else cfgs.get_config(arch)
    shape = ShapeSpec("run", seq_len, global_batch, "train")
    opt_cfg = opt_mod.OptConfig(total_steps=max(steps, 2), warmup_steps=2,
                                mv_dtype=cfg.optimizer_dtype,
                                master_fp32=cfg.optimizer_dtype == "float32")
    dcfg = DataConfig(seed=seed, vocab=cfg.vocab, seq_len=seq_len,
                      global_batch=global_batch)

    train_step = jax.jit(make_train_step(cfg, opt_cfg,
                                         microbatches=microbatches),
                         donate_argnums=(0,))

    start_step = 0
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(seed))
    if ckpt_dir and ckpt_mod.latest_step(ckpt_dir) is not None:
        state, start_step = ckpt_mod.restore(ckpt_dir, state)
        if verbose:
            print(f"[train] restored checkpoint at step {start_step}")

    # Wattchmen integration: profile the step once, monitor every step —
    # live=True adds the telemetry stream (measured J/step + drift repair).
    # A first-seen energy_system trains through the resumable calibration
    # pipeline; with a donor it is bootstrapped from a fraction of the
    # microbenchmark suite instead of a full profile (Fig. 14).
    monitor, plane = None, None
    if energy_system:
        example = model_batch(cfg, shape, dcfg, 0)
        counts = count_fn(make_train_step(cfg, opt_cfg,
                                          microbatches=microbatches),
                          state, example)
        if energy_donor is not None:
            model = EnergyModel.train(
                energy_system, resume=True, store=True,
                profile_fraction=energy_profile_fraction or 0.5,
                donor=energy_donor)
        else:
            model = EnergyModel.from_store(energy_system)
        # DVFS: --freq-mhz pins the whole run at one operating point;
        # --governor picks the run's frequency from the sweet-spot
        # governor's exploration order (training is one long session, so
        # the loop closes across runs: per-step measured J/work feeds the
        # governor and its verdict is reported at the end).
        point, gov = freq_mhz, None
        if governor:
            from repro.dvfs import GovernorConfig, SweetSpotGovernor
            fam = [(f, c) for f, c, _ in model.table.family()
                   if f is not None]
            if len(fam) < 2:
                model.calibrate_points(duration_s=3.0, repeats=2)
                fam = [(f, c) for f, c, _ in model.table.family()
                       if f is not None]
            gov = SweetSpotGovernor(
                fam, GovernorConfig(sla_work_per_s=sla_tokens_per_s))
            work = float(seq_len * global_batch)
            gov.seed_exploration(
                lambda p: model.predict(counts, 1.0, operating_point=p)
                .total_j / max(work, 1e-12))
            point = gov.propose()
        chaos = None
        if chaos_profile and chaos_profile != "none":
            from repro.telemetry.faults import ChaosPlan
            chaos = ChaosPlan.profile(chaos_profile, seed=chaos_seed)
            if verbose:
                print(f"[chaos] profile {chaos_profile!r} seed={chaos_seed}:"
                      f" telemetry runs behind the fault-injection layer")
        monitor = model.monitor(live=True, step_counts=counts,
                                telemetry_chunk=telemetry_chunk,
                                operating_point=point, governor=gov,
                                chaos=chaos)
        # --telemetry-shards: the run's session rides a sharded telemetry
        # plane (plane-wide drains, merge-based snapshot) instead of
        # finishing stand-alone
        plane = (model.plane(telemetry_shards, chaos=chaos)
                 if telemetry_shards else None)
        if plane is not None:
            monitor.bind(plane)

    straggler = StragglerMonitor()
    losses = []
    for step in range(start_step, steps):
        if fail_at is not None and step == fail_at:
            raise RuntimeError(f"simulated node failure at step {step}")
        batch = {k: jnp.asarray(v)
                 for k, v in model_batch(cfg, shape, dcfg, step).items()}
        t0 = time.time()
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        straggler.record(step, dt)
        if monitor is not None:
            monitor.live.step(step, duration_s=dt,
                              work_units=seq_len * global_batch)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt_mod.save(ckpt_dir, step + 1, state)
        if verbose:
            print(f"[train] step {step} loss={loss:.4f} ({dt*1e3:.0f}ms)")
    if monitor is not None and monitor.live.steps_registered:
        if plane is not None:
            monitor.live.start()
            plane.finish_all()       # plane-wide drain over all shards
            summary = monitor.live.summary
            if verbose:
                fleet = plane.snapshot()["fleet"]
                print(f"[plane] {len(plane.shards)} shards, "
                      f"{fleet['n_sessions']} sessions, "
                      f"{fleet['measured_j']:.4e} J merged exactly")
        else:
            summary = monitor.live.finish()
        if verbose:
            rec = monitor.records[-1]
            print(f"[train] E/token={rec.joules_per_unit_work:.2e}J "
                  f"live MAPE {summary.mape_pct:.1f}% over {summary.steps} "
                  f"steps" + (", DRIFT flagged" if summary.drift.drifting
                              else ""))
        dev_pt = monitor.live.operating_point
        if verbose and dev_pt is not None:
            what = "governed" if gov is not None else "pinned"
            print(f"[dvfs] {what} at f={dev_pt[0]:g} MHz"
                  + (f" ({len(gov.decisions)} decisions, "
                     f"{gov.decisions[-1].reason})" if gov is not None
                     else ""))
    return state, losses, monitor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--energy-system", default="sim-v5e-air")
    ap.add_argument("--energy-donor", default=None,
                    help="bootstrap the energy table by affine transfer "
                         "from this system's table (Fig. 14)")
    ap.add_argument("--energy-profile-fraction", type=float, default=None,
                    help="fraction of the microbenchmark suite to measure "
                         "when bootstrapping from --energy-donor")
    ap.add_argument("--telemetry-chunk", type=int, default=4096,
                    help="streaming ingestion chunk size (0 = per-sample)")
    ap.add_argument("--freq-mhz", type=float, default=None,
                    help="pin the device at this core frequency")
    ap.add_argument("--governor", action="store_true",
                    help="let the sweet-spot governor pick the run's "
                         "frequency and feed it per-step measurements")
    ap.add_argument("--sla-tokens-per-s", type=float, default=None,
                    help="throughput floor the governor must hold")
    ap.add_argument("--telemetry-shards", type=int, default=None,
                    help="shard the telemetry plane across N workers "
                         "(0/None = single-process service)")
    ap.add_argument("--chaos-profile", default=None,
                    choices=["none", "light", "heavy"],
                    help="run telemetry behind the deterministic "
                         "fault-injection layer (soak/chaos testing)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos plan (same seed = same faults)")
    args = ap.parse_args(argv)
    _, losses, _ = run(args.arch, smoke=args.smoke, steps=args.steps,
                       seq_len=args.seq_len, global_batch=args.global_batch,
                       ckpt_dir=args.ckpt_dir, fail_at=args.fail_at,
                       microbatches=args.microbatches,
                       energy_system=args.energy_system,
                       energy_donor=args.energy_donor,
                       energy_profile_fraction=args.energy_profile_fraction,
                       telemetry_chunk=args.telemetry_chunk or None,
                       freq_mhz=args.freq_mhz, governor=args.governor,
                       sla_tokens_per_s=args.sla_tokens_per_s,
                       telemetry_shards=args.telemetry_shards or None,
                       chaos_profile=args.chaos_profile,
                       chaos_seed=args.chaos_seed)
    ok = np.isfinite(losses).all() and losses[-1] < losses[0]
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if ok else 'check'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
