"""Serving driver: energy-aware continuous batching with a per-request
energy ledger (measured and predicted joules per request/tenant, from the
Wattchmen table + simulated telemetry).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --tenants 2 --requests 6 --budget-j-per-token 2e-4

A multi-request workload (staggered arrivals, mixed prompt/output lengths
across tenants) is run through ``serve.EnergyServer``: admission packs the
decode batch to the J/token budget, drift can shed load, and every aligned
step's joules land on individual requests with bitwise conservation.  The
per-step op counts the scheduler prices and the device executes are traced
from the *real* model prefill/decode steps (``core.opcount.count_fn``), so
the energy accounting reflects the actual architecture at each batch size.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.api import EnergyModel
from repro.core.opcount import count_fn
from repro.models import model as model_mod
from repro.serve.scheduler import EnergyPolicy, Request
from repro.serve.step import make_prefill_step, make_serve_step


def model_counts_fn(cfg, params, *, max_seq: int, attn_fn=None):
    """counts_fn(kind, batch, tokens) traced from the real model steps.

    Decode counts come from the cached ``decode_step`` at the phase's
    batch size; prefill counts from the full-sequence forward at the
    phase's padded prompt length.  ``EnergyServer`` memoizes per
    (kind, batch, tokens), so each shape is traced once.
    """
    def counts(kind: str, batch: int, tokens: int):
        if kind == "prefill":
            fn = make_prefill_step(cfg, attn_fn)
            sample = {"tokens": jnp.zeros((batch, tokens), jnp.int32)}
            return count_fn(fn, params, sample)
        cache = model_mod.init_cache(cfg, batch, max_seq)
        if cfg.family == "encdec":
            from repro.models import encdec
            enc = jnp.zeros((batch, cfg.n_audio_frames, cfg.d_model),
                            cfg.activation_dtype)
            ck, cv = encdec.prefill_cross_cache(params, enc, cfg)
            cache = dict(cache, cross_k=ck, cross_v=cv)
        return count_fn(make_serve_step(cfg, attn_fn), params, cache,
                        jnp.zeros((batch, 1), jnp.int32))
    return counts


def make_workload(*, tenants: int, requests: int, prompt_len: int,
                  max_new: int, seed: int = 0):
    """Staggered multi-tenant request mix for the serving demo.

    Prompt and output lengths are drawn from {½×, 1×, 2×} the nominal
    values and arrivals from a geometric inter-arrival process, so the
    batch genuinely churns: joins, evictions, and occupancy changes.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    step = 0
    for i in range(requests):
        reqs.append(Request(
            id=f"r{i}", tenant=f"tenant-{i % max(tenants, 1)}",
            prompt_len=int(prompt_len * rng.choice([0.5, 1.0, 2.0])) or 1,
            max_new=int(max_new * rng.choice([0.5, 1.0, 2.0])) or 1,
            arrival_step=step))
        step += int(rng.geometric(0.4)) - 1
    return reqs


def _governor_state_path(energy_system: str):
    """Where the sweet-spot governor persists across serve restarts."""
    from repro.core.store import default_store
    return default_store().run_dir(energy_system) / "governor_state.json"


def run(arch: str, *, smoke: bool = True, tenants: int = 2,
        requests: int = 6, prompt_len: int = 16, max_new: int = 16,
        max_batch: int = 4, budget_j_per_token: Optional[float] = None,
        energy_system: str = "sim-v5e-air", seed: int = 0,
        telemetry_chunk: Optional[int] = 4096,
        min_phase_seconds: float = 4.0, verbose: bool = True,
        freq_mhz: Optional[float] = None, governor: bool = False,
        sla_tokens_per_s: Optional[float] = None,
        telemetry_shards: Optional[int] = None,
        chaos_profile: Optional[str] = None, chaos_seed: int = 0):
    cfg = cfgs.get_smoke_config(arch) if smoke else cfgs.get_config(arch)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    max_seq = 2 * prompt_len + 2 * max_new + 1   # covers the 2× draws

    model = EnergyModel.from_store(energy_system)
    gov = None
    if governor:
        from repro.dvfs import GovernorConfig, SweetSpotGovernor
        fam = [(f, c) for f, c, _ in model.table.family() if f is not None]
        if len(fam) < 2:
            # no calibrated family yet: sweep a small grid first
            model.calibrate_points(duration_s=3.0, repeats=2)
            fam = [(f, c) for f, c, _ in model.table.family()
                   if f is not None]
        gov = SweetSpotGovernor(
            fam, GovernorConfig(sla_work_per_s=sla_tokens_per_s))
        # resume where the previous serve run left off: a converged
        # governor re-enters exploit at the same operating point instead
        # of re-exploring the whole grid on every restart
        state_path = _governor_state_path(energy_system)
        if state_path.exists():
            try:
                gov.load_state(json.loads(state_path.read_text()))
                if verbose:
                    print(f"[dvfs] restored governor state "
                          f"({state_path})")
            except (ValueError, KeyError) as exc:
                print(f"[dvfs] ignoring stale governor state: {exc}")
    # sharded telemetry plane: billing, governor pane and the per-phase
    # sessions ride it exactly like the one-process service (the plane is
    # a drop-in TelemetryService with a merge-based snapshot)
    chaos = None
    if chaos_profile and chaos_profile != "none":
        from repro.telemetry.faults import ChaosPlan
        chaos = ChaosPlan.profile(chaos_profile, seed=chaos_seed)
        if verbose:
            print(f"[chaos] profile {chaos_profile!r} seed={chaos_seed}: "
                  f"telemetry runs behind the fault-injection layer")
    plane = (model.plane(telemetry_shards, chaos=chaos)
             if telemetry_shards else None)
    server = model.serve(
        model_counts_fn(cfg, params, max_seq=max_seq),
        policy=EnergyPolicy(max_batch=max_batch,
                            budget_j_per_token=budget_j_per_token),
        min_phase_seconds=min_phase_seconds,
        telemetry_chunk=telemetry_chunk, name=f"serve/{arch}",
        operating_point=freq_mhz, governor=gov, service=plane,
        chaos=chaos)
    workload = make_workload(tenants=tenants, requests=requests,
                             prompt_len=prompt_len, max_new=max_new,
                             seed=seed)
    report = server.run(workload)
    if gov is not None:
        state_path = _governor_state_path(energy_system)
        state_path.parent.mkdir(parents=True, exist_ok=True)
        state_path.write_text(json.dumps(gov.state_dict(), indent=1))
        if verbose:
            print(f"[dvfs] governor state saved ({state_path})")

    if verbose:
        print(f"[serve] {arch}: {len(workload)} requests / {tenants} "
              f"tenants, max_batch={max_batch}"
              + (f", budget {budget_j_per_token:.3e} J/token"
                 if budget_j_per_token else ""))
        print(report.table())
        for t, bill in report.billing.bills.items():
            print(f"[bill] {t}: {bill.measured_j:.4e} J over "
                  f"{bill.requests} requests, {bill.j_per_token:.3e} J/token"
                  f" (residual {bill.residual_j:+.3e} J)")
        deferred = [e for e in report.events if e.event == "defer"]
        shed = [e for e in report.events if e.event == "shed"]
        print(f"[serve] {len(report.ledger)} aligned steps in "
              f"{len(report.phases)} phases; live MAPE "
              f"{report.mape_pct:.1f}%; {len(deferred)} deferrals, "
              f"{len(shed)} sheds, overhead {report.overhead_j:.3e} J")
        if gov is not None and gov.current is not None:
            print(f"[dvfs] governor holding f={gov.current[0]:g} MHz "
                  f"(cap {gov.current[1]} W) after "
                  f"{len(gov.decisions)} decisions")
        elif freq_mhz is not None:
            print(f"[dvfs] pinned at f={freq_mhz:g} MHz")
        if plane is not None:
            fleet = plane.snapshot()["fleet"]
            print(f"[plane] {len(plane.shards)} shards, "
                  f"{fleet['n_sessions']} sessions, "
                  f"{fleet['measured_j']:.4e} J merged exactly")
    return report, server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--budget-j-per-token", type=float, default=None)
    ap.add_argument("--telemetry-chunk", type=int, default=4096,
                    help="streaming ingestion chunk size (0 = per-sample)")
    ap.add_argument("--freq-mhz", type=float, default=None,
                    help="pin the device at this core frequency")
    ap.add_argument("--governor", action="store_true",
                    help="close the loop: sweet-spot DVFS per phase")
    ap.add_argument("--sla-tokens-per-s", type=float, default=None,
                    help="throughput floor the governor must hold")
    ap.add_argument("--telemetry-shards", type=int, default=None,
                    help="shard the telemetry plane across N workers "
                         "(0/None = single-process service)")
    ap.add_argument("--chaos-profile", default=None,
                    choices=["none", "light", "heavy"],
                    help="run telemetry behind the deterministic "
                         "fault-injection layer (soak/chaos testing)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos plan (same seed = same faults)")
    args = ap.parse_args(argv)
    report, _ = run(args.arch, smoke=args.smoke, tenants=args.tenants,
                    requests=args.requests, prompt_len=args.prompt_len,
                    max_new=args.max_new, max_batch=args.max_batch,
                    budget_j_per_token=args.budget_j_per_token,
                    telemetry_chunk=args.telemetry_chunk or None,
                    freq_mhz=args.freq_mhz, governor=args.governor,
                    sla_tokens_per_s=args.sla_tokens_per_s,
                    telemetry_shards=args.telemetry_shards or None,
                    chaos_profile=args.chaos_profile,
                    chaos_seed=args.chaos_seed)
    assert len(report.requests) == args.requests
    return 0


if __name__ == "__main__":
    sys.exit(main())
