"""Serving driver: batched prefill + decode with per-request energy
attribution (joules/token from the Wattchmen table).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.api import EnergyModel
from repro.core.opcount import count_fn
from repro.models import model as model_mod
from repro.serve.step import make_serve_step


def run(arch: str, *, smoke: bool = True, batch: int = 4,
        prompt_len: int = 16, max_new: int = 16,
        energy_system: Optional[str] = "sim-v5e-air", seed: int = 0,
        telemetry_chunk: Optional[int] = 4096, verbose: bool = True):
    cfg = cfgs.get_smoke_config(arch) if smoke else cfgs.get_config(arch)
    max_seq = prompt_len + max_new + 1
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    cache = model_mod.init_cache(cfg, batch, max_seq)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc = jnp.zeros((batch, cfg.n_audio_frames, cfg.d_model),
                        cfg.activation_dtype)
        ck, cv = jax.jit(
            lambda p, e: encdec.prefill_cross_cache(p, e, cfg))(params, enc)
        cache = dict(cache, cross_k=ck, cross_v=cv)

    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    monitor = None
    if energy_system:
        counts = count_fn(make_serve_step(cfg), params, cache,
                          jnp.zeros((batch, 1), jnp.int32))
        # live=True wires a telemetry StreamSession (monitor.live): each
        # decode step is an MTSM sync point; finish() aligns measured
        # joules per step against the sampled power trace, ingested
        # chunk-wise (telemetry_chunk=None falls back to per-sample).
        monitor = EnergyModel.from_store(energy_system).monitor(
            live=True, step_counts=counts, telemetry_chunk=telemetry_chunk)

    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    toks = [tok]
    t0 = time.time()
    for i in range(prompt_len + max_new - 1):
        tok, cache = step(params, cache, tok)
        toks.append(tok)
        if monitor is not None:
            monitor.live.step(i, duration_s=1e-3, work_units=batch)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    summary = (monitor.live.finish()
               if monitor is not None and monitor.live.steps_registered
               else None)
    if verbose:
        total = (prompt_len + max_new) * batch
        print(f"[serve] generated {out.shape} in {dt:.2f}s "
              f"({total / max(dt, 1e-9):.0f} tok/s host-side)")
        if summary is not None:
            rec = monitor.records[-1]
            pred = rec.prediction
            print(f"[serve] predicted energy/step: {pred.total_j:.3e} J "
                  f"(measured {rec.measured_j:.3e} J), dominant bucket: "
                  f"{max(pred.by_bucket, key=pred.by_bucket.get)}")
            print(f"[serve] live MAPE {summary.mape_pct:.1f}% over "
                  f"{summary.steps} steps"
                  + (", DRIFT flagged" if summary.drift.drifting else ""))
    return out, monitor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--telemetry-chunk", type=int, default=4096,
                    help="streaming ingestion chunk size (0 = per-sample)")
    args = ap.parse_args(argv)
    out, _ = run(args.arch, smoke=args.smoke, batch=args.batch,
                 prompt_len=args.prompt_len, max_new=args.max_new,
                 telemetry_chunk=args.telemetry_chunk or None)
    assert out.shape[1] == args.prompt_len + args.max_new
    return 0


if __name__ == "__main__":
    sys.exit(main())
