import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, prove memory/sharding coherence, and emit the roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--all] [--out results.json]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init); do not set it globally — smoke tests and
benchmarks are single-device.
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs as cfgs                         # noqa: E402
from repro.configs.base import SHAPES, input_specs, shape_applicable  # noqa: E402
from repro.core.opcount import count_fn                   # noqa: E402
from repro.core.predict import traffic_from_counts        # noqa: E402
from repro.hlo.roofline import roofline_from_compiled     # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.models import model as model_mod               # noqa: E402
from repro.models.layers import sds_from_specs            # noqa: E402
from repro.models.transformer import model_specs as tfm_specs  # noqa: E402
from repro.parallel import sharding as sh                 # noqa: E402
from repro.serve.step import make_prefill_step, make_serve_step  # noqa: E402
from repro.train import optimizer as opt_mod              # noqa: E402
from repro.train.step import TrainState, make_train_step  # noqa: E402


def _sharded_sds(specs, mesh):
    shardings = sh.param_shardings(specs, mesh)
    sds = sds_from_specs(specs)
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        sds, shardings)


def _replicated_scalar(mesh, dtype):
    return jax.ShapeDtypeStruct((), dtype,
                                sharding=NamedSharding(mesh, P()))


VARIANTS = ("baseline", "zero1", "moe-index", "serve-repl", "seqpar", "best")


def build_cell(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    """(jit-able fn, example args as sharded SDS, model_flops) for a cell.

    Variants (§Perf hillclimb knobs; combine with '+'):
      zero1      — train: params replicated over data (TP only), optimizer
                   state FSDP-sharded (ZeRO-1) -> no per-layer param gathers
      moe-index  — index-based MoE dispatch (scalar scatter + wide gather)
      serve-repl — serving: params replicated over data, sharded on model
      seqpar     — sequence-parallel residual (AR -> AG/RS around TP dots)
      best       — all of the above where applicable
    """
    cfg = cfgs.get_config(arch)
    shape = SHAPES[shape_name]
    parts = set(variant.split("+"))
    if parts & {"moe-index", "best"} and cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_dispatch="index")
    # "best" excludes seqpar: §Perf A2 showed it trades wire for memory
    if "seqpar" in parts \
            and shape.seq_len % mesh.shape.get("model", 1) == 0:
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    if "noremat" in parts:
        cfg = dataclasses.replace(cfg, remat=False)
    if "savecoll" in parts:
        cfg = dataclasses.replace(cfg, remat_policy="save_collectives")
    specs = model_mod.model_specs(cfg)
    zero1 = bool(parts & {"zero1", "best"})
    serve_repl = bool(parts & {"serve-repl", "best"})
    inputs = sh.input_shardings(input_specs(cfg, shape_name), mesh,
                                batch_dim_overrides={"positions": 1})

    if shape.kind == "train":
        # ZeRO-1 only when the TP-sharded params fit comfortably in HBM
        params_fit = (cfg.param_count() * 2 / mesh.shape.get("model", 1)
                      < 8 * 2**30)
        fsdp_params = not (zero1 and params_fit)
        params_sds = _sharded_sds(specs, mesh) if fsdp_params else \
            jax.tree.map(
                lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=ns),
                sds_from_specs(specs),
                sh.param_shardings(specs, mesh, fsdp=False))
        opt_cfg = opt_mod.OptConfig(
            mv_dtype=cfg.optimizer_dtype,
            master_fp32=(cfg.optimizer_dtype == "float32"))
        opt_specs = opt_mod.opt_state_specs(specs, opt_cfg)
        opt_sds = _sharded_sds(opt_specs, mesh)     # always FSDP (ZeRO-1)
        state = TrainState(params=params_sds, opt=opt_sds)
        fn = make_train_step(cfg, opt_cfg)
        args = (state, inputs)
        # 6·N·D (dense) / 6·N_active·D (MoE) useful training flops
        tokens = shape.seq_len * shape.global_batch
        model_flops = 6.0 * cfg.active_param_count() * tokens
    elif shape.kind == "prefill":
        params_sds = _sharded_sds(specs, mesh) if not serve_repl else \
            jax.tree.map(
                lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=ns),
                sds_from_specs(specs),
                sh.param_shardings(specs, mesh, fsdp=False))
        fn = make_prefill_step(cfg)
        args = (params_sds, inputs)
        tokens = shape.seq_len * shape.global_batch
        model_flops = 2.0 * cfg.active_param_count() * tokens
    else:   # decode
        params_sds = _sharded_sds(specs, mesh) if not serve_repl else \
            jax.tree.map(
                lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=ns),
                sds_from_specs(specs),
                sh.param_shardings(specs, mesh, fsdp=False))
        fn = make_serve_step(cfg)
        cache_sds = sh.cache_shardings(
            model_mod.init_cache_specs(cfg, shape.global_batch,
                                       shape.seq_len), mesh)
        args = (params_sds, cache_sds, inputs["tokens"])
        model_flops = 2.0 * cfg.active_param_count() * shape.global_batch
    return fn, args, model_flops


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, donate: bool = True, variant: str = "baseline") -> Dict:
    cfg = cfgs.get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "variant": variant, "status": "skipped", "reason": reason}
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, model_flops = build_cell(arch, shape_name, mesh,
                                           variant=variant)
        # jaxpr-exact dynamic counts (XLA cost_analysis counts loop bodies
        # once): program FLOPs + an HBM-traffic estimate for the roofline
        counts = count_fn(fn, *args)
        traffic = traffic_from_counts(counts)
        program_hbm = (traffic["hbm_read_bytes"]
                       + traffic["hbm_write_bytes"])
        with mesh:
            donate_argnums = (0,) if shape.kind != "prefill" and donate else ()
            jitted = jax.jit(fn, donate_argnums=donate_argnums)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            hlo_text = compiled.as_text()
            rt = roofline_from_compiled(
                compiled, arch=arch, shape=shape_name, mesh=mesh_name,
                model_flops_total=model_flops,
                n_devices=mesh.devices.size, hlo_text=hlo_text,
                program_flops_total=counts.flops,
                program_hbm_bytes_total=program_hbm)
        row = rt.as_row()
        row.update({
            "status": "ok",
            "variant": variant,
            "compile_s": round(time.time() - t0, 1),
            "arg_bytes_per_device": ma.argument_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "total_bytes_per_device": (ma.argument_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       + ma.output_size_in_bytes
                                       - ma.alias_size_in_bytes),
            "collective_counts": dict(rt.collectives.count_by_kind),
            "collective_bytes_by_kind": {
                k: float(v) for k, v in rt.collectives.by_kind.items()},
        })
        row.pop("collectives", None)
        return row
    except Exception as e:  # noqa: BLE001 — a failed cell IS the signal
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "variant": variant,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
                "compile_s": round(time.time() - t0, 1)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = list(cfgs.ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape_name in shapes:
                row = run_cell(arch, shape_name, multi_pod=mp, mesh=mesh,
                               variant=args.variant)
                results.append(row)
                status = row["status"]
                extra = ""
                if status == "ok":
                    extra = (f"bound={row['bound']} "
                             f"comp={row['compute_s']:.3e}s "
                             f"mem={row['memory_s']:.3e}s "
                             f"coll={row['collective_s']:.3e}s "
                             f"bytes/dev={row['total_bytes_per_device']/2**30:.2f}GiB "
                             f"compile={row['compile_s']}s")
                elif status == "error":
                    extra = row["error"]
                else:
                    extra = row["reason"][:60]
                print(f"[{row['mesh']}] {arch:24s} {shape_name:12s} "
                      f"{status:7s} {extra}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results)} cells: {len(results) - len(bad)} ok/skipped, "
          f"{len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
