"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the "pod"
axis crosses DCN and carries the data-parallel gradient reduction +
FSDP parameter sharding of the outermost degree.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper (smoke tests, elastic re-meshes).

    Version-tolerant across the ``jax.sharding.AxisType`` API drift (same
    posture as ``parallel.sharding.abstract_mesh``): newer JAX wants every
    axis explicitly typed ``Auto`` for shard_map interop; older JAX has no
    ``AxisType`` and every ``make_mesh`` axis is implicitly auto.
    """
    shape, axes = tuple(shape), tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    except TypeError:      # AxisType exists but make_mesh predates axis_types
        return jax.make_mesh(shape, axes)
