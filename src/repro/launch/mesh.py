"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the "pod"
axis crosses DCN and carries the data-parallel gradient reduction +
FSDP parameter sharding of the outermost degree.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh helper (smoke tests, elastic re-meshes)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
