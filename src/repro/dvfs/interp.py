"""Operating-point resolution over an ``EnergyTable`` frequency family.

A v3 table is a family of per-(freq_mhz, power_cap_w) calibrations: the
top-level *anchor* plus the ``operating_points`` sub-tables the DVFS sweep
stages measured.  ``resolve`` turns that family plus a requested operating
point into a :class:`ResolvedPoint` — the powers and class-energy vectors
the predictor prices with.

Exactness contract (the acceptance criterion of the frequency axis): when
the requested point *is* a calibrated member, the resolved point hands back
that member's own ``p_const``/``p_static`` floats and ``energy_vectors``
arrays with **no arithmetic applied**, so predictions there are
bitwise-identical to predicting through the per-point table directly.
Between members, class energies and powers interpolate piecewise-linearly
in frequency (dynamic energy is smooth in V(f)² over the short spans of a
calibration grid; the paper's sweet-spot curvature comes from the
energy×time product, not from per-class kinks).

Interpolation happens within the group of members sharing the requested
power cap (nearest calibrated cap when no exact group exists — caps change
throttle behaviour, not per-op energy, so cross-cap blending is the wrong
axis).  Queries outside the calibrated span clamp to the boundary member:
extrapolating leakage beyond the measured voltage range is guesswork, and a
clamped answer keeps the governor inside calibrated territory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


class OperatingPointError(ValueError):
    """The family cannot answer for the requested operating point."""


def as_point(op) -> Optional[Tuple[float, Optional[float]]]:
    """Normalize an operating-point argument to ``(freq_mhz, cap_w|None)``.

    Accepts an ``OperatingPoint`` (or any object with ``freq_mhz``), a
    ``(freq, cap)`` tuple/list, a bare frequency in MHz, or ``None``.
    """
    if op is None:
        return None
    f = getattr(op, "freq_mhz", None)
    if f is not None:
        cap = getattr(op, "power_cap_w", None)
        return (float(f), None if cap is None else float(cap))
    if isinstance(op, (tuple, list)):
        f, cap = op
        return (float(f), None if cap is None else float(cap))
    return (float(op), None)


@dataclasses.dataclass
class ResolvedPoint:
    """A family resolved at one operating point.

    ``exact`` means the point is a calibrated member (``lo is hi``); the
    vectors/powers are then the member's own, untouched.  Otherwise they are
    the ``w``-blend of ``lo`` and ``hi`` (``w`` = weight of ``hi``).
    """

    freq_mhz: float
    power_cap_w: Optional[float]
    lo: object                      # EnergyTable
    hi: object                      # EnergyTable
    w: float
    exact: bool

    @property
    def p_const(self) -> float:
        if self.exact:
            return self.lo.p_const
        return self.lo.p_const * (1.0 - self.w) + self.hi.p_const * self.w

    @property
    def p_static(self) -> float:
        if self.exact:
            return self.lo.p_static
        return self.lo.p_static * (1.0 - self.w) + self.hi.p_static * self.w

    def vectors(self, n: Optional[int] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
        """``(e_direct, e_pred)`` over the first ``n`` class ids."""
        if self.exact:
            return self.lo.energy_vectors(n)
        ed0, ep0 = self.lo.energy_vectors(n)
        ed1, ep1 = self.hi.energy_vectors(n)
        w = self.w
        return ed0 * (1.0 - w) + ed1 * w, ep0 * (1.0 - w) + ep1 * w


def resolve(table, freq_mhz: float,
            power_cap_w: Optional[float] = None) -> ResolvedPoint:
    """Resolve ``table``'s family at ``(freq_mhz, power_cap_w)``.

    Callers normally go through ``EnergyTable.at`` (which caches).  A
    single-member family — every pre-v3 table — resolves to its only member
    for *any* query: a one-point family prices the whole frequency range at
    its anchor, exactly the legacy behaviour.
    """
    fam = table.family()
    if len(fam) == 1:
        return ResolvedPoint(freq_mhz=freq_mhz, power_cap_w=power_cap_w,
                             lo=fam[0][2], hi=fam[0][2], w=0.0, exact=True)
    # exact member match first — the bitwise path
    for f, c, t in fam:
        if f == freq_mhz and (power_cap_w is None or c == power_cap_w):
            return ResolvedPoint(freq_mhz=freq_mhz, power_cap_w=c,
                                 lo=t, hi=t, w=0.0, exact=True)
    # group by cap: exact cap group, else the nearest calibrated cap
    known = [(f, c, t) for f, c, t in fam if f is not None]
    if not known:
        raise OperatingPointError(
            f"{table.system}: family has no frequency-tagged members")
    caps = sorted({c for _, c, _ in known if c is not None})
    group = known
    if power_cap_w is not None and caps:
        nearest = min(caps, key=lambda c: abs(c - power_cap_w))
        group = [(f, c, t) for f, c, t in known if c == nearest] or known
    elif caps:
        # default cap: the anchor's cap when known, else the highest
        anchor = table.anchor_point()
        cap = anchor[1] if anchor else caps[-1]
        group = [(f, c, t) for f, c, t in known if c == cap] or known
    group = sorted(group, key=lambda e: e[0])
    freqs = [f for f, _, _ in group]
    if freq_mhz <= freqs[0]:
        f, c, t = group[0]
        return ResolvedPoint(freq_mhz=freq_mhz, power_cap_w=c,
                             lo=t, hi=t, w=0.0, exact=True)
    if freq_mhz >= freqs[-1]:
        f, c, t = group[-1]
        return ResolvedPoint(freq_mhz=freq_mhz, power_cap_w=c,
                             lo=t, hi=t, w=0.0, exact=True)
    hi_i = int(np.searchsorted(np.asarray(freqs), freq_mhz))
    lo_f, lo_c, lo_t = group[hi_i - 1]
    hi_f, hi_c, hi_t = group[hi_i]
    w = (freq_mhz - lo_f) / (hi_f - lo_f)
    return ResolvedPoint(freq_mhz=freq_mhz, power_cap_w=lo_c,
                         lo=lo_t, hi=hi_t, w=float(w), exact=False)
