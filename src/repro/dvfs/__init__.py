"""The frequency/DVFS axis: interpolated pricing + sweet-spot governing.

Three layers over the core energy table:

* ``interp`` — resolve a v3 table's calibrated (freq, cap) family at any
  operating point: exact (bitwise) at calibrated members, piecewise-linear
  in frequency between them, clamped at the span boundaries;
* ``governor`` — the closed-loop ``SweetSpotGovernor``: explore the
  candidate grid, then hold the measured-J/work argmin under a throughput
  SLA, with hysteresis, drift-pause and workload-shift re-exploration;
* ``sweep`` — the harnesses: exhaustive ``sweep_operating_points`` (the
  ground-truth J/work curve) and ``govern_workload`` (the closed loop),
  both riding per-point ``StreamSession``s.
"""
from repro.dvfs.governor import (GovernorConfig, GovernorDecision,
                                 SweetSpotGovernor)
from repro.dvfs.interp import (OperatingPointError, ResolvedPoint, as_point,
                               resolve)
from repro.dvfs.sweep import (GovernedRound, GovernedRun, SweepResult,
                              SweepRow, default_sweep_points,
                              govern_workload, sweep_operating_points)

__all__ = [
    "GovernorConfig", "GovernorDecision", "SweetSpotGovernor",
    "OperatingPointError", "ResolvedPoint", "as_point", "resolve",
    "GovernedRound", "GovernedRun", "SweepResult", "SweepRow",
    "default_sweep_points", "govern_workload", "sweep_operating_points",
]
