"""Frequency sweeps and governed runs over the streaming pipeline.

``sweep_operating_points`` is the exhaustive instrument: run the same
workload once per candidate (freq_mhz, power_cap_w) point, each through its
own ``StreamSession`` (device set to the point, windows attributed at the
point), and tabulate measured J/work against work/s.  The J/work curve is
the paper-adjacent sweet-spot observable: dynamic energy falls with V(f)²
while the constant+static floor is paid for longer at low clocks, so the
product bottoms out at a workload-dependent frequency (Afzal et al.).

``govern_workload`` is the closed loop around the same primitive: a
``SweetSpotGovernor`` proposes the next point, one session measures it,
the measured J/work feeds back, and the trace records every decision — the
harness behind ``EnergyModel.govern`` and the dashboard example.

Sweeps run with ``recalibrate=None``: exploring off-anchor points must
never trigger a drift "repair" of the shared table (off-nominal residuals
are the physics being measured, not drift).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.dvfs.interp import as_point


def default_sweep_points(device, n: int = 4,
                         power_cap_w: Optional[float] = None,
                         ) -> List[Tuple[float, float]]:
    """``n`` evenly spaced frequencies across the device's V/f range
    (nominal always included) at one power cap (default: the chip TDP)."""
    cap = float(power_cap_w) if power_cap_w is not None \
        else float(device.chip.tdp_watts)
    return [(f, cap) for f in device.vf.grid(n)]


@dataclasses.dataclass
class SweepRow:
    """One operating point's measured outcome."""

    freq_mhz: float
    power_cap_w: Optional[float]
    measured_j: float          # summed over the attributed step windows
    predicted_j: float
    duration_s: float          # summed window durations
    work_units: float          # summed work (tokens, steps, ...)
    mape_pct: float

    @property
    def j_per_work(self) -> float:
        return self.measured_j / max(self.work_units, 1e-12)

    @property
    def work_per_s(self) -> float:
        return self.work_units / max(self.duration_s, 1e-12)

    def snapshot(self) -> dict:
        return {"freq_mhz": self.freq_mhz, "power_cap_w": self.power_cap_w,
                "measured_j": self.measured_j,
                "predicted_j": self.predicted_j,
                "duration_s": self.duration_s,
                "work_units": self.work_units,
                "j_per_work": self.j_per_work,
                "work_per_s": self.work_per_s,
                "mape_pct": self.mape_pct}


@dataclasses.dataclass
class SweepResult:
    """The J/work-vs-frequency curve one sweep measured."""

    workload: str
    rows: List[SweepRow]

    def best(self, sla_work_per_s: Optional[float] = None
             ) -> Optional[SweepRow]:
        """The measured sweet spot: min J/work, optionally under an SLA."""
        rows = self.rows
        if sla_work_per_s is not None:
            rows = [r for r in rows if r.work_per_s >= sla_work_per_s]
        if not rows:
            return None
        return min(rows, key=lambda r: r.j_per_work)

    def snapshot(self) -> dict:
        best = self.best()
        return {"workload": self.workload,
                "rows": [r.snapshot() for r in self.rows],
                "best": None if best is None else best.snapshot()}


def _run_point(model, counts, point, *, steps: int, work_units: float,
               name: str, min_duration_s: float) -> SweepRow:
    """One workload run at one point, measured through a StreamSession."""
    freq, cap = point
    session = model.stream(counts, name=name, recalibrate=None,
                           min_duration_s=min_duration_s,
                           operating_point=point)
    for i in range(steps):
        session.step(i, work_units=work_units)
    session.finish()
    atts = session.attributions
    group = session.iterations_per_step
    return SweepRow(
        freq_mhz=freq, power_cap_w=cap,
        measured_j=float(sum(a.measured_j for a in atts)),
        predicted_j=float(sum(a.predicted_j for a in atts)),
        duration_s=float(sum(a.duration_s for a in atts)),
        work_units=work_units * steps * group,
        mape_pct=session.summary.mape_pct)


def sweep_operating_points(model, counts, points=None, *, steps: int = 6,
                           work_units: float = 1.0,
                           min_duration_s: float = 8.0,
                           name: str = "sweep",
                           restore: bool = True) -> SweepResult:
    """Measure J/work and work/s at every candidate operating point.

    ``model`` is an ``EnergyModel`` (anything with ``stream`` + ``device``);
    ``counts`` the per-step op counts; ``work_units`` the work one logical
    step represents (tokens, samples).  ``restore=True`` puts the device
    back at its pre-sweep operating point afterwards.
    """
    dev = model.device
    if points is None:
        points = default_sweep_points(dev)
    before = dev.operating_point
    rows: List[SweepRow] = []
    try:
        for op in points:
            p = as_point(op)
            rows.append(_run_point(
                model, counts, p, steps=steps, work_units=work_units,
                name=f"{name}@f{p[0]:g}", min_duration_s=min_duration_s))
    finally:
        if restore:
            dev.set_operating_point(before)
    return SweepResult(workload=name, rows=rows)


@dataclasses.dataclass
class GovernedRound:
    """One closed-loop round: the proposal and what it measured."""

    round: int
    freq_mhz: float
    power_cap_w: Optional[float]
    reason: str
    measured_j: float
    duration_s: float
    work_units: float

    @property
    def j_per_work(self) -> float:
        return self.measured_j / max(self.work_units, 1e-12)

    @property
    def work_per_s(self) -> float:
        return self.work_units / max(self.duration_s, 1e-12)

    def snapshot(self) -> dict:
        return {"round": self.round, "freq_mhz": self.freq_mhz,
                "power_cap_w": self.power_cap_w, "reason": self.reason,
                "measured_j": self.measured_j,
                "duration_s": self.duration_s,
                "work_units": self.work_units,
                "j_per_work": self.j_per_work,
                "work_per_s": self.work_per_s}


@dataclasses.dataclass
class GovernedRun:
    """The trace of a governed workload: rounds + the governor's verdict."""

    workload: str
    rounds: List[GovernedRound]
    governor: object             # SweetSpotGovernor

    @property
    def final_point(self) -> Optional[Tuple[float, Optional[float]]]:
        return self.governor.current

    @property
    def converged(self) -> bool:
        return self.governor.converged

    def snapshot(self) -> dict:
        return {"workload": self.workload,
                "rounds": [r.snapshot() for r in self.rounds],
                "governor": self.governor.snapshot()}


def govern_workload(model, counts, governor, *, rounds: int = 12,
                    steps: int = 4, work_units: float = 1.0,
                    min_duration_s: float = 8.0,
                    name: str = "govern",
                    restore: bool = True) -> GovernedRun:
    """Run the closed loop for ``rounds`` phases.

    Each round the governor proposes a point (explore order seeded from
    this model's *predicted* J/work over the candidates), one streaming
    session runs the workload there, and the measured J/work feeds back.
    Frequency changes therefore land exactly at session boundaries — the
    serving stack's phase-boundary DVFS posture.
    """
    dev = model.device
    if not governor.decisions:          # fresh governor: seed exploration
        def _predicted_j_per_work(p):
            dur = steps * min_duration_s
            pred = model.predict(counts.scaled(steps), dur,
                                 operating_point=p)
            return pred.total_j / max(work_units * steps, 1e-12)
        governor.seed_exploration(_predicted_j_per_work)
    before = dev.operating_point
    out: List[GovernedRound] = []
    try:
        for r in range(rounds):
            point = governor.propose()
            reason = governor.decisions[-1].reason
            row = _run_point(model, counts, point, steps=steps,
                             work_units=work_units,
                             name=f"{name}#{r}@f{point[0]:g}",
                             min_duration_s=min_duration_s)
            governor.observe(point, row.measured_j, row.duration_s,
                             row.work_units)
            out.append(GovernedRound(
                round=r, freq_mhz=point[0], power_cap_w=point[1],
                reason=reason, measured_j=row.measured_j,
                duration_s=row.duration_s, work_units=row.work_units))
    finally:
        if restore:
            dev.set_operating_point(before)
    return GovernedRun(workload=name, rounds=out, governor=governor)
