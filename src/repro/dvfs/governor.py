"""Closed-loop sweet-spot governor: chase min J/work under a throughput SLA.

Afzal et al. ("Modeling and Chasing the Energy-Efficiency Sweet Spots in
Modern GPUs", PAPERS.md) show the J/step minimum moves with frequency *and*
workload mix: dynamic energy falls with V(f)² while the static+constant
floor is paid for longer at low clocks, so J/step(f) is U-shaped with a
workload-dependent bottom.  The governor rides the existing
``StreamSession``/``OnlineAttributor`` loop:

* **explore** — visit every candidate operating point once (prediction-
  seeded order, best predicted J/work first, so the early windows already
  run near the sweet spot);
* **exploit** — hold the measured-EWMA argmin of J/work among candidates
  meeting the SLA, with hysteresis (a minimum dwell and a minimum relative
  improvement before switching);
* **re-explore** — when the measurement at the held point drifts from its
  own EWMA beyond ``restale_tol`` (a workload-mix shift moved the sweet
  spot), stale statistics are discarded and exploration restarts;
* **drift pause** — while the attributor's drift detector is tripped the
  governor freezes (mirroring the serve scheduler's admission pause):
  measurements under a drifting table would poison the statistics, and the
  repair path must see a stable operating point.

Frequency changes apply at session/phase boundaries (the simulated device
executes a whole program per session), which is also where real serving
stacks prefer to switch: mid-batch DVFS transitions stall the pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.dvfs.interp import as_point


@dataclasses.dataclass
class GovernorConfig:
    """Tuning knobs for :class:`SweetSpotGovernor`."""

    sla_work_per_s: Optional[float] = None  # throughput floor (tokens/s,
                                            # steps/s — any work unit/s)
    hysteresis_windows: int = 2     # min observations at the held point
                                    # before a switch is considered
    min_improvement: float = 0.02   # relative J/work gain required to move
    ewma_alpha: float = 0.35        # weight of the newest observation
    restale_tol: float = 0.25       # |obs/ewma - 1| that re-opens exploration
    sla_margin: float = 0.0         # fractional slack on the SLA test


@dataclasses.dataclass
class GovernorDecision:
    """One ``propose()`` outcome, kept in the decision history."""

    index: int
    freq_mhz: float
    power_cap_w: Optional[float]
    reason: str                     # explore|hold|switch|sla|drift-pause|
                                    # re-explore
    j_per_work: Optional[float] = None
    work_per_s: Optional[float] = None

    def snapshot(self) -> Dict[str, object]:
        return {"index": self.index, "freq_mhz": self.freq_mhz,
                "power_cap_w": self.power_cap_w, "reason": self.reason,
                "j_per_work": self.j_per_work,
                "work_per_s": self.work_per_s}


class _PointStat:
    """EWMA of measured J/work and work/s at one operating point."""

    __slots__ = ("j_per_work", "work_per_s", "n", "last_j_per_work")

    def __init__(self):
        self.j_per_work: Optional[float] = None
        self.work_per_s: Optional[float] = None
        self.last_j_per_work: Optional[float] = None
        self.n = 0

    def update(self, j_per_work: float, work_per_s: float,
               alpha: float) -> None:
        if self.j_per_work is None:
            self.j_per_work = j_per_work
            self.work_per_s = work_per_s
        else:
            self.j_per_work += alpha * (j_per_work - self.j_per_work)
            self.work_per_s += alpha * (work_per_s - self.work_per_s)
        self.last_j_per_work = j_per_work
        self.n += 1

    def reset(self) -> None:
        self.j_per_work = None
        self.work_per_s = None
        self.last_j_per_work = None
        self.n = 0


class SweetSpotGovernor:
    """Pick the operating point minimizing measured J/work under an SLA.

    ``candidates`` is the calibrated grid (``(freq, cap)`` tuples or
    ``OperatingPoint``s) the governor may choose from — keep it to points
    the table family covers, so session predictions track the measurement
    and the drift detector stays calm.  ``drift_flag`` is the same callable
    the serve scheduler uses (``OnlineAttributor``-backed); while it returns
    True the governor holds still.
    """

    def __init__(self, candidates: Sequence, config: Optional[GovernorConfig]
                 = None, *, drift_flag: Optional[Callable[[], bool]] = None,
                 predictor: Optional[Callable] = None):
        pts = [as_point(c) for c in candidates]
        if not pts:
            raise ValueError("governor needs at least one candidate point")
        # de-dup, keep caller order
        seen = set()
        self.candidates: List[Tuple[float, Optional[float]]] = []
        for p in pts:
            if p not in seen:
                seen.add(p)
                self.candidates.append(p)
        self.config = config or GovernorConfig()
        self.drift_flag = drift_flag
        self._stats: Dict[Tuple[float, Optional[float]], _PointStat] = {
            p: _PointStat() for p in self.candidates}
        self._current: Optional[Tuple[float, Optional[float]]] = None
        self._dwell = 0                     # observations since last switch
        self._stale = False                 # workload shift detected
        self.decisions: List[GovernorDecision] = []
        self._explore_order = list(self.candidates)
        if predictor is not None:
            self.seed_exploration(predictor)

    # -- seeding ------------------------------------------------------------
    def seed_exploration(self, predict_j_per_work: Callable) -> None:
        """Order exploration by predicted J/work (best first) so the early
        windows already run near the predicted sweet spot.

        ``predict_j_per_work(point) -> float`` — typically a closure over
        ``EnergyModel.predict(..., operating_point=point)``.
        """
        scored = []
        for p in self.candidates:
            try:
                scored.append((float(predict_j_per_work(p)), p))
            except Exception:
                scored.append((float("inf"), p))
        scored.sort(key=lambda e: e[0])
        self._explore_order = [p for _, p in scored]

    # -- observation --------------------------------------------------------
    def observe(self, point, measured_j: float, duration_s: float,
                work_units: float) -> None:
        """Feed one attributed window measured at ``point``."""
        p = as_point(point)
        stat = self._stats.get(p)
        if stat is None or work_units <= 0.0 or duration_s <= 0.0:
            return
        j_per_work = measured_j / work_units
        work_per_s = work_units / duration_s
        prev = stat.j_per_work
        stat.update(j_per_work, work_per_s, self.config.ewma_alpha)
        if p == self._current:
            self._dwell += 1
            # workload-mix shift: the point no longer measures like its own
            # history -> statistics at *other* points are stale too
            if (prev is not None and prev > 0.0
                    and abs(j_per_work / prev - 1.0)
                    > self.config.restale_tol):
                for q, s in self._stats.items():
                    if q != p:
                        s.reset()
                stat.reset()
                stat.update(j_per_work, work_per_s, self.config.ewma_alpha)
                self._stale = True

    # -- decision -----------------------------------------------------------
    def _eligible(self) -> List[Tuple[float, Optional[float]]]:
        sla = self.config.sla_work_per_s
        if sla is None:
            return [p for p in self.candidates if self._stats[p].n > 0]
        floor = sla * (1.0 - self.config.sla_margin)
        return [p for p in self.candidates
                if self._stats[p].n > 0
                and (self._stats[p].work_per_s or 0.0) >= floor]

    def propose(self) -> Tuple[float, Optional[float]]:
        """The operating point the next session/phase should run at."""
        cfg = self.config
        if self.drift_flag is not None and self.drift_flag():
            p = self._current or self._explore_order[0]
            self._decide(p, "drift-pause")
            return p
        unexplored = [p for p in self._explore_order
                      if self._stats[p].n == 0]
        if unexplored:
            reason = "re-explore" if self._stale else "explore"
            self._stale = False
            p = unexplored[0]
            self._current = p
            self._dwell = 0
            self._decide(p, reason)
            return p
        eligible = self._eligible()
        if not eligible:
            # nothing meets the SLA: run the fastest point we measured
            p = max(self.candidates,
                    key=lambda q: self._stats[q].work_per_s or 0.0)
            if p != self._current:
                self._current, self._dwell = p, 0
            self._decide(p, "sla")
            return p
        best = min(eligible, key=lambda q: self._stats[q].j_per_work)
        cur = self._current
        if cur is None or cur not in self._stats:
            self._current, self._dwell = best, 0
            self._decide(best, "switch")
            return best
        if best != cur and self._dwell >= cfg.hysteresis_windows:
            cur_j = self._stats[cur].j_per_work
            best_j = self._stats[best].j_per_work
            if (cur_j is not None and best_j is not None and cur_j > 0.0
                    and (cur_j - best_j) / cur_j >= cfg.min_improvement):
                self._current, self._dwell = best, 0
                self._decide(best, "switch")
                return best
        self._decide(cur, "hold")
        return cur

    def _decide(self, p: Tuple[float, Optional[float]], reason: str) -> None:
        stat = self._stats.get(p)
        self.decisions.append(GovernorDecision(
            index=len(self.decisions), freq_mhz=p[0], power_cap_w=p[1],
            reason=reason,
            j_per_work=None if stat is None else stat.j_per_work,
            work_per_s=None if stat is None else stat.work_per_s))

    # -- introspection ------------------------------------------------------
    @property
    def current(self) -> Optional[Tuple[float, Optional[float]]]:
        return self._current

    @property
    def converged(self) -> bool:
        """Every candidate measured and the governor is holding."""
        if any(self._stats[p].n == 0 for p in self.candidates):
            return False
        return bool(self.decisions) and self.decisions[-1].reason in (
            "hold", "switch")

    def best_measured(self) -> Optional[Tuple[float, Optional[float]]]:
        eligible = self._eligible()
        if not eligible:
            return None
        return min(eligible, key=lambda q: self._stats[q].j_per_work)

    def stats(self) -> Dict[Tuple[float, Optional[float]], Dict[str, float]]:
        return {p: {"j_per_work": s.j_per_work, "work_per_s": s.work_per_s,
                    "n": s.n}
                for p, s in self._stats.items()}

    def snapshot(self, history: int = 16) -> Dict[str, object]:
        """JSON-safe state for the ``TelemetryService`` snapshot."""
        return {
            "current": None if self._current is None else
                {"freq_mhz": self._current[0],
                 "power_cap_w": self._current[1]},
            "converged": self.converged,
            "sla_work_per_s": self.config.sla_work_per_s,
            "candidates": [
                {"freq_mhz": p[0], "power_cap_w": p[1],
                 "j_per_work": self._stats[p].j_per_work,
                 "work_per_s": self._stats[p].work_per_s,
                 "n": self._stats[p].n}
                for p in self.candidates],
            "decisions": [d.snapshot() for d in self.decisions[-history:]],
        }

    # -- persistence --------------------------------------------------------
    def state_dict(self) -> Dict[str, object]:
        """Everything a restarted serve process needs to resume *exploit*.

        JSON-safe.  Unlike ``snapshot`` (a dashboard view), this carries
        the full EWMA statistics, the exploration order, and the dwell/
        stale flags — so ``load_state``/``restore`` puts a fresh governor
        exactly where this one stood: a converged governor proposes the
        same operating point with reason ``"hold"`` on its first call.
        """
        def enc(p):
            return None if p is None else [p[0], p[1]]
        return {
            "version": 1,
            "config": dataclasses.asdict(self.config),
            "candidates": [enc(p) for p in self.candidates],
            "explore_order": [enc(p) for p in self._explore_order],
            "current": enc(self._current),
            "dwell": self._dwell,
            "stale": self._stale,
            "stats": [
                {"point": enc(p), "j_per_work": s.j_per_work,
                 "work_per_s": s.work_per_s,
                 "last_j_per_work": s.last_j_per_work, "n": s.n}
                for p, s in self._stats.items()],
            "decisions": [d.snapshot() for d in self.decisions],
        }

    def load_state(self, state: Dict[str, object]) -> "SweetSpotGovernor":
        """Fold a ``state_dict`` into this governor.

        Tolerant of candidate-set changes across restarts: statistics for
        points this governor doesn't know are dropped; new points it has
        that the state lacks stay unexplored (they join the end of the
        exploration order), so a grid extension after a restart is
        explored incrementally rather than from scratch.
        """
        def dec(v):
            if v is None:
                return None
            return (float(v[0]), None if v[1] is None else float(v[1]))
        known = set(self.candidates)
        for row in state.get("stats", []):
            p = dec(row["point"])
            if p not in known:
                continue
            s = self._stats[p]
            s.j_per_work = row["j_per_work"]
            s.work_per_s = row["work_per_s"]
            s.last_j_per_work = row.get("last_j_per_work")
            s.n = int(row["n"])
        cur = dec(state.get("current"))
        self._current = cur if cur in known else None
        self._dwell = int(state.get("dwell", 0))
        self._stale = bool(state.get("stale", False))
        order = [p for p in (dec(v) for v in state.get("explore_order", []))
                 if p in known]
        order += [p for p in self._explore_order if p not in set(order)]
        if order:
            self._explore_order = order
        self.decisions = [
            GovernorDecision(index=d["index"], freq_mhz=d["freq_mhz"],
                             power_cap_w=d["power_cap_w"],
                             reason=d["reason"],
                             j_per_work=d.get("j_per_work"),
                             work_per_s=d.get("work_per_s"))
            for d in state.get("decisions", [])]
        return self

    @classmethod
    def restore(cls, state: Dict[str, object], *,
                config: Optional[GovernorConfig] = None,
                drift_flag: Optional[Callable[[], bool]] = None
                ) -> "SweetSpotGovernor":
        """Rebuild a governor from ``state_dict`` output alone."""
        candidates = [(float(v[0]), None if v[1] is None else float(v[1]))
                      for v in state["candidates"]]
        cfg = config or GovernorConfig(**state["config"])
        gov = cls(candidates, cfg, drift_flag=drift_flag)
        return gov.load_state(state)
