"""Pipeline parallelism: GPipe-style microbatch pipeline over a "stage"
mesh axis using explicit ``ppermute`` hops (shard_map).

The model is split into S stages with stacked per-stage parameters; M
microbatches flow through the classic (M + S - 1)-tick schedule, each tick
computing one stage body and shifting activations one hop along the ICI
ring.  Output equals the sequential composition of the stages — asserted in
``tests/test_distributed.py``.

This complements the DP/FSDP/TP/EP axes of ``parallel.sharding``: at
1000+-node scale, PP over pods bounds the TP domain to one pod while the
pipeline hops cross DCN with only [microbatch, d_model]-sized tensors.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                     # jax >= 0.8
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
except ImportError:                      # pragma: no cover
    from jax.experimental.shard_map import shard_map as _old

    def shard_map(f, mesh, in_specs, out_specs):
        return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def pipeline_forward(stage_fn: Callable, stage_params, microbatches,
                     mesh: Mesh, axis: str = "stage"):
    """Run ``microbatches`` through S pipeline stages.

    stage_fn:      (params_one_stage, x) -> y  (same shape as x)
    stage_params:  pytree stacked on a leading [S, ...] axis
    microbatches:  [M, mb, ...] array
    Returns [M, mb, ...] outputs equal to applying all stages in order.
    """
    n_stages = mesh.shape[axis]
    m = microbatches.shape[0]
    ticks = m + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params_local, xs_local):
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs_local.shape[1:]
        outputs = jnp.zeros((m,) + mb_shape, xs_local.dtype)

        def tick(t, carry):
            held, outputs = carry
            # compute this stage's body on what it holds (valid when the
            # wavefront has reached it: stage <= t < stage + M)
            valid = (t >= stage) & (t < stage + m)
            y = stage_fn(params_local, held)
            y = jnp.where(valid, y, held)
            # last stage records its finished microbatch
            mb_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            record = valid & (stage == n_stages - 1)
            outputs = jax.lax.dynamic_update_slice(
                outputs,
                jnp.where(record, y, jax.lax.dynamic_slice(
                    outputs, (mb_idx,) + (0,) * len(mb_shape),
                    (1,) + mb_shape)[0])[None],
                (mb_idx,) + (0,) * len(mb_shape))
            # shift activations one hop down the ring
            shifted = jax.lax.ppermute(y, axis, perm)
            # stage 0 injects the next microbatch
            nxt = jnp.clip(t + 1, 0, m - 1)
            inject = jax.lax.dynamic_slice(
                xs_local, (nxt,) + (0,) * len(mb_shape),
                (1,) + mb_shape)[0]
            held = jnp.where(stage == 0, inject, shifted)
            return held, outputs

        held0 = xs_local[0]
        # the carry becomes stage-varying after the first ppermute
        try:
            held0 = jax.lax.pcast(held0, (axis,), to="varying")
            outputs = jax.lax.pcast(outputs, (axis,), to="varying")
        except AttributeError:     # older jax without vma typing
            pass
        _, outputs = jax.lax.fori_loop(0, ticks, tick, (held0, outputs))
        return outputs[None]      # [1, M, ...] per stage

    fn = shard_map(per_stage, mesh,
                   in_specs=(P(axis), P()),       # params sharded by stage
                   out_specs=P(axis))
    outs = fn(stage_params, microbatches)         # [S, M, ...]
    return outs[-1]
