"""Activation sharding constraints (ambient-mesh aware, divisibility-safe).

GSPMD propagation into scanned layer bodies is weak; without explicit
constraints the attention scores / MLP hidden / logits can materialize
replicated (a 224 GiB/device buffer on the first qwen2 dry-run).  Model code
calls ``constrain(x, prefs)`` with *preferences*; outside a mesh context (or
when a dim is not divisible) it degrades to a no-op, so single-device smoke
tests and odd configs are unaffected.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH = "batch"        # -> ("pod", "data") (whichever exist & divide)
MODEL = "model"        # -> "model" if divisible
MODEL_OR_SKIP = MODEL  # alias


def ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover — jax internals moved
        return None


def _batch_axes(mesh, dim: int):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    while axes and dim % int(np.prod([mesh.shape[a] for a in axes])) != 0:
        axes.pop(0)
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def constrain(x, prefs: Sequence[Optional[str]]):
    """Apply a best-effort sharding constraint.

    ``prefs``: one of None / "batch" / "model" per dim.  The first "model"
    preference whose dim divides the model-axis extent wins; the rest
    degrade to None (so callers can list fallbacks, e.g. kv-heads then
    q-groups then seq).
    """
    mesh = ambient_mesh()
    if mesh is None or x.ndim != len(prefs):
        return x
    model_n = int(mesh.shape.get("model", 1))
    spec = []
    model_used = False
    for dim, pref in zip(x.shape, prefs):
        if pref == BATCH:
            spec.append(_batch_axes(mesh, dim))
        elif pref == MODEL and not model_used and model_n > 1 \
                and dim % model_n == 0:
            spec.append("model")
            model_used = True
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
