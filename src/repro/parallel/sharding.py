"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Parameters carry logical axis names (``PSpec.axes``); these rules map them
to mesh axes.  A rule is skipped when the dimension is not divisible by the
mesh-axis extent or the mesh axis is already consumed by an earlier dim —
so odd configs (whisper's 51865 vocab, qwen2's 14 heads on a 16-way model
axis) degrade to replication instead of failing, and GSPMD handles the rest.

Mesh axes: ``pod`` (DCN), ``data`` (DP/FSDP), ``model`` (TP/EP).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import PSpec

# logical axis -> preferred mesh axes, in priority order.  "fsdp" expands to
# the data axis (and pod axis in multi-pod meshes) for parameter sharding.
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "ff": ("model",),
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "embed": ("fsdp",),
    "head_dim": (),
    "lora": (),
    "layers": (),
    "enc_layers": (),
    "conv": (),
    "ssm_heads": (),
}

# activation / batch rules
BATCH_AXES = ("pod", "data")


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> "jax.sharding.AbstractMesh":
    """Device-free mesh for rule evaluation, across JAX API generations.

    ``jax.sharding.AbstractMesh`` changed signature: newer JAX takes
    ``(axis_sizes, axis_names)``, older JAX a tuple of ``(name, size)``
    pairs.  The sharding rules only consume ``mesh.shape`` /
    ``mesh.axis_names``, which both generations provide.
    """
    sizes = tuple(int(s) for s in axis_sizes)
    names = tuple(axis_names)
    if len(sizes) != len(names):
        raise ValueError(f"{len(sizes)} axis sizes vs {len(names)} names")
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[n] for n in names]))


def spec_to_pspec(spec: PSpec, mesh: Mesh, *, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter spec under the rules."""
    out = []
    used: set = set()
    for dim, axis in zip(spec.shape, spec.axes):
        assigned: Optional[Tuple[str, ...]] = None
        for rule_axis in LOGICAL_RULES.get(axis, ()):
            mesh_axes: Tuple[str, ...]
            if rule_axis == "fsdp":
                if not fsdp:
                    continue
                mesh_axes = fsdp_axes(mesh)
            else:
                mesh_axes = (rule_axis,) if rule_axis in mesh.axis_names else ()
            if not mesh_axes or any(m in used for m in mesh_axes):
                continue
            if dim % _axis_size(mesh, mesh_axes) != 0:
                continue
            assigned = mesh_axes
            break
        if assigned:
            used.update(assigned)
            out.append(assigned if len(assigned) > 1 else assigned[0])
        else:
            out.append(None)
    return P(*out)


def param_shardings(specs, mesh: Mesh, *, fsdp: bool = True):
    """NamedSharding tree matching a PSpec tree."""
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, spec_to_pspec(sp, mesh, fsdp=fsdp)),
        specs, is_leaf=lambda x: isinstance(x, PSpec))


def batch_pspec(mesh: Mesh, batch_size: int, ndim: int,
                batch_dim: int = 0) -> P:
    """Shard the batch dim over (pod, data), falling back when indivisible."""
    axes = [a for a in BATCH_AXES if a in mesh.axis_names]
    while axes and batch_size % _axis_size(mesh, axes) != 0:
        axes.pop(0)
    spec = [None] * ndim
    if axes:
        spec[batch_dim] = tuple(axes) if len(axes) > 1 else axes[0]
    return P(*spec)


def input_shardings(input_sds: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
                    batch_dim_overrides: Optional[Dict[str, int]] = None):
    """Attach batch sharding to model-input ShapeDtypeStructs."""
    out = {}
    overrides = batch_dim_overrides or {}
    for name, sds in input_sds.items():
        bdim = overrides.get(name, 1 if name == "positions" else 0)
        b = sds.shape[bdim] if sds.shape else 1
        ns = NamedSharding(mesh, batch_pspec(mesh, b, len(sds.shape), bdim))
        out[name] = jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=ns)
    return out


def cache_shardings(cache_sds, mesh: Mesh):
    """Decode-cache shardings.

    Rule: shard batch over (pod, data); for the per-layer KV tensors
    [L, B, S, KV, D] prefer kv-heads on "model" when divisible, else shard
    the sequence dim on "model" (sequence-parallel attention over the cache).
    """
    model_n = mesh.shape.get("model", 1)

    def one(sds):
        shape = sds.shape
        spec = [None] * len(shape)
        if len(shape) == 0:
            return jax.ShapeDtypeStruct(shape, sds.dtype,
                                        sharding=NamedSharding(mesh, P()))
        if len(shape) >= 2:
            bp = batch_pspec(mesh, shape[1], len(shape), 1)
            spec = list(bp)
        if len(shape) == 5:          # [L/apps, B, S, KV, D]
            if shape[3] % model_n == 0 and model_n > 1:
                spec[3] = "model"
            elif shape[2] % model_n == 0 and model_n > 1:
                spec[2] = "model"
        elif len(shape) == 4 and shape[-1] % model_n == 0 and model_n > 1:
            spec[-1] = None          # ssm state [L,B,H,P,N]? handled below
        if len(shape) == 4 and shape[2] % model_n == 0 and model_n > 1:
            # [L, B, S, latent] (MLA) or [L, B, H, ...]: shard dim 2
            spec[2] = "model"
        ns = NamedSharding(mesh, P(*spec))
        return jax.ShapeDtypeStruct(shape, sds.dtype, sharding=ns)

    return jax.tree.map(one, cache_sds)
