"""Gradient compression: int8 quantized all-reduce with error feedback.

At 1000+-node scale the gradient all-reduce is DCN/ICI-bound; 4x wire-byte
reduction via per-chunk int8 quantization (with an error-feedback residual
so compression noise doesn't bias the optimizer) is the standard trick.
``compressed_mean`` is the shard_map building block; ``make_compressor``
adapts it to the train-step ``grad_transform`` hook.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

CHUNK = 1024


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-chunk symmetric int8 quantization.  x is flattened."""
    n = x.size
    pad = (-n) % CHUNK
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad))
    xc = xf.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(xc), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xc / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape,
               dtype=jnp.float32) -> jnp.ndarray:
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return x[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Quantize -> psum(int32) -> dequantize; ~4x fewer wire bytes than f32.

    The scales are psum-maxed so all shards dequantize consistently.
    """
    q, scale = quantize(x)
    scale = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is exact
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, q.size - x.size))
    q2 = jnp.clip(jnp.round(xf.reshape(-1, CHUNK) / scale), -127, 127)
    total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
    out = total.astype(jnp.float32) * scale
    return out.reshape(-1)[:x.size].reshape(x.shape).astype(x.dtype)


def make_error_feedback():
    """Stateful error-feedback wrapper: residual r is added before
    quantization and the quantization error is carried to the next step."""
    def step(x: jnp.ndarray, residual: jnp.ndarray, axis_name: str):
        xr = x + residual
        out = compressed_psum(xr, axis_name)
        # local quantization error (what the wire failed to carry)
        q, scale = quantize(xr)
        deq = dequantize(q, scale, xr.shape, xr.dtype)
        new_residual = xr - deq
        return out, new_residual
    return step


def make_compressor(mesh: Mesh, axis_name: str = "data"):
    """grad_transform hook: compressed mean over the data axis.

    Under pjit the all-reduce is implicit; this hook shard_maps the grads so
    the reduction goes through the quantized path instead.
    """
    def transform(grads):
        def one(g):
            spec = P(*([None] * g.ndim))

            @functools.partial(
                shard_map, mesh=mesh, in_specs=spec, out_specs=spec)
            def run(gl):
                return compressed_psum(gl / mesh.shape[axis_name], axis_name)
            return run(g)
        return jax.tree.map(one, grads)
    return transform
