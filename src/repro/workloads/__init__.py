from repro.workloads.suite import WORKLOADS, Workload, build_workloads

__all__ = ["WORKLOADS", "Workload", "build_workloads"]
