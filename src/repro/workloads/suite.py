"""Evaluation workloads — the paper's Table 3 on the TPU side.

16+ workloads mirroring the paper's mix: Rodinia-style GPGPU kernels
(backprop, hotspot, kmeans, srad), DeepBench GEMMs (two shapes × dtypes) and
vanilla RNNs (train/infer × dtypes), graph analytics (PageRank SpMV), an
HPC QMC-style kernel, plus two TPU-era additions (attention prefill, MoE
block).  None of them share structure with the microbenchmarks — they are
the held-out prediction targets.

Each workload is a real JAX function traced to jaxpr for profiling; the
simulated device provides ground-truth energy.  ``repeat`` controls how many
algorithmic iterations form one program-iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.opcount import OpCounts, count_fn

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


@dataclasses.dataclass
class Workload:
    name: str
    counts: OpCounts          # per program-iteration
    family: str               # gpgpu | ml | graph | hpc
    target_seconds: float = 60.0


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


_REG: List[Tuple[str, str, Callable[[], Tuple[Callable, tuple]]]] = []


def _wl(name: str, family: str):
    def deco(builder):
        _REG.append((name, family, builder))
        return builder
    return deco


# ---- Rodinia-style GPGPU -----------------------------------------------------
@_wl("backprop_k1", "gpgpu")
def _backprop_k1():
    # forward pass of a 2-layer MLP, 64K points (Rodinia backprop input 64K)
    def fn(x, w1, w2):
        h = jnp.tanh(x @ w1)
        o = jax.nn.sigmoid(h @ w2)
        return o.sum()
    return fn, (_sds((65536, 64), F32), _sds((64, 1024), F32),
                _sds((1024, 16), F32))


@_wl("backprop_k2", "gpgpu")
def _backprop_k2():
    # weight-update (backward) kernel
    def fn(x, w1, w2, y):
        def loss(w1, w2):
            h = jnp.tanh(x @ w1)
            o = jax.nn.sigmoid(h @ w2)
            return jnp.mean((o - y) ** 2)
        g1, g2 = jax.grad(loss, argnums=(0, 1))(w1, w2)
        return g1.sum() + g2.sum()
    return fn, (_sds((65536, 64), F32), _sds((64, 1024), F32),
                _sds((1024, 16), F32), _sds((65536, 16), F32))


@_wl("hotspot", "gpgpu")
def _hotspot():
    # 5-point stencil on a 1024x1024 grid, 20 steps (Rodinia hotspot)
    def fn(t0, p):
        def step(t, _):
            up = jnp.roll(t, 1, 0)
            dn = jnp.roll(t, -1, 0)
            lf = jnp.roll(t, 1, 1)
            rt = jnp.roll(t, -1, 1)
            t = t + 0.2 * (up + dn + lf + rt - 4.0 * t) + 0.01 * p
            return t, ()
        t, _ = jax.lax.scan(step, t0, None, length=20)
        return t
    return fn, (_sds((1024, 1024), F32), _sds((1024, 1024), F32))


@_wl("kmeans", "gpgpu")
def _kmeans():
    # 819200 points, 34 features, 5 clusters (Rodinia kmeans input)
    def fn(pts, cent0):
        def step(cent, _):
            d = ((pts[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
            a = jnp.argmin(d, axis=1)
            one = jax.nn.one_hot(a, cent.shape[0], dtype=F32)
            num = one.T @ pts
            den = one.sum(0)[:, None] + 1e-6
            return num / den, ()
        cent, _ = jax.lax.scan(step, cent0, None, length=4)
        return cent
    return fn, (_sds((819200 // 8, 34), F32), _sds((5, 34), F32))


@_wl("srad_v1", "gpgpu")
def _srad():
    # SRAD speckle-reducing diffusion, 502x458 image (Rodinia input)
    def fn(img):
        def step(j, _):
            dn = jnp.roll(j, -1, 0) - j
            ds = jnp.roll(j, 1, 0) - j
            de = jnp.roll(j, -1, 1) - j
            dw = jnp.roll(j, 1, 1) - j
            g2 = (dn**2 + ds**2 + de**2 + dw**2) / (j * j + 1e-6)
            l = (dn + ds + de + dw) / (j + 1e-6)
            num = 0.5 * g2 - (1 / 16.0) * l * l
            den = (1 + 0.25 * l) ** 2
            q = num / (den + 1e-6)
            c = jnp.exp(-q)
            j = j + 0.1 * c * (dn + ds + de + dw)
            return j, ()
        j, _ = jax.lax.scan(step, img, None, length=100)
        return j
    return fn, (_sds((502, 458), F32),)


# ---- DeepBench GEMMs -----------------------------------------------------------
def _gemm(m, n, k, dt):
    def fn(a, b):
        def step(acc, _):
            return (a @ b + acc * 0.0), ()     # fresh gemm each step
        out0 = jnp.zeros((m, n), dt)
        o, _ = jax.lax.scan(step, out0, None, length=8)
        return o
    return fn, (_sds((m, k), dt), _sds((k, n), dt))


for _nm, (_m, _n, _k) in {"gemm_c1": (1760, 128, 1760),
                          "gemm_c2": (3072, 128, 1024)}.items():
    for _dt, _tag in ((BF16, "half"), (F32, "float")):
        _wl(f"{_nm}_{_tag}", "ml")(lambda m=_m, n=_n, k=_k, dt=_dt: _gemm(m, n, k, dt))


# ---- RNNs (DeepBench vanilla, 1760 hidden, batch 16, 50 steps) ------------------
def _rnn_infer(dt):
    def fn(x, wx, wh, h0):
        def step(h, xt):
            return jnp.tanh(xt @ wx + h @ wh), ()
        h, _ = jax.lax.scan(step, h0, x)
        return h
    return fn, (_sds((50, 16, 1760), dt), _sds((1760, 1760), dt),
                _sds((1760, 1760), dt), _sds((16, 1760), dt))


def _rnn_train(dt):
    def fn(x, wx, wh, h0):
        def loss(wx, wh):
            def step(h, xt):
                return jnp.tanh(xt @ wx + h @ wh), ()
            h, _ = jax.lax.scan(step, h0, x)
            return (h.astype(F32) ** 2).mean()
        g = jax.grad(loss, argnums=(0, 1))(wx, wh)
        return g[0].sum() + g[1].sum()
    return fn, (_sds((50, 16, 1760), dt), _sds((1760, 1760), dt),
                _sds((1760, 1760), dt), _sds((16, 1760), dt))


for _dt, _tag in ((BF16, "half"), (F32, "float")):
    _wl(f"rnn_infer_{_tag}", "ml")(lambda dt=_dt: _rnn_infer(dt))
    _wl(f"rnn_train_{_tag}", "ml")(lambda dt=_dt: _rnn_train(dt))


# ---- Graph analytics: PageRank as SpMV ------------------------------------------
@_wl("pagerank_spmv", "graph")
def _pagerank():
    # pre2-scale graph: 659033 nodes, ~6M edges, gather-based SpMV
    n, nnz = 659_033, 5_959_282
    def fn(rank, src, dst, vals):
        def step(r, _):
            contrib = r[src] * vals
            r_new = jax.ops.segment_sum(contrib, dst, num_segments=n)
            r_new = 0.85 * r_new + 0.15 / n
            return r_new, ()
        r, _ = jax.lax.scan(step, rank, None, length=5)
        return r
    return fn, (_sds((n,), F32), _sds((nnz,), I32), _sds((nnz,), I32),
                _sds((nnz,), F32))


# ---- HPC: QMC-style kernel (QMCPACK NiO S64 flavour) -----------------------------
@_wl("qmc_nio", "hpc")
def _qmc():
    # 256 walkers; per walker: Slater-matrix update-like ops — dense f32
    # matmul, rank-1 update, exp/log weights, gather of orbitals.
    def fn(psi, orb, idx, vec):
        def step(p, _):
            row = orb[idx]                       # (256, 512) gather
            ratio = jnp.einsum("wij,wj->wi", p, vec)
            p = p + 1e-3 * jnp.einsum("wi,wj->wij", ratio, vec)
            w = jnp.exp(jnp.clip((row * ratio[:, :row.shape[1]]).sum(-1), -5, 5) * 1e-3)
            p = p * (1.0 + 1e-6 * w[:, None, None])
            return p, ()
        p, _ = jax.lax.scan(step, psi, None, length=10)
        return p
    return fn, (_sds((256, 512, 512), F32), _sds((65536, 512), F32),
                _sds((256,), I32), _sds((256, 512), F32))


# ---- TPU-era additions ------------------------------------------------------------
@_wl("attention_prefill", "ml")
def _attention():
    def fn(q, k, v):
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(128.0).astype(BF16)
        p = jax.nn.softmax(s.astype(F32), axis=-1).astype(BF16)
        return jnp.einsum("bhqk,bhkd->bhqd", p, v)
    shp = (4, 16, 2048, 128)
    return fn, (_sds(shp, BF16), _sds(shp, BF16), _sds(shp, BF16))


@_wl("moe_block", "ml")
def _moe():
    def fn(x, wg, w1, w2):
        # top-2 of 8 experts, GShard-style dense dispatch
        logits = x @ wg                                   # (T, 8)
        probs = jax.nn.softmax(logits, -1)
        top_p, top_i = jax.lax.top_k(probs, 2)
        disp = jax.nn.one_hot(top_i, 8, dtype=x.dtype)    # (T, 2, 8)
        xe = jnp.einsum("td,tke->ekd", x, disp) / 2.0
        h = jax.nn.relu(jnp.einsum("ekd,edf->ekf", xe, w1))
        ye = jnp.einsum("ekf,efd->ekd", h, w2)
        y = jnp.einsum("ekd,tke,tk->td", ye, disp, top_p)
        return y
    d, f = 1024, 4096
    return fn, (_sds((16384, d), BF16), _sds((d, 8), BF16),
                _sds((8, d, f), BF16), _sds((8, f, d), BF16))


@_wl("decode_step", "ml")
def _decode():
    # single-token GQA decode with in-place KV-cache update (dus-heavy)
    def fn(q, kc, vc, knew, vnew, pos):
        def step(carry, i):
            kc, vc = carry
            kc = jax.lax.dynamic_update_slice(kc, knew, (0, 0, pos + i, 0))
            vc = jax.lax.dynamic_update_slice(vc, vnew, (0, 0, pos + i, 0))
            s = jnp.einsum("bhd,bhkd->bhk", q[:, :, 0], kc)
            p = jax.nn.softmax(s.astype(F32), -1).astype(BF16)
            o = jnp.einsum("bhk,bhkd->bhd", p, vc)
            return (kc, vc), o
        (_, _), o = jax.lax.scan(step, (kc, vc), jnp.arange(32, dtype=I32))
        return o
    b, h, s, d = 8, 16, 8192, 128
    return fn, (_sds((b, h, 1, d), BF16), _sds((b, h, s, d), BF16),
                _sds((b, h, s, d), BF16), _sds((b, h, 1, d), BF16),
                _sds((b, h, 1, d), BF16), 128)


@_wl("ssd_scan", "ml")
def _ssd():
    # Mamba2-style chunked selective scan (cumsum-heavy)
    def fn(x, dt, a):
        def step(h, inp):
            xc, dtc = inp
            da = jnp.cumsum(dtc * a, axis=-1)
            g = jnp.exp(da - da[..., -1:])
            y = jnp.cumsum(xc * g, axis=1)
            h = h * jnp.exp(da[..., -1:]) + y[-1]
            return h, y
        h0 = jnp.zeros((x.shape[1], x.shape[2]), F32)
        _, ys = jax.lax.scan(step, h0, (x, dt))
        return ys
    return fn, (_sds((16, 256, 2048), F32), _sds((16, 256, 2048), F32),
                _sds((2048,), F32))


def build_workloads(isa_gen: int = 0) -> List[Workload]:
    out = []
    for name, family, builder in _REG:
        fn, args = builder()
        out.append(Workload(name=name, family=family,
                            counts=count_fn(fn, *args, isa_gen=isa_gen)))
    return out


WORKLOADS = [name for name, _, _ in _REG]
