"""Wattchmen reproduction — high-fidelity, flexible accelerator energy
modeling (training-phase table + prediction/attribution), grown toward a
production-scale fleet-monitoring system.

The public surface is the ``EnergyModel`` session facade:

    import repro

    model = repro.EnergyModel.from_store("sim-v5e-air")
    cmp = model.compare(my_fn, *shape_args)
    print(cmp.measured_j, cmp.predicted_j, cmp.error_pct)

Attributes are resolved lazily (PEP 562) so ``import repro`` stays cheap and
environment variables (e.g. ``XLA_FLAGS``) set before the first deep import
still take effect.
"""
from __future__ import annotations

__version__ = "0.1.0"

# public name -> defining submodule
_LAZY = {
    "EnergyModel": "repro.api",
    "Profile": "repro.api",
    "ProfileSource": "repro.api",
    "JaxprSource": "repro.api",
    "HloSource": "repro.api",
    "CountsSource": "repro.api",
    "PredictJob": "repro.api",
    "Comparison": "repro.api",
    "ProfileCache": "repro.api",
    "EnergyTable": "repro.core.table",
    "TableSchemaError": "repro.core.table",
    "TableStore": "repro.core.store",
    "default_store": "repro.core.store",
    "Prediction": "repro.core.predict",
    "TablePredictor": "repro.core.predict",
    "OpCounts": "repro.core.opcount",
    "EnergyMonitor": "repro.core.fleet",
    "TelemetryService": "repro.telemetry",
    "StreamSession": "repro.telemetry",
    "StreamSummary": "repro.telemetry",
    "SYSTEMS": "repro.hw.systems",
    "get_device": "repro.hw.systems",
    "OperatingPoint": "repro.hw.device",
    "VfCurve": "repro.hw.spec",
    "SweetSpotGovernor": "repro.dvfs",
    "GovernorConfig": "repro.dvfs",
    "SweepResult": "repro.dvfs",
    "GovernedRun": "repro.dvfs",
    "default_sweep_points": "repro.dvfs",
    "sweep_operating_points": "repro.dvfs",
    "govern_workload": "repro.dvfs",
    "calibrate_sweep": "repro.core.calibrate",
    "EnergyServer": "repro.serve",
    "EnergyPolicy": "repro.serve",
    "Request": "repro.serve",
    "ServeReport": "repro.serve",
    "RequestLedger": "repro.serve",
    "LedgerPolicy": "repro.serve",
    "BillingReport": "repro.serve",
    "bill_tenants": "repro.serve",
}

__all__ = sorted(_LAZY) + ["__version__"]


def __getattr__(name: str):
    mod_name = _LAZY.get(name)
    if mod_name is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod_name), name)


def __dir__():
    return __all__
