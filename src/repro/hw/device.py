"""Simulated TPU power/energy substrate — the "real GPU + NVML" of this repo.

This container has no power sensors, so the physical device of the paper is
replaced by a black-box simulator.  The contract mirrors real hardware:

* ``SimDevice.run(program)`` executes a program (characterised by its dynamic
  op counts) and returns *telemetry*: a sampled power trace (with sensor
  noise, quantization and dropped samples), an NVML-style energy counter, a
  wall-clock duration, and profiler counters (HBM read/write bytes, VMEM
  bytes) — exactly the observables the paper's methodology consumes.
* Everything inside ``_HiddenModel`` is ground truth the modeling code in
  ``repro.core`` is FORBIDDEN from reading (enforced by convention + a test
  that greps for accesses).  Its per-class energies are *not* linear in the
  observables: utilization-dependent static power, MXU alignment penalties,
  VPU/MXU dual-issue discounts, cooling-dependent thermal leakage drift and
  sensor noise all create the organic gap between Wattchmen's linear model
  and reality that produces the paper's ~11-15% MAPEs.

Timing is roofline-based with the same public constants used by §Roofline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, Optional

import numpy as np

from repro.core import isa
# OpCounts lives in the jax-free accumulation core; telemetry shard
# workers import this module and must not pull in jax via core.opcount
from repro.core.counting import OpCounts
from repro.hw.spec import ChipSpec, VfCurve

# Canonical class ids used on the timing/energy hot paths.
_CTL_LOOP_ID = isa.CLASS_INDEX.intern("ctl.loop")
_RANDOM_ACCESS_IDS = tuple(isa.CLASS_INDEX.intern(c) for c in
                           ("gather", "scatter", "scatter_dma", "dus"))

SENSOR_HZ = 10.0           # NVML-style sampling rate
SENSOR_NOISE_W = 1.5       # gaussian sensor noise (W)
SENSOR_QUANT_W = 1.0       # sensor quantization (W)
SENSOR_DROP_P = 0.002      # dropped-sample probability


# ---------------------------------------------------------------------------
# Public telemetry containers.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SensorTrace:
    """NVML-style sampled telemetry."""

    times_s: np.ndarray
    power_w: np.ndarray
    util: np.ndarray
    temp_c: np.ndarray

    def duration(self) -> float:
        return float(self.times_s[-1] - self.times_s[0]) if len(self.times_s) > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """A DVFS setting the device can be pinned to: (core MHz, power cap W)."""

    freq_mhz: float
    power_cap_w: float

    @property
    def tag(self) -> str:
        """Filesystem/spec-id-safe identifier for this point."""
        return f"f{self.freq_mhz:g}c{self.power_cap_w:g}"

    def as_tuple(self):
        return (self.freq_mhz, self.power_cap_w)


@dataclasses.dataclass(frozen=True)
class LaunchSpec:
    """One kernel launch inside an iteration, as declared by the host.

    ``counts`` is the launch's own per-call op-count profile — the device
    times it with the same roofline it times whole programs with, which is
    how real profilers place kernel start/stop timestamps on the stream.
    """

    name: str
    counts: OpCounts
    variant: str = "pallas"
    config: tuple = ()


@dataclasses.dataclass(frozen=True)
class LaunchSpan:
    """Profiler-style per-launch timing: fraction of one iteration's span."""

    name: str
    variant: str
    config: tuple
    frac_start: float
    frac_end: float

    @property
    def frac(self) -> float:
        return self.frac_end - self.frac_start


@dataclasses.dataclass
class RunRecord:
    """Result of executing one program on the device."""

    name: str
    duration_s: float
    iters: int
    trace: SensorTrace
    energy_counter_j: float            # NVML-style total-energy counter
    counters: Dict[str, float]         # profiler counters (true, per run)
    freq_mhz: float = 0.0              # operating point during the run
    power_cap_w: float = 0.0
    launch_spans: Optional[list] = None   # per-iteration kernel timing

    @property
    def avg_power_w(self) -> float:
        return self.energy_counter_j / max(self.duration_s, 1e-12)


@dataclasses.dataclass
class Program:
    """A workload as seen by the device: per-iteration op counts × iters."""

    name: str
    counts_per_iter: OpCounts
    iters: int = 1
    is_nanosleep: bool = False   # active-but-idle probe (Oles et al. analogue)
    launches: Optional[list] = None      # declared LaunchSpecs per iteration


# ---------------------------------------------------------------------------
# Hidden ground-truth model.  *** repro.core must never touch this. ***
# ---------------------------------------------------------------------------
def _stable_unit(seed: int, key: str) -> float:
    """Deterministic uniform(0,1) from (seed, key)."""
    h = hashlib.sha256(f"{seed}:{key}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


# Base per-unit energies (J/unit) for the gen-0 chip before per-system jitter.
_BASE_COEFF: Dict[str, float] = {
    # MXU (per MAC)
    "dot.bf16": 1.30e-12, "dot.f32": 5.20e-12, "dot.int8": 0.65e-12,
    "conv.bf16": 1.55e-12, "conv.f32": 6.10e-12,
    "dot.fp8": 0.42e-12, "sparse_dot.bf16": 0.85e-12, "dot.int4": 0.36e-12,
    "dot_small.bf16": 1.95e-12, "dot_small.f32": 7.40e-12,
    "dot_group.bf16": 1.08e-12, "dot_group.f32": 4.30e-12,
    # VPU transcendental (per element)
    "exp.f32": 34e-12, "log.f32": 38e-12, "tanh.f32": 42e-12,
    "logistic.f32": 40e-12, "rsqrt.f32": 30e-12, "sqrt.f32": 28e-12,
    "erf.f32": 45e-12, "sin.f32": 36e-12, "cos.f32": 36e-12, "pow.f32": 55e-12,
    # VPU simple (per element)
    "add.f32": 10e-12, "mul.f32": 12e-12, "sub.f32": 10e-12, "div.f32": 26e-12,
    "max.f32": 9e-12, "min.f32": 9e-12, "cmp.f32": 8e-12, "select.f32": 9e-12,
    "reduce.add.f32": 11e-12, "reduce.max.f32": 10e-12, "cumsum.f32": 14e-12,
    # VPU int
    "add.int": 6e-12, "mul.int": 9e-12, "and.int": 5e-12, "or.int": 5e-12,
    "xor.int": 5e-12, "shift.int": 5.5e-12, "cmp.int": 6e-12,
    "select.int": 7e-12, "rng.bits": 24e-12,
    # Converts / moves
    "convert.f32.bf16": 8e-12, "convert.bf16.f32": 8e-12,
    "convert.int.float": 9e-12, "convert.float.int": 9e-12,
    "bcast": 4e-12, "transpose": 7e-12, "concat": 5e-12, "slice": 4.5e-12,
    "dus": 5e-12, "gather": 16e-12, "scatter": 20e-12, "iota": 2.5e-12,
    "pad": 4e-12, "sort": 18e-12, "scatter_dma": 14e-12,
    # Memory (per byte).  Fused intra-kernel traffic lives in VREGs and is
    # folded into per-op energies; VMEM prices tile loads/stores.
    "hbm.read": 45e-12, "hbm.write": 52e-12,
    "vmem.read": 1.4e-12, "vmem.write": 1.7e-12,
    # Collectives (per wire byte per chip)
    "ici.all_reduce": 28e-12, "ici.all_gather": 22e-12,
    "ici.reduce_scatter": 22e-12, "ici.all_to_all": 30e-12,
    "ici.permute": 18e-12, "dcn.transfer": 95e-12,
    # Control (per executed loop iteration / branch; scalar-core scale)
    "ctl.loop": 2.0e-9, "ctl.cond": 1.0e-9,
}

# bf16 VPU variants cost ~72% of f32.
for _op in ("exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "erf", "sin",
            "cos", "pow", "add", "mul", "sub", "div", "max", "min", "cmp",
            "select"):
    _f32 = _BASE_COEFF.get(f"{_op}.f32")
    if _f32 is not None:
        _BASE_COEFF[f"{_op}.bf16"] = _f32 * 0.72

# Process-node scaling per generation: [dynamic logic, memory, interconnect].
# Chosen so saturated dynamic power stays inside each chip's TDP envelope.
_GEN_SCALE = {0: (1.00, 1.00, 1.00), 1: (0.70, 0.86, 0.90),
              2: (0.32, 0.74, 0.82)}


class _HiddenModel:
    """Ground-truth energy/power/thermal model.  PRIVATE to repro.hw."""

    def __init__(self, chip: ChipSpec, cooling: str, seed: int,
                 coeff_scale: float = 1.0):
        self.chip = chip
        self.cooling = cooling
        self.seed = seed
        gdyn, gmem, gici = _GEN_SCALE[chip.isa_gen]
        self.coeffs: Dict[str, float] = {}
        for name, base in _BASE_COEFF.items():
            b = isa.bucket_of(name)
            if b in (isa.BUCKET_MEM,):
                scale = gmem
            elif b in (isa.BUCKET_ICI, isa.BUCKET_DCN):
                scale = gici
            else:
                scale = gdyn
            jitter = 0.85 + 0.30 * _stable_unit(seed, name)
            self.coeffs[name] = base * scale * jitter * coeff_scale
        # Static / constant power.
        self.p_const = chip.idle_watts * (0.95 + 0.10 * _stable_unit(seed, "pc"))
        self.p_static_full = chip.tdp_watts * (0.20 + 0.04 * _stable_unit(seed, "ps"))
        self.static_util_floor = 0.62      # P_static(util) = full*(floor+(1-floor)*util)
        # Dual-issue (VPU while MXU busy) energy discount.
        self.dual_issue_discount = 0.25
        # MXU alignment: energy penalty + throughput hit for misaligned dots.
        self.misaligned_energy_mult = 1.22
        self.mxu_eff_aligned = 0.92
        self.mxu_eff_misaligned = 0.52
        # Bit-toggle activity: per-program data-dependent switching factor on
        # compute/move dynamic energy.  Unknowable to any counts-based model;
        # microbenchmark loops have their own factors (absorbed into the
        # solved coefficients), applications have different ones — the
        # organic per-workload over/under-predictions of the paper's Fig. 6.
        self.toggle_spread = 0.70
        # DRAM row-locality: random-access traffic (gather/scatter) costs
        # more per byte than the streaming microbenchmarks measured.
        self.random_access_mult = 0.35
        # Power capping: programs pushing past ~92% of TDP get clock/voltage
        # throttled — longer runtime and slightly higher energy (the
        # microbenchmarks, each stressing one unit, never trip it).
        self.throttle_knee = 0.92
        self.throttle_energy_mult = 1.09
        self.throttle_time_mult = 1.18
        # DVFS truth: per-part binning makes the real exponents deviate from
        # the textbook CV²f / V-leakage laws — a counts-based model can only
        # learn them by calibrating at multiple points.  All scale factors
        # are *exactly* 1.0 at the nominal operating point, so the legacy
        # single-point behaviour is bit-for-bit unchanged.
        self.dyn_v_exp = 2.0 + 0.16 * (_stable_unit(seed, "vf:dyn") - 0.5)
        self.static_v_exp = 2.3 + 0.8 * _stable_unit(seed, "vf:leak")
        self.const_v_exp = 0.8 + 0.4 * _stable_unit(seed, "vf:const")
        self.e_dyn_scale = 1.0       # dynamic energy ~ V^dyn_v_exp
        self.t_core_scale = 1.0      # MXU/VPU/sequencer time ~ 1/f
        self.static_v_scale = 1.0    # leakage ~ V^static_v_exp
        self.const_v_scale = 1.0     # constant floor, weak V dependence
        self.cap_w = chip.tdp_watts  # effective throttle envelope
        # Private fusion/residency behaviour (XLA fusion + VMEM capacity).
        self.f_hbm_boundary = min(0.95, 0.88 * (0.95 + 0.1 * _stable_unit(seed, "fb")))
        self.fused_leak = 0.01        # fused traffic that still spills
        self.ws_knee_bytes = chip.vmem_capacity * 3 / 16
        # Thermal model.  Air runs much hotter at steady state; leakage
        # (static strongly, dynamic mildly) tracks die temperature — the
        # source of the paper's ~12% air-vs-water energy gap (§5.2.1).
        self.t_amb = 24.0
        if cooling == "liquid":
            self.tau_s, self.r_th = 8.0, 0.085    # K/W
        else:
            self.tau_s, self.r_th = 35.0, 0.35
        self.leak_per_k = 0.006                   # static leakage / K
        self.dyn_leak_per_k = 0.0025              # dynamic leakage / K
        self.t_ref = 45.0
        # Dispatch overheads (pipelined; small on TPU).
        self.startup_s = 1.8
        self.loop_lat_s = 5e-8
        self.dispatch_lat_s = 1.2e-7
        self.serial_frac = 0.08    # non-overlapped fraction of non-critical units
        # Static power wobbles with the active unit mix (clock gating) —
        # unknowable to a single-valued static model; dominates relative
        # error for workloads with a high static+const share (paper's RNNs).
        self.static_mix_mxu = 0.10
        self.static_mix_hbm = -0.08
        self.static_util_slope = 0.12
        # Vectorized truth over isa.CLASS_INDEX, lazily extended as new
        # classes are interned (same currency axis the public model uses —
        # the *values* stay private).
        self._vec_n = 0
        self._coeff_vec = np.zeros(0)       # J/unit per class id
        self._time_w = np.zeros(0)          # s/unit on the VPU-side units
        self._mxu_inv_rate = np.zeros(0)    # s/MAC (pre-efficiency) on MXU
        self._is_mxu = np.zeros(0, bool)
        self._is_vpu_like = np.zeros(0, bool)
        self._is_ici = np.zeros(0, bool)
        self._is_dcn = np.zeros(0, bool)

    # -- DVFS truth ----------------------------------------------------------
    def set_operating_point(self, vf: VfCurve, freq_mhz: float,
                            power_cap_w: float) -> None:
        """Re-derive the hidden DVFS scale factors for an operating point.

        At ``(vf.f_nom_mhz, tdp)`` every factor is exactly 1.0 (``1.0**x``
        and ``x/x`` are exact for finite floats), so pinning the nominal
        point is bitwise indistinguishable from never touching DVFS.
        """
        f_ratio = freq_mhz / vf.f_nom_mhz
        v_ratio = vf.voltage(freq_mhz) / vf.v_nom
        self.e_dyn_scale = v_ratio ** self.dyn_v_exp
        self.t_core_scale = 1.0 / f_ratio
        self.static_v_scale = v_ratio ** self.static_v_exp
        self.const_v_scale = v_ratio ** self.const_v_exp
        self.cap_w = min(power_cap_w, self.chip.tdp_watts)

    @property
    def p_const_eff(self) -> float:
        return self.p_const * self.const_v_scale

    # -- per-class truth with on-demand coefficients for unknown classes ----
    def coeff(self, cls: str) -> float:
        c = self.coeffs.get(cls)
        if c is not None:
            return c
        bucket = isa.bucket_of(cls) or isa.BUCKET_VPU_INT
        peers = [v for k, v in self.coeffs.items() if isa.bucket_of(k) == bucket]
        base = float(np.mean(peers)) if peers else 8e-12
        return base * (0.7 + 0.8 * _stable_unit(self.seed, "unk:" + cls))

    def _class_vectors(self, n: int) -> None:
        """Extend the per-class truth vectors to cover class ids < ``n``."""
        if n <= self._vec_n:
            return
        idx = isa.CLASS_INDEX
        codes = idx.bucket_codes(n)
        grow = range(self._vec_n, n)
        coeff = np.asarray([self.coeff(idx.name(i)) for i in grow])
        vpu = self.chip.vpu_throughput
        time_w = np.zeros(n - self._vec_n)
        inv_rate = np.zeros(n - self._vec_n)
        for j, i in enumerate(grow):
            b = isa.BUCKET_ORDER[codes[i]]
            if b == isa.BUCKET_MXU:
                inv_rate[j] = 1.0 / self._mxu_rate(idx.name(i))
            elif b == isa.BUCKET_VPU_TRANS:
                time_w[j] = 4.0 / vpu
            elif b in (isa.BUCKET_VPU_SIMPLE, isa.BUCKET_VPU_INT):
                time_w[j] = 1.0 / vpu
            elif b == isa.BUCKET_MOVE:
                time_w[j] = 1.0 / (vpu * 1.5)
        m = self._vec_n
        self._coeff_vec = np.concatenate([self._coeff_vec[:m], coeff])
        self._time_w = np.concatenate([self._time_w[:m], time_w])
        self._mxu_inv_rate = np.concatenate([self._mxu_inv_rate[:m], inv_rate])
        self._is_mxu = codes == isa.BUCKET_CODE[isa.BUCKET_MXU]
        self._is_vpu_like = np.isin(codes, [
            isa.BUCKET_CODE[b] for b in
            (isa.BUCKET_VPU_SIMPLE, isa.BUCKET_VPU_TRANS,
             isa.BUCKET_VPU_INT, isa.BUCKET_MOVE)])
        self._is_ici = codes == isa.BUCKET_CODE[isa.BUCKET_ICI]
        self._is_dcn = codes == isa.BUCKET_CODE[isa.BUCKET_DCN]
        self._vec_n = n

    # -- traffic truth -------------------------------------------------------
    def _f_hbm(self, c: OpCounts) -> float:
        # Boundary traffic reaches HBM only when the working set exceeds
        # VMEM residency (small benchmarks loop in VMEM; real models stream).
        ws = max(c.max_buffer_bytes, 1.0)
        cap = min(ws / self.ws_knee_bytes, 1.0)
        return max(self.f_hbm_boundary * cap, 0.01)

    def traffic(self, c: OpCounts):
        """(hbm_read, hbm_write, vmem_read, vmem_write) true bytes."""
        f = self._f_hbm(c)
        cap = f / self.f_hbm_boundary
        leak = c.fused_bytes * self.fused_leak * min(cap, 1.0)
        hbm_r = c.boundary_read_bytes * f + 0.5 * leak
        hbm_w = c.boundary_write_bytes * f + 0.5 * leak
        # on-chip tile loads/stores; fused intermediates live in VREGs
        vmem_r = c.boundary_read_bytes * (1.0 - f) * 0.95
        vmem_w = c.boundary_write_bytes * (1.0 - f) * 0.95
        return hbm_r, hbm_w, vmem_r, vmem_w

    def hbm_bytes(self, c: OpCounts) -> float:
        r, w, _, _ = self.traffic(c)
        return r + w

    # -- timing truth (roofline-based; public constants) ---------------------
    def _mxu_rate(self, cls: str) -> float:
        peak = self.chip.peak_bf16_macs
        table = {
            "dot.bf16": 1.0, "dot.f32": 0.25, "dot.int8": 2.0, "dot.fp8": 2.0,
            "sparse_dot.bf16": 1.6, "dot.int4": 3.2,
            "dot_small.bf16": 0.45, "dot_small.f32": 0.12,
            "dot_group.bf16": 1.15, "dot_group.f32": 0.28,
            "conv.bf16": 0.8, "conv.f32": 0.2,
        }
        return peak * table.get(cls, 1.0)

    def times(self, c: OpCounts):
        chip = self.chip
        v = c._vec
        n = v.size
        t_mxu = t_vpu = ici_bytes = dcn_bytes = loop_units = 0.0
        if n:
            self._class_vectors(n)
            frac_aligned = (c.mxu_macs_aligned / c.mxu_macs_total
                            if c.mxu_macs_total > 0 else 1.0)
            eff = (frac_aligned * self.mxu_eff_aligned
                   + (1 - frac_aligned) * self.mxu_eff_misaligned)
            t_mxu = (float(v @ self._mxu_inv_rate[:n])
                     / max(eff, 1e-3)) * self.t_core_scale
            t_vpu = float(v @ self._time_w[:n]) * self.t_core_scale
            ici_bytes = float(v[self._is_ici[:n]].sum())
            dcn_bytes = float(v[self._is_dcn[:n]].sum())
            loop_units = float(v[_CTL_LOOP_ID]) if n > _CTL_LOOP_ID else 0.0
        t_hbm = self.hbm_bytes(c) / (chip.hbm_bandwidth * 0.88)
        t_ici = ici_bytes / (chip.ici_links * chip.ici_link_bandwidth * 0.85)
        t_dcn = dcn_bytes / max(chip.dcn_bandwidth, 1.0)
        parts = [t_mxu, t_vpu, t_hbm, t_ici, t_dcn]
        crit = max(parts) if parts else 0.0
        busy = crit + self.serial_frac * (sum(parts) - crit)
        gap = (c.dispatch_count * self.dispatch_lat_s
               + loop_units * self.loop_lat_s) * self.t_core_scale
        t_iter = busy + gap
        util = busy / max(t_iter, 1e-12)
        return t_iter, t_mxu, t_vpu, t_hbm, t_ici + t_dcn, util

    # -- dynamic energy truth -------------------------------------------------
    def toggle_factor(self, context: str) -> float:
        lo = 1.0 - self.toggle_spread / 2.0
        return lo + self.toggle_spread * _stable_unit(self.seed, "tg:" + context)

    def random_access_frac(self, c: OpCounts) -> float:
        v = c._vec
        rand_elems = float(sum(v[i] for i in _RANDOM_ACCESS_IDS
                               if i < v.size))
        return min(rand_elems * 4.0 / max(c.boundary_bytes, 1.0), 1.0)

    def dynamic_energy(self, c: OpCounts, context: str = "") -> float:
        t_iter, t_mxu, t_vpu, _, _, _ = self.times(c)
        overlap = min(t_mxu, t_vpu) / max(t_iter, 1e-12)
        vpu_mult = 1.0 - self.dual_issue_discount * overlap
        frac_aligned = (c.mxu_macs_aligned / c.mxu_macs_total
                        if c.mxu_macs_total > 0 else 1.0)
        mxu_mult = (frac_aligned * 1.0
                    + (1 - frac_aligned) * self.misaligned_energy_mult)
        toggle = self.toggle_factor(context)
        v = c._vec
        n = v.size
        e = 0.0
        if n:
            self._class_vectors(n)
            # Core-rail dynamic energy scales with V² (MXU/VPU/move); the
            # off-chip HBM rail and the ICI/DCN serdes do not follow the
            # core DVFS rail.
            factor = np.ones(n)
            factor[self._is_mxu[:n]] = mxu_mult * toggle * self.e_dyn_scale
            factor[self._is_vpu_like[:n]] = vpu_mult * toggle * self.e_dyn_scale
            e = float(np.sum(v * self._coeff_vec[:n] * factor))
        hbm_r, hbm_w, vmem_r, vmem_w = self.traffic(c)
        row_mult = 1.0 + self.random_access_mult * self.random_access_frac(c)
        # per-program access-pattern factor (row-buffer locality, banking)
        row_mult *= 0.85 + 0.30 * _stable_unit(self.seed, "mem:" + context)
        e += (hbm_r * self.coeff("hbm.read")
              + hbm_w * self.coeff("hbm.write")) * row_mult
        e += (vmem_r * self.coeff("vmem.read")
              + vmem_w * self.coeff("vmem.write")) * self.e_dyn_scale
        return e

    def static_power(self, util: float, temp_c: float,
                     mix_mult: float = 1.0) -> float:
        leak = 1.0 + self.leak_per_k * (temp_c - self.t_ref)
        u = 1.0 + self.static_util_slope * (util - 1.0)
        return (self.p_static_full * u * mix_mult * max(leak, 0.5)
                * self.static_v_scale)

    def static_mix(self, c: OpCounts, context: str = "") -> float:
        """Unit-mix clock-gating wobble on static power (structural part)
        plus a per-program residual (layout/placement effects)."""
        t_iter, t_mxu, _, t_hbm, _, _ = self.times(c)
        mxu_share = t_mxu / max(t_iter, 1e-12)
        hbm_share = t_hbm / max(t_iter, 1e-12)
        resid = 0.94 + 0.12 * _stable_unit(self.seed, "sm:" + context)
        return (1.0 + self.static_mix_mxu * mxu_share
                + self.static_mix_hbm * hbm_share) * resid


# ---------------------------------------------------------------------------
# The device.
# ---------------------------------------------------------------------------
class SimDevice:
    """One simulated accelerator of a given system configuration."""

    def __init__(self, chip: ChipSpec, cooling: str = "air", seed: int = 0,
                 name: Optional[str] = None, coeff_scale: float = 1.0,
                 vf_model: Optional[VfCurve] = None):
        self.chip = chip
        self.cooling = cooling
        self.seed = seed
        self.name = name or f"sim-{chip.name}-{cooling}"
        self.vf = vf_model or chip.vf_curve
        self._hidden = _HiddenModel(chip, cooling, seed, coeff_scale)
        self._nominal = OperatingPoint(self.vf.f_nom_mhz, chip.tdp_watts)
        self._point = self._nominal
        self._rng = np.random.default_rng(seed ^ 0x5EED)

    # -- DVFS control (the knobs a real driver exposes) -----------------------
    @property
    def operating_point(self) -> OperatingPoint:
        return self._point

    @property
    def nominal_point(self) -> OperatingPoint:
        return self._nominal

    def set_frequency(self, freq_mhz: float) -> OperatingPoint:
        """Pin the core clock; keeps the current power cap."""
        return self.set_operating_point(freq_mhz, self._point.power_cap_w)

    def set_power_cap(self, watts: float) -> OperatingPoint:
        """Set the software power cap; keeps the current frequency."""
        return self.set_operating_point(self._point.freq_mhz, watts)

    def set_operating_point(self, point, power_cap_w: Optional[float] = None
                            ) -> OperatingPoint:
        """Pin the device to an operating point.

        ``point`` may be an :class:`OperatingPoint`, a ``(freq_mhz, cap_w)``
        tuple, or a bare frequency in MHz (cap then from ``power_cap_w`` or
        the chip TDP).  Pinning the nominal point is bitwise equivalent to a
        device that never touched DVFS.
        """
        if hasattr(point, "freq_mhz"):
            freq = float(point.freq_mhz)
            cap = getattr(point, "power_cap_w", None)
        elif isinstance(point, (tuple, list)):
            freq, cap = point
            freq = float(freq)
        else:
            freq, cap = float(point), None
        if power_cap_w is not None:
            cap = power_cap_w
        cap = self.chip.tdp_watts if cap is None else float(cap)
        vf = self.vf
        if not (vf.f_min_mhz <= freq <= vf.f_max_mhz):
            raise ValueError(
                f"{self.name}: frequency {freq:g} MHz outside the DVFS "
                f"range [{vf.f_min_mhz:g}, {vf.f_max_mhz:g}]")
        if cap <= self.chip.idle_watts:
            raise ValueError(
                f"{self.name}: power cap {cap:g} W is below the idle floor "
                f"({self.chip.idle_watts:g} W)")
        cap = min(cap, self.chip.tdp_watts)
        self._point = OperatingPoint(freq, cap)
        self._hidden.set_operating_point(vf, freq, cap)
        return self._point

    def reset_operating_point(self) -> OperatingPoint:
        return self.set_operating_point(self._nominal)

    def noise_rng(self, noise_key: Optional[str]) -> np.random.Generator:
        """Sensor-noise stream for a run.

        Real sensors are stateless: the noise a measurement sees does not
        depend on which measurements ran before it.  A ``noise_key`` gives a
        run its own deterministic substream keyed on (device seed, key), so
        a measurement campaign can be interrupted, resumed, or reordered and
        every record stays bit-identical.  Without a key, runs share the
        device-lifetime stream (legacy sequential behaviour).
        """
        if noise_key is None:
            return self._rng
        digest = hashlib.sha256(
            f"{self.seed}:noise:{noise_key}".encode()).digest()
        return np.random.default_rng(int.from_bytes(digest[:8], "big"))

    # -- telemetry synthesis --------------------------------------------------
    def _sample_trace(self, duration_s: float, p_dyn: float, util: float,
                      startup_s: float, static_mix: float = 1.0,
                      rng: Optional[np.random.Generator] = None) -> SensorTrace:
        h = self._hidden
        n = max(int(duration_s * SENSOR_HZ), 4)
        ts = np.arange(n) / SENSOR_HZ
        # thermal integration
        temp = np.empty(n)
        t_cur = h.t_amb + 8.0
        dt = 1.0 / SENSOR_HZ
        power_true = np.empty(n)
        for i, t in enumerate(ts):
            ramp = min(t / max(startup_s, 1e-9), 1.0)
            u = util * ramp
            dyn_leak = 1.0 + h.dyn_leak_per_k * (t_cur - h.t_ref)
            p_s = (h.static_power(u, t_cur, static_mix) if u > 0 else 0.0)
            p = h.p_const_eff + p_s + p_dyn * ramp * dyn_leak
            t_ss = h.t_amb + h.r_th * p
            t_cur += (t_ss - t_cur) * (dt / h.tau_s)
            temp[i] = t_cur
            power_true[i] = (h.p_const_eff
                             + (h.static_power(u, t_cur, static_mix)
                                if u > 0 else 0.0)
                             + p_dyn * ramp * max(dyn_leak, 0.7))
        rng = self._rng if rng is None else rng
        noise = rng.normal(0.0, SENSOR_NOISE_W, n)
        power_meas = np.round((power_true + noise) / SENSOR_QUANT_W) * SENSOR_QUANT_W
        keep = rng.random(n) >= SENSOR_DROP_P
        keep[0] = keep[-1] = True
        util_arr = np.clip(np.minimum(ts / max(startup_s, 1e-9), 1.0) * util, 0, 1)
        trace = SensorTrace(ts[keep], power_meas[keep], util_arr[keep], temp[keep])
        # exact energy counter (trapezoidal over the true power)
        energy = float(np.trapezoid(power_true, ts))
        trace._energy_true = energy  # type: ignore[attr-defined]
        return trace

    def idle(self, duration_s: float = 30.0, *,
             noise_key: Optional[str] = None) -> SensorTrace:
        """Sensor samples while the device is idle (constant-power probe)."""
        return self._sample_trace(duration_s, p_dyn=0.0, util=0.0,
                                  startup_s=1e9, rng=self.noise_rng(noise_key))

    def run(self, program: Program, *,
            noise_key: Optional[str] = None) -> RunRecord:
        h = self._hidden
        c = program.counts_per_iter
        if program.is_nanosleep:
            # Active-but-idle: sequencer spins, static fully powered
            # (Oles et al.'s ~80W observation, paper §3.3.1).
            t_iter = (max(c.units.get("ctl.loop", 1.0), 1.0)
                      * h.loop_lat_s) * h.t_core_scale
            e_iter = (c.units.get("ctl.loop", 0.0)
                      * h.coeff("ctl.loop")) * h.e_dyn_scale
            util, static_mix = 1.0, 1.0
        else:
            t_iter, _, _, _, _, util = h.times(c)
            e_iter = h.dynamic_energy(c, context=program.name)
            static_mix = h.static_mix(c, context=program.name)
            # power-cap throttling for programs pushing past the envelope
            # (the TDP knee by default; a tighter software cap when set)
            p_est = (h.p_const_eff + h.p_static_full * h.static_v_scale
                     + e_iter / max(t_iter, 1e-12))
            if p_est > h.throttle_knee * h.cap_w:
                e_iter *= h.throttle_energy_mult
                t_iter *= h.throttle_time_mult
        launch_spans = None
        if program.launches:
            launch_spans = self._launch_spans(program.launches, t_iter)
        duration = h.startup_s + program.iters * t_iter
        p_dyn = (program.iters * e_iter) / max(duration - h.startup_s, 1e-9)
        trace = self._sample_trace(duration, p_dyn, util, h.startup_s,
                                   static_mix, rng=self.noise_rng(noise_key))
        energy = trace._energy_true  # type: ignore[attr-defined]
        hbm_r, hbm_w, vmem_r, vmem_w = h.traffic(c)
        counters = {
            "hbm_read_bytes": hbm_r * program.iters,
            "hbm_write_bytes": hbm_w * program.iters,
            "vmem_read_bytes": vmem_r * program.iters,
            "vmem_write_bytes": vmem_w * program.iters,
            "duration_s": duration,
            "iters": program.iters,
        }
        return RunRecord(name=program.name, duration_s=duration,
                         iters=program.iters, trace=trace,
                         energy_counter_j=energy, counters=counters,
                         freq_mhz=self._point.freq_mhz,
                         power_cap_w=self._point.power_cap_w,
                         launch_spans=launch_spans)

    def _launch_spans(self, launches, t_iter: float):
        """Profiler-style timestamps for declared launches, as fractions of
        one iteration.  Each launch is timed by the same roofline that times
        whole programs; launches are placed back to back from the start of
        the iteration and squeezed to fit when their stand-alone times
        overcommit the fused iteration (overlap the roofline max hides).
        The tail past the last launch is the unattributed remainder."""
        h = self._hidden
        durs = [h.times(l.counts)[0] for l in launches]
        total = sum(durs)
        scale = t_iter / total if total > t_iter > 0 else 1.0
        spans, cursor = [], 0.0
        for launch, d in zip(launches, durs):
            frac = (d * scale) / t_iter if t_iter > 0 else 0.0
            end = min(cursor + frac, 1.0)
            spans.append(LaunchSpan(launch.name, launch.variant,
                                    tuple(launch.config), cursor, end))
            cursor = end
        return spans

    # Iteration sizing helper so microbenchmarks reach steady state (§3.3).
    def iters_for_duration(self, counts_per_iter: OpCounts,
                           target_s: float) -> int:
        """Calibrate iteration count to a target runtime (in practice this is
        a short timing pre-run; here the device answers directly)."""
        t_iter = self._hidden.times(counts_per_iter)[0]
        return max(int(target_s / max(t_iter, 1e-9)), 1)
