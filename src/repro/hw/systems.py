"""Registry of simulated systems — the paper's Table 2 analogue.

| Paper cluster (GPU)        | Here                 | Role                     |
|----------------------------|----------------------|--------------------------|
| CloudLab  V100 (air)       | ``sim-v5e-air``      | primary modeled system   |
| Summit    V100 (water)     | ``sim-v5e-liquid``   | cooling generalization   |
| Lonestar6 A100 (air)       | ``sim-v5p-air``      | next-gen generalization  |
| Lonestar6 H100 (air)       | ``sim-v6e-air``      | two-gen generalization   |
| AccelWattch's own V100     | ``sim-v5e-ref``      | the *differently-configured*
                                                      reference environment the
                                                      AccelWattch-style baseline
                                                      was calibrated on (§2.3.1) |
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.hw import spec
from repro.hw.device import SimDevice


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    chip: spec.ChipSpec
    cooling: str
    seed: int
    coeff_scale: float = 1.0     # binning/voltage-point scaling


SYSTEMS: Dict[str, SystemConfig] = {
    "sim-v5e-air": SystemConfig("sim-v5e-air", spec.V5E, "air", seed=101),
    "sim-v5e-liquid": SystemConfig("sim-v5e-liquid", spec.V5E, "liquid", seed=101),
    "sim-v5p-air": SystemConfig("sim-v5p-air", spec.V5P, "air", seed=202),
    "sim-v6e-air": SystemConfig("sim-v6e-air", spec.V6E, "air", seed=303),
    # Same chip family, *different environment*: different binning seed, a
    # different power envelope and a lower voltage/frequency point —
    # AccelWattch's "validated V100" that does not match the deployment V100
    # (TDP 300 vs 250 W, 1417 vs 1530 MHz etc., paper §2.3.1).
    "sim-v5e-ref": SystemConfig(
        "sim-v5e-ref",
        dataclasses.replace(spec.V5E, tdp_watts=250.0, idle_watts=34.0,
                            name="v5e"),
        "air", seed=777, coeff_scale=0.55),
}


def get_device(name: str) -> SimDevice:
    cfg = SYSTEMS[name]
    return SimDevice(cfg.chip, cfg.cooling, cfg.seed, name=cfg.name,
                     coeff_scale=cfg.coeff_scale)
