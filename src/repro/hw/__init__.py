from repro.hw.spec import CHIPS, V5E, V5P, V6E, ChipSpec
from repro.hw.device import Program, RunRecord, SensorTrace, SimDevice
from repro.hw.systems import SYSTEMS, SystemConfig, get_device

__all__ = [
    "CHIPS", "V5E", "V5P", "V6E", "ChipSpec",
    "Program", "RunRecord", "SensorTrace", "SimDevice",
    "SYSTEMS", "SystemConfig", "get_device",
]
