"""Hardware specs for the simulated TPU systems and the roofline constants.

The v5e numbers (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) are the
roofline constants mandated for §Roofline; the v5p/v6e entries are the
"newer generation" systems of the paper's A100/H100 experiments (§5.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class VfCurve:
    """Public datasheet-level DVFS description of a chip.

    Frequencies are core-clock MHz; voltages are normalized to the nominal
    rail (``v_nom == 1.0`` by convention).  ``voltage`` is the piecewise-
    linear V/f curve between the three published corners — the *public* part
    of DVFS.  Per-part binning deviations live in the hidden device model.
    """

    f_nom_mhz: float
    f_min_mhz: float
    f_max_mhz: float
    v_nom: float = 1.0
    v_min: float = 0.76
    v_max: float = 1.10

    def clamp(self, freq_mhz: float) -> float:
        return min(max(float(freq_mhz), self.f_min_mhz), self.f_max_mhz)

    def voltage(self, freq_mhz: float) -> float:
        """Rail voltage (normalized) at ``freq_mhz``; exact ``v_nom`` at
        nominal so the nominal operating point is bit-reproducible."""
        f = float(freq_mhz)
        if f == self.f_nom_mhz:
            return self.v_nom
        if f <= self.f_min_mhz:
            return self.v_min
        if f >= self.f_max_mhz:
            return self.v_max
        if f < self.f_nom_mhz:
            w = (f - self.f_min_mhz) / (self.f_nom_mhz - self.f_min_mhz)
            return self.v_min + w * (self.v_nom - self.v_min)
        w = (f - self.f_nom_mhz) / (self.f_max_mhz - self.f_nom_mhz)
        return self.v_nom + w * (self.v_max - self.v_nom)

    def grid(self, n: int) -> list:
        """``n`` evenly spaced frequencies spanning the DVFS range, snapped
        to whole MHz, always containing the nominal frequency."""
        if n <= 1:
            return [self.f_nom_mhz]
        span = self.f_max_mhz - self.f_min_mhz
        pts = {round(self.f_min_mhz + span * k / (n - 1)) * 1.0
               for k in range(n)}
        pts.add(self.f_nom_mhz)
        return sorted(pts)


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Static per-chip hardware description (public datasheet-level facts)."""

    name: str
    # Compute.
    peak_bf16_flops: float       # FLOP/s
    peak_f32_flops: float        # FLOP/s (MXU f32 path)
    peak_int8_ops: float         # OP/s
    vpu_throughput: float        # elementwise ops/s (vector unit)
    # Memory.
    hbm_bandwidth: float         # bytes/s
    hbm_capacity: float          # bytes
    vmem_capacity: float         # bytes
    # Interconnect.
    ici_link_bandwidth: float    # bytes/s per link
    ici_links: int               # links per chip
    dcn_bandwidth: float         # bytes/s per chip for cross-pod traffic
    # Power envelope (public TDP-level facts; *not* the hidden energy model).
    tdp_watts: float
    idle_watts: float
    # ISA generation tag — newer gens add op classes (fp8 / sparse dots).
    isa_gen: int = 0
    # DVFS range (datasheet-level); None means "fixed-clock part".
    vf: Optional[VfCurve] = None

    @property
    def peak_bf16_macs(self) -> float:
        return self.peak_bf16_flops / 2.0

    @property
    def vf_curve(self) -> VfCurve:
        """The chip's V/f curve, synthesizing a conservative single-point
        curve for fixed-clock parts so every device has an operating point."""
        if self.vf is not None:
            return self.vf
        return VfCurve(f_nom_mhz=940.0, f_min_mhz=940.0, f_max_mhz=940.0)


# TPU v5e — the primary target (and the mandated roofline constants).
V5E = ChipSpec(
    name="v5e",
    peak_bf16_flops=197e12,
    peak_f32_flops=49.25e12,     # 1/4 of bf16 MXU rate
    peak_int8_ops=394e12,
    vpu_throughput=7.9e12,       # 8 * 128 lanes * ~0.94GHz * 8 subcores-equivalent
    hbm_bandwidth=819e9,
    hbm_capacity=16 * 2**30,
    vmem_capacity=128 * 2**20,
    ici_link_bandwidth=50e9,
    ici_links=4,
    dcn_bandwidth=12.5e9,
    tdp_watts=215.0,
    idle_watts=42.0,
    isa_gen=0,
    vf=VfCurve(f_nom_mhz=940.0, f_min_mhz=564.0, f_max_mhz=1128.0),
)

# TPU v5p — "next generation" system (paper's A100 role).
V5P = ChipSpec(
    name="v5p",
    peak_bf16_flops=459e12,
    peak_f32_flops=114.75e12,
    peak_int8_ops=918e12,
    vpu_throughput=14.7e12,
    hbm_bandwidth=2.765e12,
    hbm_capacity=95 * 2**30,
    vmem_capacity=128 * 2**20,
    ici_link_bandwidth=100e9,
    ici_links=6,
    dcn_bandwidth=25e9,
    tdp_watts=350.0,
    idle_watts=68.0,
    isa_gen=1,
    vf=VfCurve(f_nom_mhz=1075.0, f_min_mhz=645.0, f_max_mhz=1290.0),
)

# TPU v6e — two generations ahead (paper's H100 role); adds fp8/sparse classes.
V6E = ChipSpec(
    name="v6e",
    peak_bf16_flops=918e12,
    peak_f32_flops=229.5e12,
    peak_int8_ops=1836e12,
    vpu_throughput=23.2e12,
    hbm_bandwidth=1.64e12,
    hbm_capacity=32 * 2**30,
    vmem_capacity=160 * 2**20,
    ici_link_bandwidth=90e9,
    ici_links=4,
    dcn_bandwidth=25e9,
    tdp_watts=300.0,
    idle_watts=55.0,
    isa_gen=2,
    vf=VfCurve(f_nom_mhz=940.0, f_min_mhz=564.0, f_max_mhz=1128.0),
)

CHIPS = {c.name: c for c in (V5E, V5P, V6E)}
