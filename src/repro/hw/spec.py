"""Hardware specs for the simulated TPU systems and the roofline constants.

The v5e numbers (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) are the
roofline constants mandated for §Roofline; the v5p/v6e entries are the
"newer generation" systems of the paper's A100/H100 experiments (§5.2).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Static per-chip hardware description (public datasheet-level facts)."""

    name: str
    # Compute.
    peak_bf16_flops: float       # FLOP/s
    peak_f32_flops: float        # FLOP/s (MXU f32 path)
    peak_int8_ops: float         # OP/s
    vpu_throughput: float        # elementwise ops/s (vector unit)
    # Memory.
    hbm_bandwidth: float         # bytes/s
    hbm_capacity: float          # bytes
    vmem_capacity: float         # bytes
    # Interconnect.
    ici_link_bandwidth: float    # bytes/s per link
    ici_links: int               # links per chip
    dcn_bandwidth: float         # bytes/s per chip for cross-pod traffic
    # Power envelope (public TDP-level facts; *not* the hidden energy model).
    tdp_watts: float
    idle_watts: float
    # ISA generation tag — newer gens add op classes (fp8 / sparse dots).
    isa_gen: int = 0

    @property
    def peak_bf16_macs(self) -> float:
        return self.peak_bf16_flops / 2.0


# TPU v5e — the primary target (and the mandated roofline constants).
V5E = ChipSpec(
    name="v5e",
    peak_bf16_flops=197e12,
    peak_f32_flops=49.25e12,     # 1/4 of bf16 MXU rate
    peak_int8_ops=394e12,
    vpu_throughput=7.9e12,       # 8 * 128 lanes * ~0.94GHz * 8 subcores-equivalent
    hbm_bandwidth=819e9,
    hbm_capacity=16 * 2**30,
    vmem_capacity=128 * 2**20,
    ici_link_bandwidth=50e9,
    ici_links=4,
    dcn_bandwidth=12.5e9,
    tdp_watts=215.0,
    idle_watts=42.0,
    isa_gen=0,
)

# TPU v5p — "next generation" system (paper's A100 role).
V5P = ChipSpec(
    name="v5p",
    peak_bf16_flops=459e12,
    peak_f32_flops=114.75e12,
    peak_int8_ops=918e12,
    vpu_throughput=14.7e12,
    hbm_bandwidth=2.765e12,
    hbm_capacity=95 * 2**30,
    vmem_capacity=128 * 2**20,
    ici_link_bandwidth=100e9,
    ici_links=6,
    dcn_bandwidth=25e9,
    tdp_watts=350.0,
    idle_watts=68.0,
    isa_gen=1,
)

# TPU v6e — two generations ahead (paper's H100 role); adds fp8/sparse classes.
V6E = ChipSpec(
    name="v6e",
    peak_bf16_flops=918e12,
    peak_f32_flops=229.5e12,
    peak_int8_ops=1836e12,
    vpu_throughput=23.2e12,
    hbm_bandwidth=1.64e12,
    hbm_capacity=32 * 2**30,
    vmem_capacity=160 * 2**20,
    ici_link_bandwidth=90e9,
    ici_links=4,
    dcn_bandwidth=25e9,
    tdp_watts=300.0,
    idle_watts=55.0,
    isa_gen=2,
)

CHIPS = {c.name: c for c in (V5E, V5P, V6E)}
