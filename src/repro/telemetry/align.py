"""MTSM-style marker synchronization — measured joules per step.

Arafa et al.'s Multi-Threaded Synchronized Monitoring runs a sampling
thread beside the application and aligns kernel begin/end markers against
the sampled power signal to attribute *measured* energy to individual
kernels.  ``StreamAligner`` is that alignment, online:

* Markers are time windows ``[t_start, t_end)`` in the trace's clock,
  added in time order (a production app emits one as each step/kernel
  retires — typically *after* the samples inside it have been produced).
* Samples are ingested in time order.  Samples beyond the latest marker's
  end are held back, so a marker that arrives late still receives every
  joule inside its window — the monitor thread lags the sync points, never
  the other way around.
* Window energy uses partial trapezoids: sample segments crossing a marker
  boundary are split by linear interpolation at the boundary, so windows
  that tile the run sum to the whole-run integral exactly (float
  round-off aside).

Edge cases are explicit: a window before the first sample or after the
last yields what its overlap with the trace supports and is flagged
``clipped``; a window strictly between two samples gets the interpolated
energy of its span.

``align_trace`` is the offline wrapper — same engine, whole trace in.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.hw.device import SensorTrace
from repro.telemetry.sampler import PowerSample

_EPS = 1e-12

# Sampling-gap inference: the first _GAP_PROBE positive inter-sample dts
# establish the sensor cadence; later segments longer than _GAP_FACTOR x
# the median cadence are classified as gaps.  Probe segments themselves
# are never classified — identically on the scalar and chunked paths, so
# gap accounting stays bitwise chunk-layout-invariant.
_GAP_PROBE = 64
_GAP_FACTOR = 1.5


UNATTRIBUTED = "__unattributed__"    # kernel-window filler for idle gaps


@dataclasses.dataclass(frozen=True)
class Marker:
    """One step/kernel window in the sampled trace's clock."""

    step: int
    name: str
    t_start_s: float
    t_end_s: float
    variant: str = ""           # kernel windows: implementation variant
    config: tuple = ()          # kernel windows: block configuration

    def __post_init__(self):
        if self.t_end_s < self.t_start_s:
            raise ValueError(f"marker {self.name!r}: t_end {self.t_end_s} "
                             f"< t_start {self.t_start_s}")

    @property
    def duration_s(self) -> float:
        return self.t_end_s - self.t_start_s


@dataclasses.dataclass
class AlignedWindow:
    """Measured energy attributed to one marker.

    A step window aligned with kernel sub-markers carries its per-launch
    ``children`` (kernel windows plus the ``__unattributed__`` remainder);
    its ``measured_j`` is then *defined* as the left-to-right sum of the
    children's energies, so ``sum(c.measured_j for c in w.children)``
    reproduces ``w.measured_j`` bitwise — the same guarantee class as step
    windows tiling the run total.
    """

    step: int
    name: str
    t_start_s: float
    t_end_s: float
    measured_j: float
    n_samples: int              # samples with t in [t_start, t_end)
    covered_s: float            # span actually backed by samples
    clipped: bool               # trace did not fully cover the window
    variant: str = ""
    config: tuple = ()
    # gap accounting: the part of measured_j that was *interpolated
    # across* sampling gaps (segments longer than the gap threshold)
    # rather than backed by dense samples.  measured_j itself is
    # untouched — it still tiles the run total exactly; gap_j/gap_s
    # report which portion of it is a gap estimate.
    gap_j: float = 0.0
    gap_s: float = 0.0
    children: Optional[List["AlignedWindow"]] = None

    @property
    def duration_s(self) -> float:
        return self.t_end_s - self.t_start_s

    @property
    def mean_power_w(self) -> float:
        return self.measured_j / max(self.duration_s, _EPS)

    @property
    def gap_fraction(self) -> float:
        """Fraction of the window's span estimated across sampling gaps."""
        return self.gap_s / max(self.duration_s, _EPS)

    @property
    def solid_coverage(self) -> float:
        """Fraction of the span backed by dense (non-gap) samples."""
        return (self.covered_s - self.gap_s) / max(self.duration_s, _EPS)

    @property
    def solid_j(self) -> float:
        """Energy excluding the gap-interpolated portion (derived)."""
        return self.measured_j - self.gap_j


class _Accum:
    __slots__ = ("marker", "energy_j", "n_samples", "covered_s",
                 "gap_j", "gap_s")

    children = None             # plain windows have no sub-accumulators

    def __init__(self, marker: Marker):
        self.marker = marker
        self.energy_j = 0.0
        self.n_samples = 0
        self.covered_s = 0.0
        self.gap_j = 0.0
        self.gap_s = 0.0

    def finish(self) -> AlignedWindow:
        m = self.marker
        clipped = self.covered_s + 1e-9 < m.duration_s
        return AlignedWindow(step=m.step, name=m.name, t_start_s=m.t_start_s,
                             t_end_s=m.t_end_s, measured_j=self.energy_j,
                             n_samples=self.n_samples,
                             covered_s=self.covered_s, clipped=clipped,
                             variant=m.variant, config=m.config,
                             gap_j=self.gap_j, gap_s=self.gap_s)


class _GroupAccum(_Accum):
    """A step accumulator subdivided into kernel-window accumulators.

    The children receive the actual split-trapezoid accumulation (the same
    expressions, in the same order, as any top-level window); the parent's
    totals are assembled from the finished children left to right, which is
    what makes the kernel→step tiling exact by construction rather than
    approximate by re-splitting.
    """

    __slots__ = ("children",)

    def __init__(self, marker: Marker, children: Sequence[Marker]):
        super().__init__(marker)
        self.children = [_Accum(c) for c in children]

    def finish(self) -> AlignedWindow:
        kids = [c.finish() for c in self.children]
        energy = 0.0
        n_samples = 0
        covered = 0.0
        gap_j = 0.0
        gap_s = 0.0
        for k in kids:
            energy += k.measured_j
            n_samples += k.n_samples
            covered += k.covered_s
            gap_j += k.gap_j
            gap_s += k.gap_s
        m = self.marker
        clipped = covered + 1e-9 < m.duration_s
        return AlignedWindow(step=m.step, name=m.name, t_start_s=m.t_start_s,
                             t_end_s=m.t_end_s, measured_j=energy,
                             n_samples=n_samples, covered_s=covered,
                             clipped=clipped, variant=m.variant,
                             config=m.config, gap_j=gap_j, gap_s=gap_s,
                             children=kids)


class StreamAligner:
    """Online marker↔sample alignment (see module docstring).

    ``on_window`` is called with each finalized ``AlignedWindow``; finished
    windows also accumulate in ``windows``.
    """

    def __init__(self,
                 on_window: Optional[Callable[[AlignedWindow], None]] = None,
                 gap_threshold_s: Optional[float] = None):
        self.windows: List[AlignedWindow] = []
        self._on_window = on_window
        self._active: deque = deque()       # _Accum, by marker time order
        self._held: deque = deque()         # scalar samples beyond horizon
        self._held_np: deque = deque()      # (t, p) array chunks beyond it
        self._horizon = -math.inf           # latest marker end seen
        self._t_prev: Optional[float] = None
        self._p_prev = 0.0
        self._last_marker_end = -math.inf
        # gap accounting: None/0 auto-infers the threshold from the first
        # _GAP_PROBE inter-sample dts (probe segments stay unclassified)
        self.gap_threshold_s = (float(gap_threshold_s) if gap_threshold_s
                                else None)
        self._gap_probe: List[float] = []
        self.gap_events = 0
        self.gap_seconds = 0.0
        self.gap_joules = 0.0
        self.gaps: List[tuple] = []         # (t_start, t_end) per gap segment

    # -- inputs -------------------------------------------------------------
    def add_marker(self, marker: Marker,
                   children: Optional[Sequence[Marker]] = None) -> None:
        """Register the next window; ``children`` subdivides it.

        Child markers (per-launch kernel windows) must *exactly* tile the
        parent span: the first child starts at the parent's start, each
        child starts where the previous one ends (bit-for-bit — build them
        with :func:`subdivide_marker`), and the last child ends at the
        parent's end.  Gaps and overlaps are rejected; zero-duration
        children are fine.
        """
        if marker.t_start_s < self._last_marker_end - 1e-9:
            raise ValueError(
                f"marker {marker.name!r} starts at {marker.t_start_s} "
                f"inside the previous window (ends {self._last_marker_end}); "
                f"markers must be time-ordered and non-overlapping")
        if children is not None:
            kids = list(children)
            if not kids:
                raise ValueError(f"marker {marker.name!r}: children given "
                                 "but empty; pass None for a plain window")
            cursor = marker.t_start_s
            for c in kids:
                if c.t_start_s != cursor:
                    raise ValueError(
                        f"kernel windows must exactly tile their step "
                        f"window: child {c.name!r} starts at {c.t_start_s!r}"
                        f" but the tiling cursor is at {cursor!r} "
                        f"(no gaps or overlaps)")
                cursor = c.t_end_s
            if cursor != marker.t_end_s:
                raise ValueError(
                    f"kernel windows must exactly tile their step window: "
                    f"last child ends at {cursor!r}, step ends at "
                    f"{marker.t_end_s!r}")
            self._active.append(_GroupAccum(marker, kids))
        else:
            self._active.append(_Accum(marker))
        self._last_marker_end = marker.t_end_s
        self._horizon = max(self._horizon, marker.t_end_s)
        self._drain()

    def add_sample(self, sample: PowerSample) -> None:
        if self._held_np:      # array chunks pending: keep one time order
            self.add_samples(np.asarray([sample.t_s]),
                             np.asarray([sample.power_w]))
            return
        self._held.append((float(sample.t_s), float(sample.power_w)))
        self._drain()

    def add_samples(self, times_s, power_w) -> None:
        """Chunked ingestion: one ndarray of samples, one vectorized pass.

        Samples must still arrive in time order (within and across chunks,
        and relative to any ``add_sample`` calls).  Held-back samples beyond
        the marker horizon stay as array chunks and are split by
        ``searchsorted`` as markers extend the horizon.
        """
        t = np.asarray(times_s, dtype=float)
        p = np.asarray(power_w, dtype=float)
        if t.size == 0:
            return
        if self._held:         # flush scalar held-backs ahead of the chunk
            sc = np.asarray(self._held, dtype=float)
            self._held.clear()
            self._held_np.append((sc[:, 0], sc[:, 1]))
        self._held_np.append((t, p))
        self._drain()

    def extend(self, samples: Iterable[PowerSample]) -> None:
        for s in samples:
            self.add_sample(s)

    def close(self) -> List[AlignedWindow]:
        """Flush held samples and finalize every remaining window."""
        self._horizon = math.inf
        self._drain()
        while self._active:
            self._finalize(self._active.popleft())
        return self.windows

    # -- engine -------------------------------------------------------------
    def _drain(self) -> None:
        while self._held and self._held[0][0] <= self._horizon:
            t, p = self._held.popleft()
            self._process(t, p)
        while self._held_np:
            t, p = self._held_np[0]
            n = int(np.searchsorted(t, self._horizon, side="right"))
            if n == 0:
                return
            self._held_np.popleft()
            if n < t.size:
                self._held_np.appendleft((t[n:], p[n:]))
                self._process_chunk(t[:n], p[:n])
                return
            self._process_chunk(t, p)

    def _classify_gap(self, dt: float) -> bool:
        """Gap-classify one positive segment dt (scalar path)."""
        if self.gap_threshold_s is None:
            self._gap_probe.append(float(dt))
            if len(self._gap_probe) >= _GAP_PROBE:
                self.gap_threshold_s = _GAP_FACTOR * float(
                    np.median(self._gap_probe))
            return False         # probe segments stay unclassified
        return dt > self.gap_threshold_s

    def _classify_gap_chunk(self, t0s: np.ndarray,
                            t1s: np.ndarray) -> Optional[np.ndarray]:
        """Vectorized ``_classify_gap`` over a chunk's segments.

        Replicates the scalar path exactly: only positive dts feed the
        probe, the segment that completes the probe stays unclassified,
        and classification starts with the next segment.
        """
        if t0s.size == 0:
            return None
        dts = t1s - t0s
        if self.gap_threshold_s is not None:
            return dts > self.gap_threshold_s
        out = np.zeros(dts.size, dtype=bool)
        k = 0
        while k < dts.size:
            dt = float(dts[k])
            k += 1
            if dt > 0:
                self._gap_probe.append(dt)
                if len(self._gap_probe) >= _GAP_PROBE:
                    self.gap_threshold_s = _GAP_FACTOR * float(
                        np.median(self._gap_probe))
                    break
        if self.gap_threshold_s is not None and k < dts.size:
            out[k:] = dts[k:] > self.gap_threshold_s
        return out

    def gap_report(self) -> dict:
        """Stream-global gap accounting (JSON-safe)."""
        return {"n_gaps": self.gap_events,
                "gap_s": self.gap_seconds,
                "gap_j": self.gap_joules,
                "threshold_s": self.gap_threshold_s}

    def _process(self, t: float, p: float) -> None:
        t0, p0 = self._t_prev, self._p_prev
        is_gap = False
        if t0 is not None and t > t0:
            is_gap = self._classify_gap(t - t0)
            if is_gap:
                self.gap_events += 1
                self.gap_seconds += t - t0
                self.gap_joules += 0.5 * (p0 + p) * (t - t0)
                self.gaps.append((t0, t))
        for acc in self._active:
            if acc.marker.t_start_s > t:
                break            # time-ordered: nothing later overlaps yet
            # kernel-subdivided windows accumulate into their children
            # (the parent is assembled from them at finalize time)
            for sub in acc.children or (acc,):
                m = sub.marker
                if m.t_start_s > t:
                    break        # children are time-ordered too
                if m.t_start_s <= t < m.t_end_s:
                    sub.n_samples += 1
                if t0 is None:
                    continue
                a = max(t0, m.t_start_s)
                b = min(t, m.t_end_s)
                if b - a > _EPS and t > t0:
                    pa = p0 + (p - p0) * (a - t0) / (t - t0)
                    pb = p0 + (p - p0) * (b - t0) / (t - t0)
                    area = 0.5 * (pa + pb) * (b - a)
                    sub.energy_j += area
                    sub.covered_s += b - a
                    if is_gap:
                        sub.gap_j += area
                        sub.gap_s += b - a
        while self._active and self._active[0].marker.t_end_s <= t:
            self._finalize(self._active.popleft())
        self._t_prev, self._p_prev = t, p

    def _process_chunk(self, t: np.ndarray, p: np.ndarray) -> None:
        """Vectorized ``_process`` over a released chunk.

        Per active window: sample membership by ``searchsorted``, energy by
        the same split-trapezoid expression the scalar path evaluates
        (identical operation order, so the results are bitwise equal), with
        per-window accumulation replicating the scalar left-to-right
        ``+=`` sequence via a seeded ``cumsum``.
        """
        if t.size == 0:
            return
        if self._t_prev is not None:
            tt = np.concatenate(([self._t_prev], t))
            pp = np.concatenate(([self._p_prev], p))
        else:
            tt, pp = t, p
        t0s, t1s = tt[:-1], tt[1:]
        p0s, p1s = pp[:-1], pp[1:]
        gap_mask = self._classify_gap_chunk(t0s, t1s)
        if gap_mask is not None and gap_mask.any():
            g0, g1 = t0s[gap_mask], t1s[gap_mask]
            gdt = g1 - g0
            genergy = 0.5 * (p0s[gap_mask] + p1s[gap_mask]) * gdt
            self.gap_events += int(np.count_nonzero(gap_mask))
            self.gap_seconds = float(np.cumsum(
                np.concatenate(([self.gap_seconds], gdt)))[-1])
            self.gap_joules = float(np.cumsum(
                np.concatenate(([self.gap_joules], genergy)))[-1])
            self.gaps.extend(zip(g0.tolist(), g1.tolist()))
        t_last = float(t[-1])
        for acc in self._active:
            if acc.marker.t_start_s > t_last:
                break            # time-ordered: nothing later overlaps yet
            for sub in acc.children or (acc,):
                m = sub.marker
                if m.t_start_s > t_last:
                    break        # children are time-ordered too
                sub.n_samples += int(
                    np.searchsorted(t, m.t_end_s, side="left")
                    - np.searchsorted(t, m.t_start_s, side="left"))
                if not t0s.size:
                    continue
                i0 = int(np.searchsorted(t1s, m.t_start_s, side="right"))
                i1 = int(np.searchsorted(t0s, m.t_end_s, side="left"))
                if i1 <= i0:
                    continue
                seg_t0, seg_t1 = t0s[i0:i1], t1s[i0:i1]
                a = np.maximum(seg_t0, m.t_start_s)
                b = np.minimum(seg_t1, m.t_end_s)
                dt = seg_t1 - seg_t0
                mask = (b - a > _EPS) & (dt > 0)
                if not mask.any():
                    continue
                dt_safe = np.where(dt > 0, dt, 1.0)
                seg_p0 = p0s[i0:i1]
                dp = p1s[i0:i1] - seg_p0
                pa = seg_p0 + dp * (a - seg_t0) / dt_safe
                pb = seg_p0 + dp * (b - seg_t0) / dt_safe
                areas = (0.5 * (pa + pb) * (b - a))[mask]
                spans = (b - a)[mask]
                sub.energy_j = float(np.cumsum(
                    np.concatenate(([sub.energy_j], areas)))[-1])
                sub.covered_s = float(np.cumsum(
                    np.concatenate(([sub.covered_s], spans)))[-1])
                if gap_mask is not None:
                    gsel = gap_mask[i0:i1][mask]
                    if gsel.any():
                        sub.gap_j = float(np.cumsum(np.concatenate(
                            ([sub.gap_j], areas[gsel])))[-1])
                        sub.gap_s = float(np.cumsum(np.concatenate(
                            ([sub.gap_s], spans[gsel])))[-1])
        while self._active and self._active[0].marker.t_end_s <= t_last:
            self._finalize(self._active.popleft())
        self._t_prev, self._p_prev = t_last, float(p[-1])

    def _finalize(self, acc: _Accum) -> None:
        win = acc.finish()
        self.windows.append(win)
        if self._on_window is not None:
            self._on_window(win)


def subdivide_marker(parent: Marker, spans) -> List[Marker]:
    """Kernel child markers exactly tiling ``parent`` from launch spans.

    ``spans`` is a sequence of launch timings with ``name``, ``variant``,
    ``config``, ``frac_start``, ``frac_end`` attributes (fractions of the
    parent window — e.g. ``RunRecord.launch_spans`` from the sim's
    profiler).  Idle gaps between launches and the tail after the last one
    become ``__unattributed__`` fillers, so the children partition the
    parent span with bit-for-bit shared boundaries: each child's start *is*
    the previous child's end (the same float object), which is what
    ``StreamAligner.add_marker`` validates and the bitwise kernel→step
    tiling rests on.
    """
    t0, t1 = parent.t_start_s, parent.t_end_s
    dur = t1 - t0
    out: List[Marker] = []
    cursor = t0
    for sp in spans:
        start = t1 if sp.frac_start >= 1.0 else min(t0 + sp.frac_start * dur, t1)
        end = t1 if sp.frac_end >= 1.0 else min(t0 + sp.frac_end * dur, t1)
        if start > cursor:
            out.append(Marker(parent.step, UNATTRIBUTED, cursor, start))
            cursor = start
        # guard float drift: chain from the cursor, never before it
        start = cursor
        if end < start:
            end = start
        out.append(Marker(parent.step, sp.name, start, end,
                          variant=sp.variant, config=tuple(sp.config)))
        cursor = end
    if cursor < t1 or not out:
        out.append(Marker(parent.step, UNATTRIBUTED, cursor, t1))
    return out


# ---------------------------------------------------------------------------
# Offline wrappers — same engine over complete inputs.
# ---------------------------------------------------------------------------
def align_trace(trace: SensorTrace,
                markers: Sequence[Marker]) -> List[AlignedWindow]:
    """Attribute a recorded trace's energy to markers (offline MTSM)."""
    aligner = StreamAligner()
    for m in sorted(markers, key=lambda m: m.t_start_s):
        aligner.add_marker(m)
    t, p = trace.times_s, trace.power_w
    for i in range(len(t)):
        aligner.add_sample(PowerSample(float(t[i]), float(p[i])))
    return aligner.close()


def window_tiling(windows: Sequence[AlignedWindow]) -> Dict[str, object]:
    """The per-session tiling record a ``ShardSummary`` carries.

    ``step_j`` lists each logical step's measured joules in window order;
    ``startup_j`` collects the pre-marker spans (step < 0) in arrival
    order — the same order ``StreamSession`` accumulated them, so anyone
    re-summing the tiling reproduces the session's floats bitwise.
    """
    startup_j = 0.0
    step_j: List[float] = []
    for w in windows:
        if w.step < 0:
            startup_j += w.measured_j
        else:
            step_j.append(w.measured_j)
    return {"startup_j": startup_j, "step_j": step_j}


def contiguous_markers(boundaries: Sequence[float], *, names=None,
                       first_step: int = 0) -> List[Marker]:
    """Markers tiling ``[boundaries[0], boundaries[-1]]`` — one per span.

    The tiling property is what makes per-step energies sum to the run
    total; use this when step boundaries are known timestamps.
    """
    bounds = np.asarray(boundaries, dtype=float)
    if bounds.ndim != 1 or bounds.size < 2:
        raise ValueError("need at least two boundary timestamps")
    if np.any(np.diff(bounds) < 0):
        raise ValueError("boundaries must be non-decreasing")
    out = []
    for i in range(bounds.size - 1):
        name = (names[i] if names is not None else f"step{first_step + i}")
        out.append(Marker(step=first_step + i, name=name,
                          t_start_s=float(bounds[i]),
                          t_end_s=float(bounds[i + 1])))
    return out
