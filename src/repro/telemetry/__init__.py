"""Streaming telemetry: online sampling, MTSM alignment, live attribution.

The runtime layer between raw power sensors and the fleet monitor:

    sampler  — background-style samplers + bounded ring buffer
    stream   — O(1) incremental integration + online plateau detection
               (shared with the offline path in ``repro.core.measure``)
    align    — MTSM-style marker synchronization → measured J per step
    attrib   — measured-vs-predicted residuals, drift, recalibration
    service  — per-workload sessions + the multi-device aggregator

Entry point: ``repro.api.EnergyModel.stream(...)`` /
``EnergyModel.monitor(live=...)``.
"""
from repro.telemetry.align import (AlignedWindow, Marker, StreamAligner,
                                   align_trace, contiguous_markers)
from repro.telemetry.attrib import (DriftDetector, DriftState,
                                    OnlineAttributor, StepAttribution,
                                    rescale_table)
from repro.telemetry.sampler import (DeviceSampler, FeedSampler, PowerSample,
                                     SampleRing, TraceReplaySampler)
from repro.telemetry.service import (StreamSession, StreamSummary,
                                     TelemetryService)
from repro.telemetry.stream import (OnlineSteadyState, PlateauState,
                                    StreamingIntegrator, rolling_std,
                                    trapezoid_energy)

__all__ = [
    "AlignedWindow", "Marker", "StreamAligner", "align_trace",
    "contiguous_markers", "DriftDetector", "DriftState", "OnlineAttributor",
    "StepAttribution", "rescale_table", "DeviceSampler", "FeedSampler",
    "PowerSample", "SampleRing", "TraceReplaySampler", "StreamSession",
    "StreamSummary", "TelemetryService", "OnlineSteadyState", "PlateauState",
    "StreamingIntegrator", "rolling_std", "trapezoid_energy",
]
