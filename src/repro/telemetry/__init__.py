"""Streaming telemetry: online sampling, MTSM alignment, live attribution.

The runtime layer between raw power sensors and the fleet monitor:

    sampler  — background-style samplers + bounded ring buffer
    stream   — O(1) incremental integration + online plateau detection
               (shared with the offline path in ``repro.core.measure``)
    align    — MTSM-style marker synchronization → measured J per step
    attrib   — measured-vs-predicted residuals, drift, recalibration
    service  — per-workload sessions + the multi-device aggregator
    shard    — mergeable per-shard summaries + the worker runtime
    plane    — the sharded service: N shards, one exactly-tiling snapshot
    faults   — deterministic chaos injection + the stream sanitizer

Every stage has two ingestion surfaces: the per-sample ``PowerSample``
reference path and a chunked ndarray fast path (``chunks(n)`` samplers,
``SampleRing.extend``, ``StreamingIntegrator.extend``,
``OnlineSteadyState.update_chunk``, ``StreamAligner.add_samples``,
``OnlineAttributor.attribute_batch``) that is bitwise-identical and ~15×
cheaper per sample — see ``benchmarks/telemetry_overhead.py``.

Entry point: ``repro.api.EnergyModel.stream(...)`` /
``EnergyModel.monitor(live=...)``.
"""
from repro.telemetry.align import (UNATTRIBUTED, AlignedWindow, Marker,
                                   StreamAligner, align_trace,
                                   contiguous_markers, subdivide_marker,
                                   window_tiling)
from repro.telemetry.attrib import (DriftDetector, DriftState,
                                    OnlineAttributor, StepAttribution,
                                    rescale_table)
from repro.telemetry.faults import (ChaosPlan, ChaosReport, FaultySampler,
                                    StreamSanitizer)
from repro.telemetry.plane import SupervisorConfig, TelemetryPlane
from repro.telemetry.sampler import (DEFAULT_CHUNK, DeviceSampler,
                                     FeedSampler, PowerSample, SampleRing,
                                     SharedSampleRing, TraceReplaySampler,
                                     iter_chunks)
from repro.telemetry.service import (StreamSession, StreamSummary,
                                     TelemetryService, fleet_block)
from repro.telemetry.shard import Shard, ShardSummary
from repro.telemetry.stream import (OnlineSteadyState, PlateauState,
                                    StreamingIntegrator, rolling_std,
                                    trapezoid_energy)

__all__ = [
    "AlignedWindow", "Marker", "StreamAligner", "align_trace",
    "contiguous_markers", "DriftDetector", "DriftState", "OnlineAttributor",
    "StepAttribution", "rescale_table", "DeviceSampler", "FeedSampler",
    "PowerSample", "SampleRing", "TraceReplaySampler", "StreamSession",
    "StreamSummary", "TelemetryService", "OnlineSteadyState", "PlateauState",
    "StreamingIntegrator", "rolling_std", "trapezoid_energy",
    "DEFAULT_CHUNK", "iter_chunks", "TelemetryPlane", "Shard",
    "ShardSummary", "SharedSampleRing", "fleet_block", "window_tiling",
    "subdivide_marker", "UNATTRIBUTED", "ChaosPlan", "ChaosReport",
    "FaultySampler", "StreamSanitizer", "SupervisorConfig",
]
