"""Online attribution: measured-vs-predicted fusion and drift repair.

Each aligned window (measured joules for one step, from ``align``) is fused
with the table prediction for the same work (``TablePredictor``), yielding a
``StepAttribution``: residual, dynamic-energy ratio, and the per-class
*measured* split (the prediction's class shares rescaled onto the measured
dynamic joules — Simsek et al.'s application-level accounting built on a
streaming ingest).

A ``DriftDetector`` keeps rolling statistics of the dynamic ratio.  Real
deployments drift: silicon ages, firmware changes DVFS tables, a table
trained on one voltage bin ships to another.  When the rolling median ratio
leaves the tolerance band for long enough, the detector flags drift and the
``OnlineAttributor`` fires its recalibration trigger — by default rescaling
every dynamic entry of the bound ``EnergyTable`` by the observed ratio
(uniform-drift repair, write-through to a ``TableStore`` when given), or
any callable for heavier strategies (full retrain via
``core.trainer.train_table``).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from repro.core import isa
# the pure-numpy accumulation core, not the jax-importing counters:
# telemetry shard workers import this module at spawn
from repro.core.counting import OpCounts
from repro.core.predict import Prediction, TablePredictor
from repro.telemetry.align import AlignedWindow

_EPS = 1e-12


@dataclasses.dataclass
class StepAttribution:
    """One window's measured-vs-predicted verdict.

    The per-class *measured* split (the prediction's class shares rescaled
    onto the measured dynamic joules) is carried as a vector over
    ``isa.CLASS_INDEX`` (``measured_class_vec``); the dict form
    (``by_class_measured``) materializes lazily on first read.
    """

    step: int
    name: str
    duration_s: float
    measured_j: float
    predicted_j: float
    measured_dyn_j: float       # measured minus (const+static) * duration
    predicted_dyn_j: float
    measured_class_vec: np.ndarray   # predicted shares × measured dyn J
    prediction: Prediction
    # window backed by too little dense sampling (quarantine/gap holes):
    # reported but excluded from drift statistics
    low_confidence: bool = False

    @property
    def by_class_measured(self) -> Dict[str, float]:
        v = self.measured_class_vec
        name = isa.CLASS_INDEX.name
        return {name(int(i)): float(v[i]) for i in np.nonzero(v)[0]}

    @property
    def residual_j(self) -> float:
        return self.measured_j - self.predicted_j

    @property
    def error_pct(self) -> float:
        if self.measured_j <= 0:
            return 0.0
        return 100.0 * (self.predicted_j / self.measured_j - 1.0)

    @property
    def dyn_ratio(self) -> float:
        """measured/predicted dynamic energy — the drift observable."""
        return self.measured_dyn_j / max(self.predicted_dyn_j, _EPS)


@dataclasses.dataclass
class DriftState:
    drifting: bool
    ratio: float                # rolling median dynamic ratio
    baseline: float             # anchored pre-drift ratio (nan: learning)
    n: int                      # windows ever observed
    consecutive: int            # consecutive out-of-band windows

    @property
    def rel_drift(self) -> float:
        """Fractional departure of the rolling ratio from the baseline."""
        if not math.isfinite(self.baseline) or self.baseline <= 0:
            return 0.0
        return self.ratio / self.baseline - 1.0


class DriftDetector:
    """Rolling-median drift flag over the dynamic measured/predicted ratio.

    A counts-based model carries a *constant* per-workload bias (data-
    dependent bit-toggle activity and access patterns — the paper's organic
    ~11–15% MAPEs), so absolute error is the wrong observable.  The
    detector instead anchors a **baseline** ratio on the first
    ``baseline_windows`` observations and declares drift when the rolling
    median departs from that baseline by more than ``rel_tol`` for
    ``patience`` consecutive updates — the QMCPACK posture of judging a
    signal against its own history, applied to the model itself.  Single-
    step spikes stay the fleet monitor's job.
    """

    def __init__(self, window: int = 16, rel_tol: float = 0.15,
                 baseline_windows: int = 6, patience: int = 4):
        self.window = int(window)
        self.rel_tol = float(rel_tol)
        self.baseline_windows = int(baseline_windows)
        self.patience = int(patience)
        self.baseline = math.nan
        self._ratios: deque = deque(maxlen=self.window)
        self._seen: List[float] = []       # baseline-learning buffer
        self._consecutive = 0
        self._n = 0

    def update(self, dyn_ratio: float) -> DriftState:
        if math.isfinite(dyn_ratio) and dyn_ratio > 0:
            self._ratios.append(dyn_ratio)
            self._n += 1
            if math.isnan(self.baseline):
                self._seen.append(dyn_ratio)
                if len(self._seen) >= self.baseline_windows:
                    self.baseline = float(np.median(self._seen))
                    self._seen.clear()
        ratio = float(np.median(self._ratios)) if self._ratios else 1.0
        out_of_band = (math.isfinite(self.baseline) and self.baseline > 0
                       and abs(ratio / self.baseline - 1.0) > self.rel_tol)
        self._consecutive = self._consecutive + 1 if out_of_band else 0
        return DriftState(drifting=self._consecutive >= self.patience,
                          ratio=ratio, baseline=self.baseline, n=self._n,
                          consecutive=self._consecutive)

    def reset(self, keep_baseline: bool = True) -> None:
        """Clear the rolling view (after a repair); the anchored baseline
        survives unless ``keep_baseline=False``."""
        self._ratios.clear()
        self._seen.clear()
        self._consecutive = 0
        if not keep_baseline:
            self.baseline = math.nan

    def state_dict(self) -> dict:
        """The detector's complete state, JSON/pickle-safe.

        ``load_state`` restores it exactly — same rolling window contents,
        same baseline-learning buffer, same streak counters — so a detector
        handed across a process boundary (telemetry shard workers) resumes
        bit-for-bit where this one stands.
        """
        return {
            "window": self.window,
            "rel_tol": self.rel_tol,
            "baseline_windows": self.baseline_windows,
            "patience": self.patience,
            "baseline": self.baseline,
            "ratios": list(self._ratios),
            "seen": list(self._seen),
            "consecutive": self._consecutive,
            "n": self._n,
        }

    def load_state(self, state: dict) -> "DriftDetector":
        self.window = int(state["window"])
        self.rel_tol = float(state["rel_tol"])
        self.baseline_windows = int(state["baseline_windows"])
        self.patience = int(state["patience"])
        self.baseline = float(state["baseline"])
        self._ratios = deque(state["ratios"], maxlen=self.window)
        self._seen = list(state["seen"])
        self._consecutive = int(state["consecutive"])
        self._n = int(state["n"])
        return self


def mape_pct(attributions) -> float:
    """Mean |error %| over attributions with positive measured energy."""
    errs = [abs(a.error_pct) for a in attributions if a.measured_j > 0]
    return float(np.mean(errs)) if errs else 0.0


def rescale_table(predictor: TablePredictor, ratio: float,
                  store=None) -> None:
    """Uniform-drift repair: scale every dynamic table entry by ``ratio``.

    Mutates the predictor's bound ``EnergyTable`` in place, invalidates the
    predictor's lookup cache, and (when a ``TableStore`` is given) publishes
    the corrected table so every node sharing the store converges.

    Uniform drift (aging silicon, a voltage-bin mismatch) shifts dynamic
    energy at *every* operating point, so the repair also scales each
    frequency-family member — otherwise a governor exploring the family
    would see repaired pricing at the anchor and stale pricing everywhere
    else.
    """
    table = predictor.table
    members = [table] + [sub for _, sub in sorted(table.points.items())]
    for t in members:
        for d in (t.direct, t.scaled, t.bucket_means):
            for cls in d:
                d[cls] *= ratio
        t.meta["recalibrated_scale"] = (
            t.meta.get("recalibrated_scale", 1.0) * ratio)
    predictor.invalidate()
    if store is not None:
        store.put(table)


class OnlineAttributor:
    """Streams ``AlignedWindow``s into attributions, drift state, repairs.

    ``recalibrate`` chooses the trigger action once drift is flagged:
      * ``"rescale"`` (default) — ``rescale_table`` by the rolling ratio;
      * a callable ``f(attributor, state)`` — custom strategy (retrain, page
        an operator, ...);
      * ``None`` — detect and record only.
    """

    def __init__(self, predictor: TablePredictor, *,
                 detector: Optional[DriftDetector] = None,
                 recalibrate: Union[str, Callable, None] = "rescale",
                 store=None, min_solid_coverage: float = 0.5):
        self.predictor = predictor
        self.table = predictor.table
        self.detector = detector or DriftDetector()
        self.recalibrate = recalibrate
        self.store = store
        # windows whose densely-sampled (non-gap) coverage falls below
        # this fraction are attributed but flagged low-confidence and
        # kept out of the drift detector — fault-induced outliers must
        # not fire spurious recalibrations
        self.min_solid_coverage = float(min_solid_coverage)
        self.attributions: List[StepAttribution] = []
        self.drift: DriftState = DriftState(False, 1.0, math.nan, 0, 0)
        self.recalibrations: List[float] = []   # applied ratios, in order
        self.low_confidence_total = 0
        self._triggers = 0     # repair actions fired (any strategy)

    def attribute(self, window: AlignedWindow, counts: OpCounts,
                  counters: Optional[dict] = None,
                  operating_point=None) -> StepAttribution:
        """Fuse one aligned window with the prediction for its op counts.

        ``operating_point`` prices the window at a (freq, cap) member of the
        table's frequency family (``None`` — the anchor, bitwise-legacy).
        """
        point = self.predictor._as_point(operating_point)
        pred = self.predictor.predict(counts, window.duration_s,
                                      counters=counters,
                                      operating_point=point)
        return self._fuse(window, pred, point)

    def attribute_batch(self, windows: List[AlignedWindow],
                        counts_list: List[OpCounts],
                        counters_list: Optional[List[Optional[dict]]] = None,
                        operating_point=None) -> List[StepAttribution]:
        """Fuse many finalized windows in one ``predict_batch`` pass.

        Bitwise-identical to calling ``attribute`` per window (a single
        prediction *is* a 1-row batch).  Drift state still advances window
        by window; when a recalibration fires mid-batch the remaining
        windows are re-predicted against the repaired table, exactly as the
        per-window path would have seen it.  ``operating_point`` applies to
        every window of the batch (sessions switch points only at phase
        boundaries, so a single batch is single-point by construction).
        """
        if counters_list is None:
            counters_list = [None] * len(windows)
        point = self.predictor._as_point(operating_point)
        out: List[StepAttribution] = []
        i, n = 0, len(windows)
        while i < n:
            preds = self.predictor.predict_batch(
                counts_list[i:], [w.duration_s for w in windows[i:]],
                counters_list[i:], operating_point=point)
            repaired = False
            for j, pred in enumerate(preds):
                before = self._triggers
                out.append(self._fuse(windows[i + j], pred, point))
                # a trigger may have mutated the table: re-predict the tail
                # so later windows see the same table state the sequential
                # path would have
                if self._triggers != before and i + j + 1 < n:
                    i += j + 1
                    repaired = True
                    break
            if not repaired:
                i = n
        return out

    def _fuse(self, window: AlignedWindow, pred: Prediction,
              point=None) -> StepAttribution:
        if point is None:
            overhead = (self.table.p_const + self.table.p_static) * window.duration_s
        else:
            p_const, p_static = self.predictor.point_powers(point)
            overhead = (p_const + p_static) * window.duration_s
        meas_dyn = window.measured_j - overhead
        pred_dyn = max(pred.dynamic_j, _EPS)
        scale = meas_dyn / pred_dyn
        low_conf = window.solid_coverage < self.min_solid_coverage
        att = StepAttribution(
            step=window.step, name=window.name,
            duration_s=window.duration_s, measured_j=window.measured_j,
            predicted_j=pred.total_j, measured_dyn_j=meas_dyn,
            predicted_dyn_j=pred.dynamic_j,
            measured_class_vec=pred.class_energy_vec * scale,
            prediction=pred, low_confidence=low_conf)
        self.attributions.append(att)
        if low_conf:
            # too little dense sampling behind this window: report it,
            # but never let a fault-shaped ratio steer recalibration
            self.low_confidence_total += 1
            return att
        self.drift = self.detector.update(att.dyn_ratio)
        if self.drift.drifting:
            self._trigger(self.drift)
        return att

    def _trigger(self, state: DriftState) -> None:
        if self.recalibrate is None:
            return
        self._triggers += 1
        if callable(self.recalibrate):
            self.recalibrate(self, state)
        elif self.recalibrate == "rescale":
            # scale so the post-repair ratio returns to the anchored
            # baseline — the pre-drift band, workload bias preserved
            factor = state.ratio / state.baseline \
                if math.isfinite(state.baseline) and state.baseline > 0 \
                else state.ratio
            rescale_table(self.predictor, factor, store=self.store)
            self.recalibrations.append(factor)
        else:
            raise ValueError(
                f"unknown recalibrate strategy {self.recalibrate!r}")
        self.detector.reset(keep_baseline=True)
        self.drift = DriftState(False, 1.0, self.detector.baseline, 0, 0)

    # -- summaries ----------------------------------------------------------
    def mape(self) -> float:
        return mape_pct(self.attributions)

    def top_measured_classes(self, k: int = 10):
        if not self.attributions:
            return []
        n = max(a.measured_class_vec.size for a in self.attributions)
        agg = np.zeros(n)
        for a in self.attributions:
            v = a.measured_class_vec
            agg[:v.size] += v
        top = np.argsort(-agg)[:k]
        name = isa.CLASS_INDEX.name
        return [(name(int(i)), float(agg[i])) for i in top if agg[i] != 0.0]
