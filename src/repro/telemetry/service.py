"""Telemetry service: per-workload stream sessions + a fleet aggregator.

``StreamSession`` is the full pipeline for one workload on one device —
ingestion → alignment → attribution → monitoring:

    session = model.stream(counts, name="train_step")
    for step in range(N):
        ...                                  # host executes the real step
        session.step(step, duration_s=dt, work_units=tokens)
    summary = session.finish()               # sample, align, attribute

The host loop registers *logical* steps (MTSM sync points); ``finish`` runs
the program on the device with a background-style sampler, places one
marker per logical step across the active span, streams every sample
through a bounded ring + O(1) integrator + online plateau detector + the
``StreamAligner``, and fuses each finalized window with the table
prediction (drift detection and recalibration included).  On real hardware
the sampler would be a polling thread racing the app; the simulated device
executes first and the pipeline consumes the identical sample stream.

``TelemetryService`` aggregates sessions across devices/workloads with a
JSON-exportable snapshot — what a fleet dashboard would poll.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
from typing import Dict, List, Optional

import numpy as np

# OpCounts from the pure-numpy accumulation core (not the jax-importing
# ``core.opcount`` counters): spawned telemetry shard workers import this
# module, and their startup must not pay for (or depend on) jax.
from repro.core.counting import OpCounts
from repro.core.predict import TablePredictor
from repro.hw.device import LaunchSpec, Program, RunRecord, SimDevice
from repro.telemetry.align import (AlignedWindow, Marker, StreamAligner,
                                   contiguous_markers, subdivide_marker)
from repro.telemetry.attrib import DriftState, OnlineAttributor, mape_pct
from repro.telemetry.attrib import rescale_table
from repro.telemetry.faults import ChaosPlan, FaultySampler, StreamSanitizer
from repro.telemetry.sampler import (DEFAULT_CHUNK, DeviceSampler,
                                     SampleRing, TraceReplaySampler,
                                     iter_chunks)
from repro.telemetry.stream import OnlineSteadyState, StreamingIntegrator

_BYTE_COUNTERS = ("hbm_read_bytes", "hbm_write_bytes",
                  "vmem_read_bytes", "vmem_write_bytes")


@dataclasses.dataclass
class _HostStep:
    """A logical step as the host loop saw it."""

    step: int
    host_duration_s: Optional[float]
    work_units: float
    counters: Optional[dict]


@dataclasses.dataclass
class _AttachedDevice:
    """Stands in for a ``SimDevice`` on sessions attached to a trace that
    was produced elsewhere (a shard worker, a replayed recording).  Only
    the snapshot-facing surface exists — such a session never launches a
    program."""

    name: str
    operating_point: Optional[object] = None


def fleet_block(per: Dict[str, dict], anomalies: int) -> dict:
    """The fleet roll-up over per-session snapshot dicts.

    Float totals accumulate in **sorted-key order** — the canonical order
    shared by ``TelemetryService.snapshot`` and the sharded plane's
    ``ShardSummary`` merges.  Float addition is not associative, so fixing
    one order is what makes the roll-up partition-invariant: any grouping
    of the same sessions into shards reproduces the same fleet floats
    bitwise.
    """
    keys = sorted(per)
    measured_j = 0.0
    samples = 0
    quarantined = 0
    n_gaps = 0
    gap_s = 0.0
    gap_j = 0.0
    low_conf = 0
    for k in keys:
        measured_j += per[k]["measured_j"]
        samples += per[k]["samples"]
        h = per[k].get("health") or {}
        quarantined += h.get("quarantined", 0)
        n_gaps += h.get("n_gaps", 0)
        gap_s += h.get("gap_s", 0.0)
        gap_j += h.get("gap_j", 0.0)
        low_conf += h.get("low_confidence_windows", 0)
    return {
        "n_sessions": len(per),
        "measured_j": measured_j,
        "samples": samples,
        "drifting": sorted(k for k in keys if per[k]["drifting"]),
        "anomalies": anomalies,
        "health": {"quarantined": quarantined, "n_gaps": n_gaps,
                   "gap_s": gap_s, "gap_j": gap_j,
                   "low_confidence_windows": low_conf},
    }


@dataclasses.dataclass
class StreamSummary:
    """What one finished stream session learned."""

    name: str
    steps: int
    duration_s: float
    measured_total_j: float       # streaming integral over the whole trace
    predicted_total_j: float      # sum of per-window predictions
    startup_j: float              # energy before the first step marker
    mape_pct: float
    drift: DriftState
    recalibrations: List[float]
    host_duration_s: Optional[float]   # summed host wall-clock, when reported
    n_samples: int
    dropped_samples: int
    # health accounting (defaults keep older pickled summaries loadable)
    quarantined_samples: int = 0       # rejected by the stream sanitizer
    stale_suspects: int = 0            # repeated-value readings (heuristic)
    n_gaps: int = 0                    # sampling-gap segments seen
    gap_s: float = 0.0                 # span estimated across gaps
    gap_j: float = 0.0                 # energy interpolated across gaps
    low_confidence_windows: int = 0    # windows below solid-coverage floor

    @property
    def attributed_j(self) -> float:
        return self.measured_total_j - self.startup_j


class StreamSession:
    """One workload's streaming pipeline (see module docstring)."""

    def __init__(self, predictor: TablePredictor, device: SimDevice,
                 counts: OpCounts, name: str = "workload", *,
                 monitor=None, min_duration_s: float = 30.0,
                 ring_capacity: int = 4096,
                 recalibrate="rescale", store=None,
                 detector=None, attributor: Optional[OnlineAttributor] = None,
                 chunk_size: Optional[int] = DEFAULT_CHUNK,
                 operating_point=None, chaos: Optional[ChaosPlan] = None,
                 gap_threshold_s: Optional[float] = None):
        self.predictor = predictor
        self.device = device
        self.counts = counts
        self.name = name
        self.monitor = monitor
        self.min_duration_s = float(min_duration_s)
        # DVFS point for this session: the device is set there when the run
        # starts, and every window is predicted/attributed at that point
        # (None — wherever the device already is, priced at the anchor)
        self.operating_point = predictor._as_point(operating_point)
        # chunk_size=None/0 selects the per-sample reference path; any
        # positive n ingests n-sample ndarray chunks through the whole
        # pipeline (ring, integrator, plateau, aligner, batch attribution)
        self.chunk_size = int(chunk_size) if chunk_size else None
        # fault injection (None/disabled: the sampler is used as-is) and
        # the always-on ingest sanitizer — on clean streams it is a
        # zero-copy, bitwise pass-through with counters
        self.chaos = chaos
        self.sanitizer = StreamSanitizer()
        self._gap_threshold_s = gap_threshold_s
        self.ring = SampleRing(ring_capacity)
        self.integrator = StreamingIntegrator()
        self.plateau = OnlineSteadyState()
        # pass a previous session's attributor to carry the drift baseline
        # across runs of the same workload (the long-lived fleet posture)
        self.attributor = attributor or OnlineAttributor(
            predictor, detector=detector, recalibrate=recalibrate,
            store=store)
        self.windows: List[AlignedWindow] = []
        self.startup_j = 0.0
        self.record: Optional[RunRecord] = None
        self.summary: Optional[StreamSummary] = None
        self._steps: List[_HostStep] = []
        self._kernel_scopes: List[LaunchSpec] = []   # declared per iteration
        self._scope_open: Optional[str] = None
        self._n = 0                  # marker windows (finish(steps=k) <= registered)
        self._group = 1.0            # device iterations per logical step
        self._group_counts = counts  # counts per marker window
        self._aligner: Optional[StreamAligner] = None
        self._source = None          # chunk/sample iterator while draining
        self._pending: List[AlignedWindow] = []   # chunked: await batch fuse
        # drain accounting: every chunk (including the final, possibly
        # partial one that closes the session) is counted, so plane-level
        # sums over polls reconcile exactly with summary.n_samples
        self.samples_drained = 0
        self.chunks_drained = 0
        self._remote_snapshot: Optional[dict] = None   # set by adopt_remote
        # session-local slices into a possibly shared attributor
        self._a0 = len(self.attributor.attributions)
        self._recal0 = len(self.attributor.recalibrations)

    @property
    def attributions(self):
        """This session's StepAttributions (shared-attributor safe)."""
        return self.attributor.attributions[self._a0:]

    @property
    def iterations_per_step(self) -> float:
        """Device iterations folded into each logical step (the work scale).

        ``start`` stretches short workloads so the device run passes
        startup and reaches a steady plateau; each logical step's aligned
        window then spans this many repetitions of its op counts.  Per-unit
        figures (J/token) must divide by it — the serving ledger does.
        """
        return self._group

    @property
    def recalibrations(self) -> List[float]:
        """Recalibration factors applied during this session."""
        return self.attributor.recalibrations[self._recal0:]

    @property
    def steps_registered(self) -> int:
        return len(self._steps)

    # -- host-loop surface ---------------------------------------------------
    def step(self, step: Optional[int] = None,
             duration_s: Optional[float] = None, work_units: float = 1.0,
             counters: Optional[dict] = None) -> None:
        """Register one logical step (an MTSM sync point).

        ``duration_s`` is the *host* wall-clock for the step, recorded for
        reporting (``summary.host_duration_s``); alignment itself follows
        the device trace's own timeline — the sampler watches the device
        clock, and the device executes the profiled counts uniformly.
        """
        if self.summary is not None:
            raise RuntimeError("session already finished")
        if self._aligner is not None:
            raise RuntimeError("session already started; steps are fixed "
                               "once sampling begins")
        idx = step if step is not None else len(self._steps)
        self._steps.append(_HostStep(idx, duration_s, work_units, counters))

    @contextlib.contextmanager
    def kernel_scope(self, name: str, variant: str = "pallas",
                     config=(), counts: Optional[OpCounts] = None):
        """Declare one kernel launch inside each iteration of this workload.

        The microscopy analogue of ``step``: where ``step`` marks the MTSM
        sync points that subdivide the run, ``kernel_scope`` marks the
        launches that subdivide each step.  The host wraps the kernel call::

            with session.kernel_scope("flash_attention", config=(512, 512),
                                      counts=model.profile(fn, *args).counts):
                out = fn(*args)

        ``counts`` is the launch's own per-call profile — the sim times the
        launch with it (a real profiler would read launch timestamps off
        the stream) and each step's aligned window subdivides into one
        kernel window per scope plus the ``__unattributed__`` remainder,
        tiling the step's measured joules bitwise.  Scopes are declarative
        and uniform across steps; they must be entered before ``start()``
        and must not nest or overlap.
        """
        if self.summary is not None:
            raise RuntimeError("session already finished")
        if self._aligner is not None:
            raise RuntimeError("session already started; kernel scopes are "
                               "fixed once sampling begins")
        if self._scope_open is not None:
            raise ValueError(
                f"kernel scope {name!r} opened while scope "
                f"{self._scope_open!r} is still active; kernel scopes must "
                f"not overlap — close the previous scope first")
        self._scope_open = name
        try:
            yield self
            self._kernel_scopes.append(LaunchSpec(
                name=name, counts=counts if counts is not None else OpCounts(),
                variant=variant, config=tuple(config)))
        finally:
            self._scope_open = None

    @property
    def started(self) -> bool:
        return self._aligner is not None

    def start(self, steps: Optional[int] = None) -> "StreamSession":
        """Run the device and arm the pipeline without consuming samples.

        After ``start``, ``poll()`` incrementally drains the sampler —
        chunk-wise on the fast path — and ``finish()`` drains to the end.
        ``TelemetryService.poll_all`` polls every started session in one
        pass, which is how one monitor process watches a whole fleet.
        """
        if self.summary is not None or self._aligner is not None:
            return self
        rec, sampler = self._launch(steps)
        self._arm(rec, self._markers(rec, self._n), sampler)
        return self

    def _launch(self, steps: Optional[int] = None):
        """Device half of ``start``: fix the step grid, run the program.

        Returns ``(record, sampler)`` without arming the ingest pipeline.
        The sharded plane uses this split: the parent process launches the
        device run, publishes the trace through a shared-memory ring, and
        a worker ``_arm``s an attached session around it — the two halves
        compose back to exactly what ``start`` does in one process.
        """
        n = steps if steps is not None else len(self._steps)
        if n <= 0:
            raise ValueError("no steps registered; call session.step(...) "
                             "or finish(steps=N)")
        while len(self._steps) < n:
            self._steps.append(_HostStep(len(self._steps), None, 1.0, None))
        self._n = n

        # Long enough to pass startup and reach a steady plateau; the extra
        # device iterations are folded evenly into the n logical windows.
        iters = max(n, self.device.iters_for_duration(
            self.counts, self.min_duration_s))
        iters = (iters // n) * n                 # equal-sized groups
        self._group = iters / n
        self._group_counts = self.counts.scaled(self._group)

        if self.operating_point is not None:
            freq, cap = self.operating_point
            self.device.set_operating_point(freq, power_cap_w=cap)
        rec, sampler = DeviceSampler(self.device).run(
            Program(self.name, self.counts, iters=iters,
                    launches=self._kernel_scopes or None))
        self.record = rec
        return rec, sampler

    def _arm(self, record: Optional[RunRecord], markers: List[Marker],
             sampler) -> None:
        """Ingest half of ``start``: marker grid + chunk source.

        Markers may be plain ``Marker``s or ``(marker, children)`` pairs —
        the latter arm a kernel-subdivided step window.  The attached/shard
        path always passes plain markers, so the sharded plane is
        untouched by kernel microscopy.
        """
        self.record = record
        self._aligner = StreamAligner(on_window=self._on_window,
                                      gap_threshold_s=self._gap_threshold_s)
        for m in markers:
            if isinstance(m, tuple):
                self._aligner.add_marker(m[0], m[1])
            else:
                self._aligner.add_marker(m)
        if self.chaos is not None and self.chaos.stream_enabled:
            sampler = FaultySampler(sampler, self.chaos)
        self._source = (iter_chunks(sampler, self.chunk_size)
                        if self.chunk_size else iter(sampler))

    @classmethod
    def attached(cls, predictor: TablePredictor, counts: OpCounts, *,
                 name: str, trace, markers: List[Marker],
                 record: Optional[RunRecord] = None, steps=None,
                 n_steps: Optional[int] = None, group: float = 1.0,
                 device_name: str = "attached", device_point=None,
                 operating_point=None, monitor=None,
                 ring_capacity: int = 4096, recalibrate="rescale",
                 store=None, detector=None, attributor=None,
                 chunk_size: Optional[int] = DEFAULT_CHUNK,
                 chaos: Optional[ChaosPlan] = None,
                 gap_threshold_s: Optional[float] = None
                 ) -> "StreamSession":
        """A session armed around an externally produced trace.

        The device half already ran somewhere else — a shard worker's
        parent process, or a recorded run — so this constructor rebuilds
        only the ingest half: the same ring/integrator/plateau/aligner/
        attributor stack, fed by ``trace`` under the given ``markers``.
        ``group``/``steps``/``record`` restore the launching session's
        step grid so window counters and summaries come out identical.
        Shard workers are the primary caller (``telemetry.shard``); the
        shard-scaling benchmark uses it to build synthetic fleets.
        """
        dev = _AttachedDevice(device_name, device_point)
        self = cls(predictor, dev, counts, name, monitor=monitor,
                   ring_capacity=ring_capacity, recalibrate=recalibrate,
                   store=store, detector=detector, attributor=attributor,
                   chunk_size=chunk_size, operating_point=None,
                   chaos=chaos, gap_threshold_s=gap_threshold_s)
        # already resolved by the launching session — adopt verbatim
        # (re-resolving could round differently than the parent did)
        self.operating_point = operating_point
        if steps is not None:
            self._steps = list(steps)
        n = n_steps if n_steps is not None else len(self._steps)
        if n <= 0:
            raise ValueError("attached session needs steps= or n_steps=")
        while len(self._steps) < n:
            self._steps.append(_HostStep(len(self._steps), None, 1.0, None))
        self._n = n
        self._group = float(group)
        self._group_counts = counts.scaled(self._group)
        if record is None:
            t = np.asarray(trace.times_s, dtype=float)
            dur = float(t[-1] - t[0]) if t.size else 0.0
            record = RunRecord(name=name, duration_s=dur,
                               iters=max(int(round(group * n)), 1),
                               trace=None, energy_counter_j=0.0, counters={})
        self._arm(record, list(markers), TraceReplaySampler(trace))
        return self

    def adopt_remote(self, result: dict, *,
                     apply_recalibrations: bool = True) -> StreamSummary:
        """Install a shard worker's finished state onto this session.

        The worker ran the identical ingest pipeline over this session's
        trace in another process; everything a snapshot or a
        ``ShardSummary`` reads is restored here — summary, windows,
        integrator state, drift-detector state, recalibration history and
        drain accounting.  The worker's ring/plateau live state stays
        remote; its final values arrive in the frozen snapshot this
        session serves from now on.  ``apply_recalibrations`` replays any
        drift repairs onto the parent's table (same ratios, same order —
        per-entry multiplication reproduces the worker's table bitwise).
        """
        if self.summary is not None:
            raise RuntimeError("session already finished; nothing to adopt")
        self.summary = result["summary"]
        self.windows = list(result["windows"])
        self.startup_j = self.summary.startup_j
        self.integrator.load_state(result["integrator"])
        self.attributor.detector.load_state(result["detector"])
        self.attributor.drift = self.summary.drift
        if apply_recalibrations:
            for ratio in result["recalibrations"]:
                rescale_table(self.attributor.predictor, ratio,
                              store=self.attributor.store)
        self.attributor.recalibrations.extend(result["recalibrations"])
        self.samples_drained = int(result["samples_drained"])
        self.chunks_drained = int(result["chunks_drained"])
        if "sanitizer" in result:
            self.sanitizer.load_state(result["sanitizer"])
        self._remote_snapshot = dict(result["snapshot"])
        self._source = None
        return self.summary

    def poll(self, max_chunks: int = 1) -> int:
        """Ingest up to ``max_chunks`` chunks; returns samples consumed.

        On the chunked path each chunk flows through the whole stack as
        arrays: one wrap-aware ring write, one vectorized integration, one
        windowed plateau pass, one searchsorted alignment, and one batched
        attribution of every window the chunk finalized.  The per-sample
        path (``chunk_size=None``) ingests the same number of samples one
        ``PowerSample`` at a time — the reference implementation.  When the
        sampler is exhausted the session closes and ``summary`` appears.

        Every chunk is counted in ``chunks_drained`` — including the final,
        possibly partial one that closes the session — so plane-level drain
        accounting (sums of poll returns, per-shard chunk tallies)
        reconciles exactly with ``summary.n_samples``.
        """
        if self.summary is not None:
            return 0
        if self._aligner is None:
            raise RuntimeError("session not started; call start() or "
                               "finish()")
        ingested = 0
        if self.chunk_size:
            for _ in range(max_chunks):
                chunk = next(self._source, None)
                if chunk is None:
                    self._close()
                    break
                raw_size = int(np.asarray(chunk[0]).size)
                # sanitize first: quarantined samples never reach the
                # pipeline (on clean chunks this returns the original
                # arrays — zero-copy, bitwise pass-through)
                t, p, u, c = self.sanitizer.chunk(*chunk)
                if int(np.asarray(t).size):
                    self.ring.extend(t, p, u, c)
                    self.integrator.extend(t, p)
                    self.plateau.update_chunk(t, p)
                    self._aligner.add_samples(t, p)
                    self._flush_pending()
                ingested += raw_size
                self.chunks_drained += 1
                self.samples_drained += raw_size
        else:
            n_before = ingested
            for _ in range(max_chunks * DEFAULT_CHUNK):
                s = next(self._source, None)
                if s is None:
                    self._close()
                    break
                ingested += 1
                if not self.sanitizer.sample(s):
                    continue     # quarantined (counted, never ingested)
                self.ring.append(s)
                self.integrator.add(s.t_s, s.power_w)
                self.plateau.update(s.t_s, s.power_w)
                self._aligner.add_sample(s)
            got = ingested - n_before
            self.samples_drained += got
            # per-sample path: account in reference chunk units, rounding
            # the final partial group up so it is never dropped
            self.chunks_drained += -(-got // DEFAULT_CHUNK) if got else 0
        return ingested

    def finish(self, steps: Optional[int] = None) -> StreamSummary:
        """Sample the device run, align markers, attribute every window."""
        if self.summary is not None:
            return self.summary
        self.start(steps)
        while self.summary is None:
            self.poll(max_chunks=64)
        return self.summary

    run = finish     # one-shot callers: ``model.stream(c).run(steps=N)``

    def _close(self) -> None:
        self._aligner.close()
        self._flush_pending()
        self._source = None
        host_dts = [h.host_duration_s for h in self._steps
                    if h.host_duration_s is not None]
        self.summary = StreamSummary(
            name=self.name, steps=self._n,
            duration_s=self.record.duration_s,
            measured_total_j=self.integrator.energy_j,
            predicted_total_j=float(sum(
                a.predicted_j for a in self.attributions)),
            startup_j=self.startup_j,
            mape_pct=self._mape(),
            drift=self.attributor.drift,
            recalibrations=list(self.recalibrations),
            host_duration_s=float(sum(host_dts)) if host_dts else None,
            n_samples=self.integrator.n_samples,
            dropped_samples=self.ring.dropped,
            quarantined_samples=self.sanitizer.quarantined,
            stale_suspects=self.sanitizer.stale_suspects,
            n_gaps=self._aligner.gap_events,
            gap_s=self._aligner.gap_seconds,
            gap_j=self._aligner.gap_joules,
            low_confidence_windows=self._low_confidence())

    # -- internals -----------------------------------------------------------
    def _markers(self, rec: RunRecord, n: int) -> List[Marker]:
        """One marker per logical step across the trace's active span.

        The active-span start is read from telemetry (the util ramp), never
        from the device's hidden model.
        """
        t, u = rec.trace.times_s, rec.trace.util
        umax = float(np.max(u)) if len(u) else 0.0
        if umax > 0:
            t_act = float(t[np.argmax(u >= umax - 1e-9)])
        else:
            t_act = float(t[0])
        t_end = float(t[-1])
        if t_act >= t_end:
            t_act = float(t[0])
        markers: List[Marker] = []
        if t_act > t[0]:
            markers.append(Marker(step=-1, name="__startup__",
                                  t_start_s=float(t[0]), t_end_s=t_act))
        bounds = np.linspace(t_act, t_end, n + 1)
        step_markers = contiguous_markers(
            bounds, names=[f"{self.name}[{h.step}]" for h in self._steps[:n]],
            first_step=0)
        spans = getattr(rec, "launch_spans", None)
        if spans:
            # each step window spans _group uniform iterations, so the
            # per-iteration launch fractions are the step's fractions too
            markers.extend((m, subdivide_marker(m, spans))
                           for m in step_markers)
        else:
            markers.extend(step_markers)
        return markers

    def _on_window(self, win: AlignedWindow) -> None:
        self.windows.append(win)
        if win.step < 0:                      # pre-marker span: not a step
            self.startup_j += win.measured_j
            return
        if self.chunk_size:
            self._pending.append(win)         # fused in batch per chunk
            return
        host, counters = self._host_and_counters(win)
        self.attributor.attribute(win, self._group_counts, counters=counters,
                                  operating_point=self.operating_point)
        self._observe(win, host, counters)

    def _flush_pending(self) -> None:
        """Batch-fuse every window the last chunk finalized.

        Attribution (and therefore the summary) is bitwise-identical to the
        per-sample path; only the optional ``monitor.observe`` calls differ
        in interleaving — they run after the chunk's attributions, so a
        monitor prediction issued in the same chunk as a drift repair sees
        the repaired table slightly earlier than the scalar path would.
        """
        if not self._pending:
            return
        wins, self._pending = self._pending, []
        hosts_counters = [self._host_and_counters(w) for w in wins]
        self.attributor.attribute_batch(
            wins, [self._group_counts] * len(wins),
            [hc[1] for hc in hosts_counters],
            operating_point=self.operating_point)
        for win, (host, counters) in zip(wins, hosts_counters):
            self._observe(win, host, counters)

    def _host_and_counters(self, win: AlignedWindow):
        host = self._steps[win.step] if win.step < len(self._steps) else None
        counters = host.counters if host and host.counters else \
            self._window_counters(win)
        return host, counters

    def _observe(self, win: AlignedWindow, host, counters) -> None:
        if self.monitor is None:
            return
        # the window spans _group repetitions of the logical step, so
        # its work is the host step's work scaled by the same factor —
        # keeping joules_per_unit_work a true per-unit figure
        work = (host.work_units if host else 1.0) * self._group
        self.monitor.observe(
            host.step if host else win.step, self._group_counts,
            win.duration_s, counters=counters, work_units=work,
            measured_j=win.measured_j,
            operating_point=self.operating_point)

    def _window_counters(self, win: AlignedWindow) -> Optional[dict]:
        if self.record is None:
            return None
        iters = max(float(self.record.iters), 1.0)
        frac = self._group / iters
        return {k: self.record.counters.get(k, 0.0) * frac
                for k in _BYTE_COUNTERS}

    def _mape(self) -> float:
        return mape_pct(self.attributions)

    def _low_confidence(self) -> int:
        """This session's low-confidence windows (shared-attributor safe)."""
        return sum(1 for a in self.attributions if a.low_confidence)

    def health(self) -> dict:
        """Exact degradation counters for this session (JSON-safe).

        ``raw_samples`` counts everything the sampler delivered;
        ``quarantined`` (split by cause) is what the sanitizer rejected;
        the ``gap_*`` block is the aligner's sampling-gap accounting —
        ``gap_j`` is energy *included* in ``measured_j`` but interpolated
        across gaps rather than densely sampled.
        """
        san = self.sanitizer
        al = self._aligner
        return {
            "raw_samples": san.total_in,
            "quarantined": san.quarantined,
            "nonfinite": san.quarantined_nonfinite,
            "spikes": san.quarantined_spike,
            "out_of_order": san.quarantined_out_of_order,
            "stale_suspects": san.stale_suspects,
            "n_gaps": al.gap_events if al is not None else 0,
            "gap_s": al.gap_seconds if al is not None else 0.0,
            "gap_j": al.gap_joules if al is not None else 0.0,
            "gap_threshold_s": (al.gap_threshold_s if al is not None
                                else None),
            "low_confidence_windows": self._low_confidence(),
        }

    # -- kernel microscopy -----------------------------------------------------
    @property
    def kernel_windows(self) -> List[AlignedWindow]:
        """Every per-launch kernel window, in step order then launch order."""
        out: List[AlignedWindow] = []
        for w in self.windows:
            if w.step >= 0 and w.children:
                out.extend(w.children)
        return out

    def kernel_report(self) -> Dict[str, dict]:
        """Aggregate measured kernel energy across steps; name -> stats.

        Each step's kernel windows tile that step's measured joules
        bitwise, so the report's energies (plus ``__unattributed__``) sum
        to the attributed total.  ``launches`` counts actual device
        launches (``iterations_per_step`` per window), so ``j_per_launch``
        is a true per-call figure.
        """
        out: Dict[str, dict] = {}
        for w in self.windows:
            if w.step < 0 or not w.children:
                continue
            for c in w.children:
                d = out.setdefault(c.name, {
                    "name": c.name, "variant": c.variant,
                    "config": list(c.config), "energy_j": 0.0,
                    "duration_s": 0.0, "windows": 0, "launches": 0.0})
                d["energy_j"] += c.measured_j
                d["duration_s"] += c.duration_s
                d["windows"] += 1
                d["launches"] += self._group
        for d in out.values():
            n = max(d["launches"], 1.0)
            d["j_per_launch"] = d["energy_j"] / n
            d["s_per_launch"] = d["duration_s"] / n
        return out

    # -- inspection ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Live (or final) state for dashboards; JSON-safe.

        All statistics are session-local even when the attributor is
        shared across sessions (drift state is the live detector's).

        A session adopted from a shard worker serves the worker's frozen
        snapshot verbatim — the ring/plateau live state stayed remote.
        """
        if self._remote_snapshot is not None:
            return dict(self._remote_snapshot)
        latest = self.ring.latest()
        dev_pt = getattr(self.device, "operating_point", None)
        out = {
            "name": self.name,
            "device": self.device.name,
            "operating_point": None if dev_pt is None else
                {"freq_mhz": dev_pt.freq_mhz,
                 "power_cap_w": dev_pt.power_cap_w},
            "steps_registered": len(self._steps),
            "samples": self.ring.total,
            "dropped_samples": self.ring.dropped,
            "measured_j": self.integrator.energy_j,
            "power_w": latest.power_w if latest else None,
            "steady": (not math.isnan(self.plateau.start_s)),
            "windows": len(self.windows),
            "mape_pct": self._mape(),
            "drift_ratio": self.attributor.drift.ratio,
            "drifting": self.attributor.drift.drifting,
            "recalibrations": list(self.recalibrations),
            "finished": self.summary is not None,
            "health": self.health(),
        }
        if self.summary is not None:
            out["startup_j"] = self.summary.startup_j
            out["predicted_total_j"] = self.summary.predicted_total_j
        return out


class TelemetryService:
    """Multi-device aggregator: register sessions, export one snapshot.

    The production shape of the QMCPACK workflow (§5.3.2): every
    device/workload pair streams through its own session; the service is
    the single pane a dashboard or alerting hook polls.
    """

    def __init__(self):
        self._sessions: Dict[str, StreamSession] = {}
        self._billing: Dict[str, object] = {}   # key -> provider() -> dict
        self._governors: Dict[str, object] = {}  # key -> SweetSpotGovernor
        self._cursor = 0                         # poll_all round-robin start

    def register_governor(self, key: str, governor) -> None:
        """Attach a DVFS governor pane: its decision history and per-point
        statistics ride the fleet snapshot (``snapshot()["governors"]``).
        Re-registering a key replaces the governor."""
        if not hasattr(governor, "snapshot"):
            raise TypeError("governor must expose snapshot()")
        self._governors[key] = governor

    def register_billing(self, key: str, provider) -> None:
        """Attach a billing pane: ``provider()`` -> JSON-safe dict.

        The serving layer (``serve.EnergyServer``) registers its report
        here so per-tenant bills ride the same snapshot the dashboard
        already polls.  Re-registering a key replaces the provider (a
        server's latest run supersedes the previous one).
        """
        if not callable(provider):
            raise TypeError("billing provider must be callable")
        self._billing[key] = provider

    def register(self, session: StreamSession,
                 key: Optional[str] = None) -> StreamSession:
        key = key or f"{session.device.name}/{session.name}"
        if key in self._sessions:
            raise KeyError(f"session {key!r} already registered")
        self._sessions[key] = session
        return session

    def __len__(self) -> int:
        return len(self._sessions)

    def sessions(self) -> Dict[str, StreamSession]:
        return dict(self._sessions)

    def poll_all(self, max_chunks: int = 1) -> int:
        """Drain every started session's sampler, one pass over the fleet.

        Each session ingests up to ``max_chunks`` chunks through its full
        pipeline (ring, integrator, plateau, alignment, batched
        attribution).  Returns the total samples consumed; ``0`` means every
        registered session is either finished or not yet started — the
        monitor loop's termination condition:

            while service.poll_all(max_chunks=4):
                render(service.snapshot())

        Sessions drain round-robin from a rotating cursor, not in
        registration order: with unequal backlogs and a small
        ``max_chunks`` budget, dict-order draining lets early-registered
        sessions monopolize every pass while late ones starve.
        """
        keys = [k for k, s in self._sessions.items()
                if s.summary is None and s.started]
        if not keys:
            return 0
        start = self._cursor % len(keys)
        self._cursor += 1
        total = 0
        for k in keys[start:] + keys[:start]:
            total += self._sessions[k].poll(max_chunks)
        return total

    def finish_all(self) -> Dict[str, "StreamSummary"]:
        """Drain and summarize every started session; key -> summary."""
        return {k: s.finish() for k, s in self._sessions.items()
                if s.started or s.summary is not None}

    def snapshot(self) -> dict:
        per = {key: s.snapshot() for key, s in self._sessions.items()}
        anomalies = sum(len(s.monitor.anomalies)
                        for s in self._sessions.values()
                        if s.monitor is not None)
        out = {
            "sessions": per,
            "fleet": fleet_block(per, anomalies),
        }
        if self._billing:
            out["billing"] = {k: fn() for k, fn in self._billing.items()}
        if self._governors:
            out["governors"] = {k: g.snapshot()
                                for k, g in self._governors.items()}
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
