"""Telemetry service: per-workload stream sessions + a fleet aggregator.

``StreamSession`` is the full pipeline for one workload on one device —
ingestion → alignment → attribution → monitoring:

    session = model.stream(counts, name="train_step")
    for step in range(N):
        ...                                  # host executes the real step
        session.step(step, duration_s=dt, work_units=tokens)
    summary = session.finish()               # sample, align, attribute

The host loop registers *logical* steps (MTSM sync points); ``finish`` runs
the program on the device with a background-style sampler, places one
marker per logical step across the active span, streams every sample
through a bounded ring + O(1) integrator + online plateau detector + the
``StreamAligner``, and fuses each finalized window with the table
prediction (drift detection and recalibration included).  On real hardware
the sampler would be a polling thread racing the app; the simulated device
executes first and the pipeline consumes the identical sample stream.

``TelemetryService`` aggregates sessions across devices/workloads with a
JSON-exportable snapshot — what a fleet dashboard would poll.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.opcount import OpCounts
from repro.core.predict import TablePredictor
from repro.hw.device import Program, RunRecord, SimDevice
from repro.telemetry.align import (AlignedWindow, Marker, StreamAligner,
                                   contiguous_markers)
from repro.telemetry.attrib import DriftState, OnlineAttributor, mape_pct
from repro.telemetry.sampler import DeviceSampler, SampleRing
from repro.telemetry.stream import OnlineSteadyState, StreamingIntegrator

_BYTE_COUNTERS = ("hbm_read_bytes", "hbm_write_bytes",
                  "vmem_read_bytes", "vmem_write_bytes")


@dataclasses.dataclass
class _HostStep:
    """A logical step as the host loop saw it."""

    step: int
    host_duration_s: Optional[float]
    work_units: float
    counters: Optional[dict]


@dataclasses.dataclass
class StreamSummary:
    """What one finished stream session learned."""

    name: str
    steps: int
    duration_s: float
    measured_total_j: float       # streaming integral over the whole trace
    predicted_total_j: float      # sum of per-window predictions
    startup_j: float              # energy before the first step marker
    mape_pct: float
    drift: DriftState
    recalibrations: List[float]
    host_duration_s: Optional[float]   # summed host wall-clock, when reported
    n_samples: int
    dropped_samples: int

    @property
    def attributed_j(self) -> float:
        return self.measured_total_j - self.startup_j


class StreamSession:
    """One workload's streaming pipeline (see module docstring)."""

    def __init__(self, predictor: TablePredictor, device: SimDevice,
                 counts: OpCounts, name: str = "workload", *,
                 monitor=None, min_duration_s: float = 30.0,
                 ring_capacity: int = 4096,
                 recalibrate="rescale", store=None,
                 detector=None, attributor: Optional[OnlineAttributor] = None):
        self.predictor = predictor
        self.device = device
        self.counts = counts
        self.name = name
        self.monitor = monitor
        self.min_duration_s = float(min_duration_s)
        self.ring = SampleRing(ring_capacity)
        self.integrator = StreamingIntegrator()
        self.plateau = OnlineSteadyState()
        # pass a previous session's attributor to carry the drift baseline
        # across runs of the same workload (the long-lived fleet posture)
        self.attributor = attributor or OnlineAttributor(
            predictor, detector=detector, recalibrate=recalibrate,
            store=store)
        self.windows: List[AlignedWindow] = []
        self.startup_j = 0.0
        self.record: Optional[RunRecord] = None
        self.summary: Optional[StreamSummary] = None
        self._steps: List[_HostStep] = []
        self._group = 1.0            # device iterations per logical step
        self._group_counts = counts  # counts per marker window
        # session-local slices into a possibly shared attributor
        self._a0 = len(self.attributor.attributions)
        self._recal0 = len(self.attributor.recalibrations)

    @property
    def attributions(self):
        """This session's StepAttributions (shared-attributor safe)."""
        return self.attributor.attributions[self._a0:]

    @property
    def recalibrations(self) -> List[float]:
        """Recalibration factors applied during this session."""
        return self.attributor.recalibrations[self._recal0:]

    @property
    def steps_registered(self) -> int:
        return len(self._steps)

    # -- host-loop surface ---------------------------------------------------
    def step(self, step: Optional[int] = None,
             duration_s: Optional[float] = None, work_units: float = 1.0,
             counters: Optional[dict] = None) -> None:
        """Register one logical step (an MTSM sync point).

        ``duration_s`` is the *host* wall-clock for the step, recorded for
        reporting (``summary.host_duration_s``); alignment itself follows
        the device trace's own timeline — the sampler watches the device
        clock, and the device executes the profiled counts uniformly.
        """
        if self.summary is not None:
            raise RuntimeError("session already finished")
        idx = step if step is not None else len(self._steps)
        self._steps.append(_HostStep(idx, duration_s, work_units, counters))

    def finish(self, steps: Optional[int] = None) -> StreamSummary:
        """Sample the device run, align markers, attribute every window."""
        if self.summary is not None:
            return self.summary
        n = steps if steps is not None else len(self._steps)
        if n <= 0:
            raise ValueError("no steps registered; call session.step(...) "
                             "or finish(steps=N)")
        while len(self._steps) < n:
            self._steps.append(_HostStep(len(self._steps), None, 1.0, None))

        # Long enough to pass startup and reach a steady plateau; the extra
        # device iterations are folded evenly into the n logical windows.
        iters = max(n, self.device.iters_for_duration(
            self.counts, self.min_duration_s))
        iters = (iters // n) * n                 # equal-sized groups
        self._group = iters / n
        self._group_counts = self.counts.scaled(self._group)

        rec, sampler = DeviceSampler(self.device).run(
            Program(self.name, self.counts, iters=iters))
        self.record = rec

        aligner = StreamAligner(on_window=self._on_window)
        for m in self._markers(rec, n):
            aligner.add_marker(m)
        for s in sampler:
            self.ring.append(s)
            self.integrator.add(s.t_s, s.power_w)
            self.plateau.update(s.t_s, s.power_w)
            aligner.add_sample(s)
        aligner.close()

        host_dts = [h.host_duration_s for h in self._steps
                    if h.host_duration_s is not None]
        self.summary = StreamSummary(
            name=self.name, steps=n, duration_s=rec.duration_s,
            measured_total_j=self.integrator.energy_j,
            predicted_total_j=float(sum(
                a.predicted_j for a in self.attributions)),
            startup_j=self.startup_j,
            mape_pct=self._mape(),
            drift=self.attributor.drift,
            recalibrations=list(self.recalibrations),
            host_duration_s=float(sum(host_dts)) if host_dts else None,
            n_samples=self.integrator.n_samples,
            dropped_samples=self.ring.dropped)
        return self.summary

    run = finish     # one-shot callers: ``model.stream(c).run(steps=N)``

    # -- internals -----------------------------------------------------------
    def _markers(self, rec: RunRecord, n: int) -> List[Marker]:
        """One marker per logical step across the trace's active span.

        The active-span start is read from telemetry (the util ramp), never
        from the device's hidden model.
        """
        t, u = rec.trace.times_s, rec.trace.util
        umax = float(np.max(u)) if len(u) else 0.0
        if umax > 0:
            t_act = float(t[np.argmax(u >= umax - 1e-9)])
        else:
            t_act = float(t[0])
        t_end = float(t[-1])
        if t_act >= t_end:
            t_act = float(t[0])
        markers: List[Marker] = []
        if t_act > t[0]:
            markers.append(Marker(step=-1, name="__startup__",
                                  t_start_s=float(t[0]), t_end_s=t_act))
        bounds = np.linspace(t_act, t_end, n + 1)
        markers.extend(contiguous_markers(
            bounds, names=[f"{self.name}[{h.step}]" for h in self._steps[:n]],
            first_step=0))
        return markers

    def _on_window(self, win: AlignedWindow) -> None:
        self.windows.append(win)
        if win.step < 0:                      # pre-marker span: not a step
            self.startup_j += win.measured_j
            return
        host = self._steps[win.step] if win.step < len(self._steps) else None
        counters = host.counters if host and host.counters else \
            self._window_counters(win)
        self.attributor.attribute(win, self._group_counts, counters=counters)
        if self.monitor is not None:
            # the window spans _group repetitions of the logical step, so
            # its work is the host step's work scaled by the same factor —
            # keeping joules_per_unit_work a true per-unit figure
            work = (host.work_units if host else 1.0) * self._group
            self.monitor.observe(
                host.step if host else win.step, self._group_counts,
                win.duration_s, counters=counters, work_units=work,
                measured_j=win.measured_j)

    def _window_counters(self, win: AlignedWindow) -> Optional[dict]:
        if self.record is None:
            return None
        iters = max(float(self.record.iters), 1.0)
        frac = self._group / iters
        return {k: self.record.counters.get(k, 0.0) * frac
                for k in _BYTE_COUNTERS}

    def _mape(self) -> float:
        return mape_pct(self.attributions)

    # -- inspection ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Live (or final) state for dashboards; JSON-safe.

        All statistics are session-local even when the attributor is
        shared across sessions (drift state is the live detector's).
        """
        latest = self.ring.latest()
        out = {
            "name": self.name,
            "device": self.device.name,
            "steps_registered": len(self._steps),
            "samples": self.ring.total,
            "dropped_samples": self.ring.dropped,
            "measured_j": self.integrator.energy_j,
            "power_w": latest.power_w if latest else None,
            "steady": (not math.isnan(self.plateau.start_s)),
            "windows": len(self.windows),
            "mape_pct": self._mape(),
            "drift_ratio": self.attributor.drift.ratio,
            "drifting": self.attributor.drift.drifting,
            "recalibrations": list(self.recalibrations),
            "finished": self.summary is not None,
        }
        if self.summary is not None:
            out["startup_j"] = self.summary.startup_j
            out["predicted_total_j"] = self.summary.predicted_total_j
        return out


class TelemetryService:
    """Multi-device aggregator: register sessions, export one snapshot.

    The production shape of the QMCPACK workflow (§5.3.2): every
    device/workload pair streams through its own session; the service is
    the single pane a dashboard or alerting hook polls.
    """

    def __init__(self):
        self._sessions: Dict[str, StreamSession] = {}

    def register(self, session: StreamSession,
                 key: Optional[str] = None) -> StreamSession:
        key = key or f"{session.device.name}/{session.name}"
        if key in self._sessions:
            raise KeyError(f"session {key!r} already registered")
        self._sessions[key] = session
        return session

    def __len__(self) -> int:
        return len(self._sessions)

    def sessions(self) -> Dict[str, StreamSession]:
        return dict(self._sessions)

    def snapshot(self) -> dict:
        per = {key: s.snapshot() for key, s in self._sessions.items()}
        anomalies = sum(len(s.monitor.anomalies)
                        for s in self._sessions.values()
                        if s.monitor is not None)
        return {
            "sessions": per,
            "fleet": {
                "n_sessions": len(per),
                "measured_j": sum(p["measured_j"] for p in per.values()),
                "samples": sum(p["samples"] for p in per.values()),
                "drifting": sorted(k for k, p in per.items()
                                   if p["drifting"]),
                "anomalies": anomalies,
            },
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
