"""Deterministic fault injection + stream sanitization for telemetry.

Real sensor feeds (NVML poll loops, SMC counters) are not the clean
streams the sim produces: they drop samples, return NaN or railed power
readings, repeat stale values, deliver duplicated or reordered
timestamps, and arrive in delayed bursts when the host stalls.  This
module provides both halves of hardening against that:

``ChaosPlan`` / ``FaultySampler``
    A seedable wrapper around any sampler that injects those faults
    *deterministically*: faults are laid out per fixed-size granule
    (``plan.granularity`` samples) with a per-granule
    ``np.random.default_rng((seed, granule))``, so the faulted stream is
    byte-identical regardless of the consumer's chunk size — the
    scalar-vs-chunked bitwise invariant survives chaos.  With every
    fault fraction at zero the wrapper is an identity pass-through
    (bitwise: it yields the inner sampler's own chunks).  Injected
    counts are tallied exactly in a ``ChaosReport``.

``StreamSanitizer``
    The ingest-side defense: rejects non-finite and railed ("spike")
    power readings and non-monotonic timestamps, counts repeated-value
    stale suspects, and keeps exact quarantine counters.  The monotonic
    filter is vectorized via a prefix-max: a sample rejected for
    ``t <= running max`` can never raise that max, so "accept iff
    ``t_i > max(carry, cummax of prior valid t)``" reproduces the
    sequential filter exactly — the chunked and per-sample paths make
    bitwise-identical accept decisions.  Clean chunks are returned as
    the *original* array objects (zero-copy, bitwise pass-through).

Shard-level faults (worker crash/hang) are carried on the same plan but
acted on by the ``TelemetryPlane`` supervisor, not here.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.telemetry.sampler import DEFAULT_CHUNK, PowerSample, iter_chunks

#: Default quarantine bound for |power| readings — far above any real
#: device (railed/garbage sensor values sit at 1e5+ W), far below the
#: injected spike magnitude.
SENSOR_MAX_W = 1e4


# ---------------------------------------------------------------------------
# Plan + report.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """A deterministic fault-injection schedule.

    Stream faults (everything except ``crash_*``/``hang_*``) are applied
    by ``FaultySampler`` per granule; shard faults are read by the
    telemetry plane's supervisor.  ``fraction`` fields are per-sample
    probabilities realized as exact per-granule counts
    (``round(fraction * granule)``), so injected totals are reproducible
    and countable, not merely expected values.
    """

    seed: int = 0
    # -- stream faults ------------------------------------------------------
    drop_fraction: float = 0.0     # samples deleted (gaps)
    nan_fraction: float = 0.0      # samples with NaN power
    nan_burst: int = 1             # NaNs arrive in runs of this length
    spike_fraction: float = 0.0    # samples with railed power
    spike_w: float = 1e6
    stale_fraction: float = 0.0    # samples repeating the previous power
    stale_run: int = 1
    dup_fraction: float = 0.0      # samples duplicating the previous sample
    swap_fraction: float = 0.0     # adjacent timestamp swaps
    coalesce_every: int = 0        # deliver chunks in bursts of this many
    granularity: int = DEFAULT_CHUNK
    # -- shard faults (plane supervisor) ------------------------------------
    crash_shards: Tuple[int, ...] = ()
    crash_attempts: int = 1        # crash the first N attempts, then succeed
    hang_shards: Tuple[int, ...] = ()
    hang_s: float = 120.0

    @property
    def stream_enabled(self) -> bool:
        return (self.drop_fraction > 0 or self.nan_fraction > 0
                or self.spike_fraction > 0 or self.stale_fraction > 0
                or self.dup_fraction > 0 or self.swap_fraction > 0
                or self.coalesce_every > 1)

    @property
    def shard_enabled(self) -> bool:
        return bool(self.crash_shards) or bool(self.hang_shards)

    @property
    def enabled(self) -> bool:
        return self.stream_enabled or self.shard_enabled

    @classmethod
    def profile(cls, name: str, seed: int = 0) -> "ChaosPlan":
        """Named presets: ``none``, ``light``, ``heavy``."""
        if name == "none":
            return cls(seed=seed)
        if name == "light":
            return cls(seed=seed, drop_fraction=0.01, nan_fraction=0.005,
                       spike_fraction=0.002, stale_fraction=0.002,
                       dup_fraction=0.001, swap_fraction=0.001)
        if name == "heavy":
            return cls(seed=seed, drop_fraction=0.06, nan_fraction=0.02,
                       nan_burst=8, spike_fraction=0.01,
                       stale_fraction=0.01, stale_run=4,
                       dup_fraction=0.005, swap_fraction=0.005,
                       coalesce_every=3, crash_shards=(0,),
                       crash_attempts=1)
        raise ValueError(f"unknown chaos profile {name!r}; "
                         "expected none|light|heavy")

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent,
                          sort_keys=True)


@dataclasses.dataclass
class ChaosReport:
    """Exact injected-fault tallies, accumulated per granule.

    ``drop_events`` counts maximal runs of dropped samples that sit
    *between* two delivered samples (leading/trailing runs shift the
    stream edge but open no gap), so on a regular-dt trace with a
    drops-only plan it equals the aligner's gap-segment count exactly.
    """

    granules: int = 0
    samples_in: int = 0
    samples_out: int = 0
    dropped: int = 0
    drop_events: int = 0
    nan_samples: int = 0
    nan_events: int = 0
    spikes: int = 0
    stale_samples: int = 0
    stale_events: int = 0
    dup_samples: int = 0
    swapped_pairs: int = 0

    @property
    def expected_quarantine(self) -> dict:
        """What a ``StreamSanitizer`` must report for this stream."""
        return {"nonfinite": self.nan_samples,
                "spikes": self.spikes,
                "out_of_order": self.dup_samples + self.swapped_pairs}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent,
                          sort_keys=True)


# ---------------------------------------------------------------------------
# Injection.
# ---------------------------------------------------------------------------
def _n_events(fraction: float, m: int, run: int) -> int:
    return int(round(fraction * m / max(run, 1)))


class FaultySampler:
    """Wraps any sampler, injecting ``plan``'s stream faults.

    Exposes the standard sampler surface (``chunks(n)`` / ``__iter__``)
    and yields the *same* faulted sample sequence on both — faults are
    laid out per ``plan.granularity``-sized granule, independent of the
    consumer's chunk size.  Single-pass: the stream (and its
    ``report``) is consumed once.
    """

    def __init__(self, inner, plan: ChaosPlan):
        self.inner = inner
        self.plan = plan
        self.report = ChaosReport()
        self._emitted_any = False    # a sample has been delivered
        self._pending_gap = False    # drops seen since the last delivery
        self._consumed = False

    # -- sampler surface ----------------------------------------------------
    def chunks(self, n: int = DEFAULT_CHUNK):
        if not self.plan.stream_enabled:
            yield from iter_chunks(self.inner, n)   # identity, bitwise
            return
        burst = max(int(self.plan.coalesce_every), 1)
        target = burst * n     # delayed delivery: hold, then burst
        parts: List[tuple] = []
        held = 0
        for arrs in self._granules():
            if arrs[0].size == 0:
                continue
            parts.append(arrs)
            held += arrs[0].size
            while held >= target:
                t, p, u, c = (np.concatenate([q[i] for q in parts])
                              for i in range(4))
                yield t[:target], p[:target], u[:target], c[:target]
                rest = (t[target:], p[target:], u[target:], c[target:])
                parts = [rest] if rest[0].size else []
                held -= target
        if held:
            yield tuple(np.concatenate([q[i] for q in parts])
                        for i in range(4))

    def __iter__(self) -> Iterator[PowerSample]:
        if not self.plan.stream_enabled:
            yield from iter(self.inner)
            return
        for t, p, u, c in self._granules():
            for i in range(t.size):
                yield PowerSample(float(t[i]), float(p[i]), float(u[i]),
                                  float(c[i]))

    # -- internals ----------------------------------------------------------
    def _granules(self):
        if self._consumed:
            raise RuntimeError("FaultySampler is single-pass; wrap the "
                               "source again for another run")
        self._consumed = True
        for idx, (t, p, u, c) in enumerate(
                iter_chunks(self.inner, self.plan.granularity)):
            yield self._fault(idx, t, p, u, c)

    def _fault(self, idx: int, t, p, u, c):
        plan, rep = self.plan, self.report
        t = np.array(t, dtype=float)
        p = np.array(p, dtype=float)
        u = np.array(u, dtype=float)
        c = np.array(c, dtype=float)
        m = int(t.size)
        rep.granules += 1
        rep.samples_in += m
        if m == 0:
            return t, p, u, c
        rng = np.random.default_rng((plan.seed, idx))
        used = np.zeros(m, dtype=bool)

        def scan(count, valid, apply):
            done = 0
            if count <= 0:
                return
            for i in rng.permutation(m):
                if done >= count:
                    return
                i = int(i)
                if valid(i):
                    apply(i)
                    done += 1

        # Categories draw disjoint index sets (``used``) in a fixed
        # order, so every injected fault survives to reach the sanitizer
        # and the report's tallies match quarantine counters exactly.
        burst = max(int(plan.nan_burst), 1)

        def nan_ok(i):
            j = min(i + burst, m)
            return not used[i:j].any()

        def nan_do(i):
            j = min(i + burst, m)
            p[i:j] = np.nan
            used[i:j] = True
            rep.nan_samples += j - i
            rep.nan_events += 1

        scan(_n_events(plan.nan_fraction, m, burst), nan_ok, nan_do)

        def spike_do(i):
            p[i] = plan.spike_w
            used[i] = True
            rep.spikes += 1

        scan(_n_events(plan.spike_fraction, m, 1),
             lambda i: not used[i], spike_do)

        run = max(int(plan.stale_run), 1)

        def stale_ok(i):
            j = min(i + run, m)
            return i >= 1 and not used[i - 1:j].any()

        def stale_do(i):
            j = min(i + run, m)
            p[i:j] = p[i - 1]         # sensor repeats its last reading
            used[i - 1:j] = True      # keep the source value pristine
            rep.stale_samples += j - i
            rep.stale_events += 1

        scan(_n_events(plan.stale_fraction, m, run), stale_ok, stale_do)

        def dup_do(i):
            t[i], p[i], u[i], c[i] = t[i - 1], p[i - 1], u[i - 1], c[i - 1]
            used[i - 1:i + 1] = True
            rep.dup_samples += 1

        scan(_n_events(plan.dup_fraction, m, 1),
             lambda i: i >= 1 and not used[i - 1:i + 1].any(), dup_do)

        def swap_do(i):
            for a in (t, p, u, c):
                a[i], a[i + 1] = a[i + 1], a[i]
            used[i:i + 2] = True
            rep.swapped_pairs += 1

        scan(_n_events(plan.swap_fraction, m, 1),
             lambda i: i + 1 < m and not used[i:i + 2].any(), swap_do)

        drop = np.zeros(m, dtype=bool)

        def drop_do(i):
            drop[i] = True
            used[i] = True
            rep.dropped += 1

        scan(_n_events(plan.drop_fraction, m, 1),
             lambda i: not used[i], drop_do)

        keep = np.flatnonzero(~drop)
        if keep.size:
            if self._pending_gap or (self._emitted_any and keep[0] > 0):
                rep.drop_events += 1
            rep.drop_events += int(np.count_nonzero(np.diff(keep) > 1))
            self._emitted_any = True
            self._pending_gap = bool(m - 1 - keep[-1] > 0)
        elif self._emitted_any:
            self._pending_gap = True
        rep.samples_out += int(keep.size)
        if keep.size < m:
            t, p, u, c = t[keep], p[keep], u[keep], c[keep]
        return t, p, u, c


# ---------------------------------------------------------------------------
# Sanitization.
# ---------------------------------------------------------------------------
class StreamSanitizer:
    """Quarantines invalid samples with exact counters.

    Rejection precedence per sample: non-finite ``t``/``p`` first, then
    ``|p| > power_bound_w`` (railed/spiked reading), then non-monotonic
    timestamp (``t`` must strictly exceed the last accepted ``t``).
    ``util``/``temp`` are auxiliary and may legitimately be NaN.
    Accepted samples whose power exactly repeats the previous accepted
    power increment ``stale_suspects`` — a heuristic counter only (a
    quantized sensor produces genuine repeats); nothing is rejected for
    staleness.

    ``chunk`` returns the original array objects untouched when every
    sample is accepted, so clean streams pass through zero-copy and
    bitwise-identical.  The chunked and per-sample paths make identical
    accept decisions (prefix-max equivalence; see module docstring).
    """

    def __init__(self, power_bound_w: float = SENSOR_MAX_W):
        self.power_bound_w = float(power_bound_w)
        self.total_in = 0
        self.quarantined_nonfinite = 0
        self.quarantined_spike = 0
        self.quarantined_out_of_order = 0
        self.stale_suspects = 0
        self._last_t = -math.inf
        self._last_p = math.nan     # NaN: first sample never a stale suspect

    @property
    def quarantined(self) -> int:
        return (self.quarantined_nonfinite + self.quarantined_spike
                + self.quarantined_out_of_order)

    # -- chunked path -------------------------------------------------------
    def chunk(self, t, p, u, c):
        ta = np.asarray(t)
        pa = np.asarray(p)
        m = int(ta.size)
        self.total_in += m
        if m == 0:
            return t, p, u, c
        finite = np.isfinite(ta) & np.isfinite(pa)
        spike = finite & (np.abs(pa) > self.power_bound_w)
        valid = finite & ~spike
        self.quarantined_nonfinite += m - int(np.count_nonzero(finite))
        self.quarantined_spike += int(np.count_nonzero(spike))
        all_valid = bool(valid.all())
        idx = None if all_valid else np.flatnonzero(valid)
        tv = ta if all_valid else ta[idx]
        if tv.size == 0:
            return ta[:0], pa[:0], np.asarray(u)[:0], np.asarray(c)[:0]
        cm = np.maximum.accumulate(tv)
        prev = np.empty_like(cm)
        prev[0] = self._last_t
        np.maximum(cm[:-1], self._last_t, out=prev[1:])
        accept = tv > prev
        self._last_t = max(self._last_t, float(cm[-1]))
        n_ooo = int(tv.size) - int(np.count_nonzero(accept))
        self.quarantined_out_of_order += n_ooo
        if all_valid and n_ooo == 0:
            self._count_stale(pa)
            return t, p, u, c           # clean: original objects, zero-copy
        final = (np.flatnonzero(accept) if idx is None
                 else idx[np.flatnonzero(accept)])
        p2 = pa[final]
        self._count_stale(p2)
        return ta[final], p2, np.asarray(u)[final], np.asarray(c)[final]

    def _count_stale(self, p_accepted: np.ndarray) -> None:
        if p_accepted.size == 0:
            return
        prev = np.empty_like(p_accepted)
        prev[0] = self._last_p
        prev[1:] = p_accepted[:-1]
        self.stale_suspects += int(np.count_nonzero(p_accepted == prev))
        self._last_p = float(p_accepted[-1])

    # -- per-sample path ----------------------------------------------------
    def sample(self, s: PowerSample) -> bool:
        """Accept/reject one sample; mirrors ``chunk`` bitwise."""
        self.total_in += 1
        if not (math.isfinite(s.t_s) and math.isfinite(s.power_w)):
            self.quarantined_nonfinite += 1
            return False
        if abs(s.power_w) > self.power_bound_w:
            self.quarantined_spike += 1
            return False
        if not s.t_s > self._last_t:
            self.quarantined_out_of_order += 1
            return False
        if s.power_w == self._last_p:
            self.stale_suspects += 1
        self._last_t = s.t_s
        self._last_p = s.power_w
        return True

    # -- state --------------------------------------------------------------
    def state_dict(self) -> dict:
        return {"power_bound_w": self.power_bound_w,
                "total_in": self.total_in,
                "quarantined_nonfinite": self.quarantined_nonfinite,
                "quarantined_spike": self.quarantined_spike,
                "quarantined_out_of_order": self.quarantined_out_of_order,
                "stale_suspects": self.stale_suspects,
                "last_t": self._last_t, "last_p": self._last_p}

    def load_state(self, state: dict) -> "StreamSanitizer":
        self.power_bound_w = float(state["power_bound_w"])
        self.total_in = int(state["total_in"])
        self.quarantined_nonfinite = int(state["quarantined_nonfinite"])
        self.quarantined_spike = int(state["quarantined_spike"])
        self.quarantined_out_of_order = int(
            state["quarantined_out_of_order"])
        self.stale_suspects = int(state["stale_suspects"])
        self._last_t = float(state["last_t"])
        self._last_p = float(state["last_p"])
        return self
