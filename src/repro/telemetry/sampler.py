"""Background-style power samplers — the SMA half of Arafa et al.'s
Sampling Monitoring Approach, adapted to this repo's simulated substrate.

A sampler is anything iterable over ``PowerSample``s in time order.  Three
sources cover the deployment spectrum:

* ``TraceReplaySampler`` — replays a recorded ``SensorTrace`` (post-hoc
  analysis of archived telemetry through the *same* code path as live).
* ``DeviceSampler`` — runs a program on a ``SimDevice`` and streams the
  resulting NVML-style trace as if a background thread were polling the
  sensor during execution (the container has no real sensors, so the run
  completes first; every consumer still sees one sample at a time).
* ``FeedSampler`` — adapts a raw feed (iterable of tuples or a poll
  callable) from a real collector daemon.

``SampleRing`` is the bounded buffer between producer and consumers: O(1)
append, overwrite-oldest semantics with a drop counter, snapshot to arrays.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Iterator, Optional, Tuple

import numpy as np

from repro.hw.device import Program, RunRecord, SensorTrace, SimDevice


@dataclasses.dataclass
class PowerSample:
    """One telemetry reading."""

    t_s: float
    power_w: float
    util: float = math.nan
    temp_c: float = math.nan


class SampleRing:
    """Bounded ring buffer of power samples.

    A production collector outlives any single consumer; the ring caps
    memory while exposing the recent window.  ``dropped`` counts samples
    the ring has overwritten (no longer reachable via ``arrays()`` /
    ``to_trace()`` — consumers reading the live stream still saw them).
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        self.capacity = int(capacity)
        self._t = np.zeros(self.capacity)
        self._p = np.zeros(self.capacity)
        self._u = np.full(self.capacity, math.nan)
        self._c = np.full(self.capacity, math.nan)
        self._head = 0          # next write slot
        self._count = 0         # valid samples (<= capacity)
        self.total = 0          # samples ever appended
        self.dropped = 0        # overwritten before being snapshotted

    def __len__(self) -> int:
        return self._count

    def append(self, s: PowerSample) -> None:
        if self._count == self.capacity:
            self.dropped += 1
        self._t[self._head] = s.t_s
        self._p[self._head] = s.power_w
        self._u[self._head] = s.util
        self._c[self._head] = s.temp_c
        self._head = (self._head + 1) % self.capacity
        self._count = min(self._count + 1, self.capacity)
        self.total += 1

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """(times, power) of the buffered window, oldest first (copies)."""
        idx = self._order()
        return self._t[idx].copy(), self._p[idx].copy()

    def latest(self) -> Optional[PowerSample]:
        if self._count == 0:
            return None
        i = (self._head - 1) % self.capacity
        return PowerSample(float(self._t[i]), float(self._p[i]),
                           float(self._u[i]), float(self._c[i]))

    def to_trace(self) -> SensorTrace:
        """The buffered window as a ``SensorTrace`` (for offline tooling)."""
        idx = self._order()
        return SensorTrace(self._t[idx].copy(), self._p[idx].copy(),
                           self._u[idx].copy(), self._c[idx].copy())

    def _order(self) -> np.ndarray:
        if self._count < self.capacity:
            return np.arange(self._count)
        return (np.arange(self.capacity) + self._head) % self.capacity


# ---------------------------------------------------------------------------
# Sources.
# ---------------------------------------------------------------------------
class TraceReplaySampler:
    """Streams a recorded ``SensorTrace`` sample by sample."""

    def __init__(self, trace: SensorTrace):
        self.trace = trace

    def __iter__(self) -> Iterator[PowerSample]:
        t, p, u, c = (self.trace.times_s, self.trace.power_w,
                      self.trace.util, self.trace.temp_c)
        for i in range(len(t)):
            yield PowerSample(float(t[i]), float(p[i]), float(u[i]),
                              float(c[i]))


class FeedSampler:
    """Adapts a raw sample feed: an iterable of ``PowerSample``s /
    ``(t, p[, util[, temp]])`` tuples, or a zero-arg poll callable returning
    the same (``None`` ends the stream)."""

    def __init__(self, feed):
        self._feed = feed

    @staticmethod
    def _coerce(item) -> PowerSample:
        if isinstance(item, PowerSample):
            return item
        t, p, *rest = item
        u = rest[0] if len(rest) > 0 else math.nan
        c = rest[1] if len(rest) > 1 else math.nan
        return PowerSample(float(t), float(p), float(u), float(c))

    def __iter__(self) -> Iterator[PowerSample]:
        if callable(self._feed):
            while True:
                item = self._feed()
                if item is None:
                    return
                yield self._coerce(item)
        else:
            for item in self._feed:
                yield self._coerce(item)


class DeviceSampler:
    """Background-monitor view of a ``SimDevice`` execution.

    ``run`` executes the program and returns ``(record, sampler)`` where the
    sampler replays the run's telemetry in sensor order — the streaming
    pipeline consumes it exactly as it would a live NVML poll loop.
    """

    def __init__(self, device: SimDevice):
        self.device = device

    def run(self, program: Program) -> Tuple[RunRecord, TraceReplaySampler]:
        rec = self.device.run(program)
        return rec, TraceReplaySampler(rec.trace)

    def idle(self, duration_s: float = 30.0) -> TraceReplaySampler:
        return TraceReplaySampler(self.device.idle(duration_s))
