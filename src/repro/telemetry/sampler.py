"""Background-style power samplers — the SMA half of Arafa et al.'s
Sampling Monitoring Approach, adapted to this repo's simulated substrate.

A sampler is anything iterable over ``PowerSample``s in time order.  Three
sources cover the deployment spectrum:

* ``TraceReplaySampler`` — replays a recorded ``SensorTrace`` (post-hoc
  analysis of archived telemetry through the *same* code path as live).
* ``DeviceSampler`` — runs a program on a ``SimDevice`` and streams the
  resulting NVML-style trace as if a background thread were polling the
  sensor during execution (the container has no real sensors, so the run
  completes first; every consumer still sees one sample at a time).
* ``FeedSampler`` — adapts a raw feed (iterable of tuples or a poll
  callable) from a real collector daemon.

``SampleRing`` is the bounded buffer between producer and consumers: O(1)
append, overwrite-oldest semantics with a drop counter, snapshot to arrays.

Chunked ingestion is the first-class fast path: every sampler grows a
``chunks(n)`` iterator yielding ``(times, power, util, temp)`` ndarray
quadruples (``TraceReplaySampler`` serves zero-copy slices of the recorded
arrays — no per-sample object construction at all), and ``SampleRing.extend``
writes a whole chunk with at most two wrap-aware slice copies.  The
per-sample ``PowerSample`` path is preserved as the reference implementation
the chunked path is tested bitwise against.  ``iter_chunks`` adapts any
sampler — chunk-native or per-sample — into the chunked consume loop.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Iterator, Optional, Tuple

import numpy as np

from repro.hw.device import Program, RunRecord, SensorTrace, SimDevice

DEFAULT_CHUNK = 4096

#: (times_s, power_w, util, temp_c) arrays of equal length — the chunked
#: currency every sampler's ``chunks(n)`` yields and the whole telemetry
#: stack ingests.
SampleChunk = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class PowerSample:
    """One telemetry reading."""

    t_s: float
    power_w: float
    util: float = math.nan
    temp_c: float = math.nan


class SampleRing:
    """Bounded ring buffer of power samples.

    A production collector outlives any single consumer; the ring caps
    memory while exposing the recent window.  ``dropped`` counts samples
    the ring has overwritten (no longer reachable via ``arrays()`` /
    ``to_trace()`` — consumers reading the live stream still saw them).
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        self.capacity = int(capacity)
        self._t = np.zeros(self.capacity)
        self._p = np.zeros(self.capacity)
        self._u = np.full(self.capacity, math.nan)
        self._c = np.full(self.capacity, math.nan)
        self._head = 0          # next write slot
        self._count = 0         # valid samples (<= capacity)
        self.total = 0          # samples ever appended
        self.dropped = 0        # overwritten before being snapshotted

    def __len__(self) -> int:
        return self._count

    def append(self, s: PowerSample) -> None:
        if self._count == self.capacity:
            self.dropped += 1
        self._t[self._head] = s.t_s
        self._p[self._head] = s.power_w
        self._u[self._head] = s.util
        self._c[self._head] = s.temp_c
        self._head = (self._head + 1) % self.capacity
        self._count = min(self._count + 1, self.capacity)
        self.total += 1

    def extend(self, times_s, power_w, util=None, temp_c=None) -> int:
        """Bulk append: one wrap-aware slice copy (two when wrapping).

        Accounting matches ``append`` called per sample exactly: ``total``
        grows by the chunk length, and ``dropped`` counts every sample the
        write pushed out of the visible window — including the head of a
        chunk *larger than capacity*, whose samples are overwritten before
        any snapshot could see them.
        """
        t = np.asarray(times_s, dtype=float)
        n = int(t.size)
        p = np.asarray(power_w, dtype=float)
        u = (np.full(n, math.nan) if util is None
             else np.asarray(util, dtype=float))
        c = (np.full(n, math.nan) if temp_c is None
             else np.asarray(temp_c, dtype=float))
        if p.size != n or u.size != n or c.size != n:
            # a shorter array would raise an opaque broadcast error mid
            # copy; a scalar would broadcast *silently* — fail loud instead
            raise ValueError(
                f"chunk field lengths disagree: times={n} power={p.size} "
                f"util={u.size} temp={c.size}")
        if n == 0:
            return 0
        cap = self.capacity
        self.dropped += max(self._count + n - cap, 0)
        self.total += n
        head = self._head
        if n >= cap:
            # only the chunk's tail is ever visible; lay it out so the
            # oldest visible sample sits at the final head position
            final_head = (head + n) % cap
            for dst, src in ((self._t, t), (self._p, p),
                             (self._u, u), (self._c, c)):
                dst[final_head:] = src[n - cap:n - final_head]
                dst[:final_head] = src[n - final_head:]
            self._head = final_head
            self._count = cap
            return n
        first = min(n, cap - head)
        for dst, src in ((self._t, t), (self._p, p),
                         (self._u, u), (self._c, c)):
            dst[head:head + first] = src[:first]
            if first < n:
                dst[:n - first] = src[first:]
        self._head = (head + n) % cap
        self._count = min(self._count + n, cap)
        return n

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """(times, power) of the buffered window, oldest first (copies)."""
        idx = self._order()
        return self._t[idx].copy(), self._p[idx].copy()

    def latest(self) -> Optional[PowerSample]:
        if self._count == 0:
            return None
        i = (self._head - 1) % self.capacity
        return PowerSample(float(self._t[i]), float(self._p[i]),
                           float(self._u[i]), float(self._c[i]))

    def to_trace(self) -> SensorTrace:
        """The buffered window as a ``SensorTrace`` (for offline tooling)."""
        idx = self._order()
        return SensorTrace(self._t[idx].copy(), self._p[idx].copy(),
                           self._u[idx].copy(), self._c[idx].copy())

    def _order(self) -> np.ndarray:
        if self._count < self.capacity:
            return np.arange(self._count)
        return (np.arange(self.capacity) + self._head) % self.capacity

    def views(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(times, power, util, temp) oldest-first — zero-copy when possible.

        When the buffered window is laid out contiguously (the common case
        for a publisher that wrote exactly ``capacity`` samples, or fewer
        than one wrap), the returned arrays are direct views of the ring
        storage — this is what lets a shard worker consume a shared-memory
        ring without ever copying the trace.  A wrapped window falls back
        to the ordered copy.
        """
        if self._count == self.capacity and self._head == 0:
            return self._t, self._p, self._u, self._c
        if self._count < self.capacity and self._head == self._count:
            n = self._count
            return self._t[:n], self._p[:n], self._u[:n], self._c[:n]
        idx = self._order()
        return (self._t[idx], self._p[idx], self._u[idx], self._c[idx])


class SharedSampleRing(SampleRing):
    """A ``SampleRing`` whose storage lives in ``multiprocessing``
    shared memory — the zero-copy transport between a telemetry plane's
    publisher process and its shard workers.

    Layout: an int64 header ``[capacity, head, count, total, dropped]``
    followed by four float64 arrays (times, power, util, temp).  Header
    counters are ndarray views into the segment too, so publisher-side
    ``append``/``extend`` bookkeeping is visible to an attached consumer
    with no extra protocol.  The intended discipline is single-writer:
    the publisher fills the ring, then workers ``attach`` and read
    ``views()`` — which, for an unwrapped window, are direct views of the
    shared segment (no copy anywhere on the path).

    ``create`` owns the segment (``unlink`` releases it); ``attach`` maps
    an existing one by name and never unlinks.
    """

    _HEADER = 5 * 8          # five int64 header slots

    def __init__(self, capacity: int = 4096, *, _shm=None):
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        if _shm is None:
            from multiprocessing import shared_memory
            size = self._HEADER + 4 * 8 * int(capacity)
            _shm = shared_memory.SharedMemory(create=True, size=size)
            fresh = True
        else:
            fresh = False
        self.shm = _shm
        cap = int(capacity)
        self._hdr = np.ndarray((5,), dtype=np.int64, buffer=_shm.buf)
        off = self._HEADER
        arrays = []
        for _ in range(4):
            arrays.append(np.ndarray((cap,), dtype=np.float64,
                                     buffer=_shm.buf, offset=off))
            off += 8 * cap
        self._t, self._p, self._u, self._c = arrays
        self.capacity = cap
        if fresh:
            self._hdr[0] = cap
            self._hdr[1:] = 0
            self._t[:] = 0.0
            self._p[:] = 0.0
            self._u[:] = math.nan
            self._c[:] = math.nan

    # base-class code manipulates these as instance attributes; as data
    # descriptors they shadow that and route every access to the header
    @property
    def _head(self) -> int:
        return int(self._hdr[1])

    @_head.setter
    def _head(self, v: int) -> None:
        self._hdr[1] = v

    @property
    def _count(self) -> int:
        return int(self._hdr[2])

    @_count.setter
    def _count(self, v: int) -> None:
        self._hdr[2] = v

    @property
    def total(self) -> int:
        return int(self._hdr[3])

    @total.setter
    def total(self, v: int) -> None:
        self._hdr[3] = v

    @property
    def dropped(self) -> int:
        return int(self._hdr[4])

    @dropped.setter
    def dropped(self, v: int) -> None:
        self._hdr[4] = v

    @classmethod
    def create(cls, capacity: int = 4096) -> "SharedSampleRing":
        return cls(capacity)

    @classmethod
    def attach(cls, name: str) -> "SharedSampleRing":
        from multiprocessing import shared_memory
        # Python 3.10 registers this attach with the resource tracker too
        # (bpo-39959); spawned workers share the creator's tracker and the
        # cache is name-keyed, so the duplicate is harmless — the creator's
        # ``unlink`` clears the one entry.
        shm = shared_memory.SharedMemory(name=name)
        cap = int(np.ndarray((1,), dtype=np.int64, buffer=shm.buf)[0])
        return cls(cap, _shm=shm)

    @property
    def shm_name(self) -> str:
        return self.shm.name

    def close(self) -> None:
        """Drop this process's mapping (the segment itself survives)."""
        # ndarray views pin the buffer; release them before closing
        self._hdr = self._t = self._p = self._u = self._c = None
        self.shm.close()

    def unlink(self) -> None:
        """Release the segment system-wide (creator-side, after close)."""
        try:
            self.shm.unlink()
        except FileNotFoundError:
            pass


# ---------------------------------------------------------------------------
# Sources.
# ---------------------------------------------------------------------------
class TraceReplaySampler:
    """Streams a recorded ``SensorTrace`` — per sample, or as array chunks."""

    def __init__(self, trace: SensorTrace):
        self.trace = trace

    def __iter__(self) -> Iterator[PowerSample]:
        t, p, u, c = (self.trace.times_s, self.trace.power_w,
                      self.trace.util, self.trace.temp_c)
        for i in range(len(t)):
            yield PowerSample(float(t[i]), float(p[i]), float(u[i]),
                              float(c[i]))

    def chunks(self, n: int = DEFAULT_CHUNK) -> Iterator[SampleChunk]:
        """Zero-copy array slices of the trace, ``n`` samples at a time."""
        t, p, u, c = (self.trace.times_s, self.trace.power_w,
                      self.trace.util, self.trace.temp_c)
        for lo in range(0, len(t), n):
            yield t[lo:lo + n], p[lo:lo + n], u[lo:lo + n], c[lo:lo + n]


class FeedSampler:
    """Adapts a raw sample feed: an iterable of ``PowerSample``s /
    ``(t, p[, util[, temp]])`` tuples, or a zero-arg poll callable returning
    the same (``None`` ends the stream)."""

    def __init__(self, feed):
        self._feed = feed

    @staticmethod
    def _coerce(item) -> PowerSample:
        if isinstance(item, PowerSample):
            return item
        t, p, *rest = item
        u = rest[0] if len(rest) > 0 else math.nan
        c = rest[1] if len(rest) > 1 else math.nan
        return PowerSample(float(t), float(p), float(u), float(c))

    def __iter__(self) -> Iterator[PowerSample]:
        if callable(self._feed):
            while True:
                item = self._feed()
                if item is None:
                    return
                yield self._coerce(item)
        else:
            for item in self._feed:
                yield self._coerce(item)

    def chunks(self, n: int = DEFAULT_CHUNK) -> Iterator[SampleChunk]:
        """Batch the coerced feed into ndarray chunks of up to ``n``."""
        return _batch_samples(iter(self), n)


def _batch_samples(samples: Iterable[PowerSample],
                   n: int) -> Iterator[SampleChunk]:
    """Generic per-sample -> chunk adapter (the slow-source fallback)."""
    buf_t, buf_p, buf_u, buf_c = [], [], [], []
    for s in samples:
        buf_t.append(s.t_s)
        buf_p.append(s.power_w)
        buf_u.append(s.util)
        buf_c.append(s.temp_c)
        if len(buf_t) >= n:
            yield (np.asarray(buf_t), np.asarray(buf_p),
                   np.asarray(buf_u), np.asarray(buf_c))
            buf_t, buf_p, buf_u, buf_c = [], [], [], []
    if buf_t:
        yield (np.asarray(buf_t), np.asarray(buf_p),
               np.asarray(buf_u), np.asarray(buf_c))


def iter_chunks(sampler, n: int = DEFAULT_CHUNK) -> Iterator[SampleChunk]:
    """Chunk view of *any* sampler.

    Chunk-native samplers (anything with ``chunks(n)``) serve array slices
    directly; per-sample iterables are batched through the fallback adapter,
    so the downstream pipeline is always array-at-a-time.
    """
    chunks = getattr(sampler, "chunks", None)
    if chunks is not None:
        return chunks(n)
    return _batch_samples(iter(sampler), n)


class DeviceSampler:
    """Background-monitor view of a ``SimDevice`` execution.

    ``run`` executes the program and returns ``(record, sampler)`` where the
    sampler replays the run's telemetry in sensor order — the streaming
    pipeline consumes it exactly as it would a live NVML poll loop.
    """

    def __init__(self, device: SimDevice):
        self.device = device

    def run(self, program: Program) -> Tuple[RunRecord, TraceReplaySampler]:
        rec = self.device.run(program)
        return rec, TraceReplaySampler(rec.trace)

    def idle(self, duration_s: float = 30.0) -> TraceReplaySampler:
        return TraceReplaySampler(self.device.idle(duration_s))
