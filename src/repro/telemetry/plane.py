"""The sharded telemetry plane: N shards, one exactly-tiling snapshot.

``TelemetryPlane`` is a drop-in ``TelemetryService`` — same ``register`` /
``poll_all`` / ``finish_all`` / ``snapshot`` surface, so billing panes,
governors, the serving scheduler and the fleet monitor ride it unchanged —
that partitions registered sessions across ``Shard``s and merges their
``ShardSummary``s back into one snapshot.  The merge is exact: every float
is either per-session (one shard computed it) or re-summed in the canonical
sorted-key order shared with the single-process service, so the plane's
snapshot is bitwise-identical to an unsharded service over the same
sessions, for any shard count and any partition.

Three runners cover the deployment spectrum with one drain code path
(``Shard.poll`` — the same rotating round-robin the service uses):

* ``"serial"`` — shards drain in-line, one after another.  The reference.
* ``"thread"`` (default) — one pool thread per shard.  Sessions on
  different shards interleave in time, exactly like production; totals are
  unchanged because each session's pipeline is touched by only its shard.
* ``"process"`` — spawned workers drain shards over shared-memory rings
  (``telemetry.shard``): the parent launches device runs and publishes
  traces into ``SharedSampleRing``s, workers rebuild the sessions
  (``StreamSession.attached``) and ship results back for
  ``adopt_remote``.  Workers never import jax.

Elastic membership: ``detach_shard`` retires a shard — its unfinished
sessions are rehomed to the survivors, its finished history is frozen as a
``ShardSummary`` that keeps merging into every later snapshot, so a shard
loss never loses a joule (``train.elastic.fold_shard_loss`` wraps this for
the checkpoint-restart path).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

from repro.telemetry.service import StreamSession, TelemetryService
from repro.telemetry.shard import Shard, ShardSummary, export_session

RUNNERS = ("serial", "thread", "process")


class TelemetryPlane(TelemetryService):
    """A ``TelemetryService`` partitioned into mergeable shards."""

    def __init__(self, n_shards: int = 2, *, runner: str = "thread"):
        super().__init__()
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        if runner not in RUNNERS:
            raise ValueError(f"unknown runner {runner!r} (one of {RUNNERS})")
        self.runner = runner
        self.shards: List[Shard] = [Shard(i) for i in range(n_shards)]
        self._retired: List[ShardSummary] = []
        self._assignment: Dict[str, Shard] = {}
        self._delegated = False        # process runner already dispatched
        self._pool = None

    # -- membership ----------------------------------------------------------
    def register(self, session: StreamSession, key: Optional[str] = None,
                 *, shard: Optional[int] = None) -> StreamSession:
        """Register a session and place it on a shard.

        Default placement is least-loaded (ties to the lowest shard id) —
        deterministic round-robin for a stream of registrations, so the
        same registration order always yields the same partition.
        ``shard=`` pins the session explicitly.
        """
        session = super().register(session, key)
        key = next(k for k, s in self._sessions.items() if s is session)
        if shard is None:
            target = min(self.shards, key=lambda sh: (len(sh), sh.id))
        else:
            target = self.shard(shard)
        target.add(key, session)
        self._assignment[key] = target
        return session

    def shard(self, shard_id: int) -> Shard:
        for sh in self.shards:
            if sh.id == shard_id:
                return sh
        raise KeyError(f"no shard {shard_id} "
                       f"(have {[s.id for s in self.shards]})")

    # -- drains --------------------------------------------------------------
    def poll_all(self, max_chunks: int = 1) -> int:
        """One drain pass over every shard (plane-wide ``poll_all``)."""
        if self.runner == "process":
            return self._drain_remote()
        active = [sh for sh in self.shards if sh.active()]
        if not active:
            return 0
        if self.runner == "thread" and len(active) > 1:
            pool = self._thread_pool()
            return sum(pool.map(lambda sh: sh.poll(max_chunks), active))
        return sum(sh.poll(max_chunks) for sh in active)

    def finish_all(self) -> Dict[str, object]:
        """Drain every shard to completion; key -> summary."""
        if self.runner == "process":
            self._drain_remote()
        else:
            while self.poll_all(max_chunks=64):
                pass
        return {k: s.summary for k, s in self._sessions.items()
                if s.summary is not None}

    def _thread_pool(self):
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=len(self.shards),
                thread_name_prefix="telemetry-shard")
        return self._pool

    def _drain_remote(self) -> int:
        """Dispatch every shard's pending sessions to spawned workers.

        Sessions that were already started in this process (their pipeline
        state lives here) drain locally; unstarted ones are exported —
        the parent runs the device half, publishes the trace into a
        shared ring, and the worker runs the ingest half.  One shot per
        plane: the process runner is a batch drain, not an incremental
        poll.
        """
        import multiprocessing as mp

        total = 0
        if self._delegated:
            for sh in self.shards:
                total += sh.drain()
            return total
        self._delegated = True
        from repro.core import isa
        ctx = mp.get_context("spawn")
        class_names = isa.CLASS_INDEX.names()
        # Launch device runs in *registration* order, not shard order: a
        # shared device's sensor-noise stream is consumed run by run, so
        # the trace each session gets must not depend on how sessions were
        # grouped into shards — this is part of the partition-invariance
        # guarantee (the unsharded reference starts sessions in the same
        # registration order).
        per_shard: Dict[int, list] = {}
        jobs = []
        try:
            for key, s in self._sessions.items():
                if s.summary is not None or s.started or not s._steps:
                    continue       # finished/armed-here/idle: stays local
                sh = self._assignment.get(key)
                if sh is None:
                    continue
                spec, ring = export_session(key, s)
                per_shard.setdefault(sh.id, []).append((spec, ring, s))
            for sh in self.shards:
                exported = per_shard.get(sh.id, [])
                if not exported:
                    continue
                specs = [spec for spec, _, _ in exported]
                rings = [ring for _, ring, _ in exported]
                tables = {}
                for spec, _, s in exported:
                    tables.setdefault(spec["table_ref"],
                                      s.predictor.table.to_dict())
                parent_conn, child_conn = ctx.Pipe(duplex=False)
                proc = ctx.Process(
                    target=_worker_main,
                    args=(sh.id, class_names, tables, specs, child_conn),
                    daemon=True)
                proc.start()
                child_conn.close()
                jobs.append((sh, specs, rings, parent_conn, proc))
            for sh, specs, rings, conn, proc in jobs:
                if not conn.poll(300.0):
                    proc.terminate()
                    raise RuntimeError(
                        f"telemetry shard {sh.id} worker timed out")
                reply = conn.recv()       # before join: avoid pipe deadlock
                proc.join()
                if not reply["ok"]:
                    raise RuntimeError(
                        f"telemetry shard {sh.id} worker failed:\n"
                        f"{reply['error']}")
                for spec in specs:
                    result = reply["results"][spec["key"]]
                    sh.sessions[spec["key"]].adopt_remote(result)
                    total += int(result["samples_drained"])
        finally:
            for _, _, _, conn, _ in jobs:
                conn.close()
            for exported in per_shard.values():
                for _, ring, _ in exported:
                    ring.close()
                    ring.unlink()
        # anything armed in this process (serve-style inline sessions)
        # still drains here
        for sh in self.shards:
            total += sh.drain()
        return total

    # -- snapshots ------------------------------------------------------------
    def shard_summaries(self) -> List[ShardSummary]:
        """Live summaries of every populated shard, plus retired ones."""
        live = [sh.summarize() for sh in self.shards if len(sh)]
        return live + list(self._retired)

    def merged(self) -> ShardSummary:
        return functools.reduce(ShardSummary.merge, self.shard_summaries(),
                                ShardSummary())

    def snapshot(self) -> dict:
        """Merge-based snapshot: bitwise the unsharded service's."""
        out = self.merged().snapshot()
        if self._billing:
            out["billing"] = {k: fn() for k, fn in self._billing.items()}
        if self._governors:
            out["governors"] = {k: g.snapshot()
                                for k, g in self._governors.items()}
        return out

    # -- elastic membership ---------------------------------------------------
    def detach_shard(self, shard_id: int, *,
                     rehome: bool = True) -> ShardSummary:
        """Retire a shard with exact accounting.

        The departing shard's finished sessions freeze into a
        ``ShardSummary`` that every later ``snapshot()`` still merges —
        their joules stay on the books forever.  Unfinished sessions are
        rehomed to the least-loaded survivors (``rehome=False`` drops
        them *from the plane's live set* but they remain registered, so a
        caller can still finish them by hand).  Returns the frozen
        summary.
        """
        shard = self.shard(shard_id)
        survivors = [sh for sh in self.shards if sh.id != shard_id]
        if not survivors:
            raise ValueError("cannot detach the last shard")
        moved = {k: s for k, s in shard.sessions.items()
                 if s.summary is None}
        for k in moved:
            del shard.sessions[k]
        final = shard.summarize()          # finished history only — frozen
        if len(shard):
            self._retired.append(final)
        if rehome:
            for k in sorted(moved):
                target = min(survivors, key=lambda sh: (len(sh), sh.id))
                target.add(k, moved[k])
                self._assignment[k] = target
        else:
            for k in moved:
                self._assignment.pop(k, None)
        self.shards = survivors
        return final


def _worker_main(shard_id, class_names, tables, specs, conn):
    """Top-level spawn target (bound methods don't pickle across spawn)."""
    from repro.telemetry.shard import run_shard_worker
    run_shard_worker(shard_id, class_names, tables, specs, conn)
