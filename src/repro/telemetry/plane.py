"""The sharded telemetry plane: N shards, one exactly-tiling snapshot.

``TelemetryPlane`` is a drop-in ``TelemetryService`` — same ``register`` /
``poll_all`` / ``finish_all`` / ``snapshot`` surface, so billing panes,
governors, the serving scheduler and the fleet monitor ride it unchanged —
that partitions registered sessions across ``Shard``s and merges their
``ShardSummary``s back into one snapshot.  The merge is exact: every float
is either per-session (one shard computed it) or re-summed in the canonical
sorted-key order shared with the single-process service, so the plane's
snapshot is bitwise-identical to an unsharded service over the same
sessions, for any shard count and any partition.

Three runners cover the deployment spectrum with one drain code path
(``Shard.poll`` — the same rotating round-robin the service uses):

* ``"serial"`` — shards drain in-line, one after another.  The reference.
* ``"thread"`` (default) — one pool thread per shard.  Sessions on
  different shards interleave in time, exactly like production; totals are
  unchanged because each session's pipeline is touched by only its shard.
* ``"process"`` — spawned workers drain shards over shared-memory rings
  (``telemetry.shard``): the parent launches device runs and publishes
  traces into ``SharedSampleRing``s, workers rebuild the sessions
  (``StreamSession.attached``) and ship results back for
  ``adopt_remote``.  Workers never import jax.

Elastic membership: ``detach_shard`` retires a shard — its unfinished
sessions are rehomed to the survivors, its finished history is frozen as a
``ShardSummary`` that keeps merging into every later snapshot, so a shard
loss never loses a joule (``train.elastic.fold_shard_loss`` wraps this for
the checkpoint-restart path).

The process runner is supervised: every worker heartbeats before doing
work, and the parent enforces a heartbeat timeout (hung worker), a result
timeout (stuck drain) and pipe EOF (crashed worker).  A failed attempt is
restarted with exponential backoff up to ``SupervisorConfig.max_restarts``
times — safe because workers only read the shared rings and the drain is
deterministic, so a relaunch reproduces the lost attempt and results are
adopted exactly once.  A shard whose every attempt fails is drained
in-parent from the published rings and then folded out of the live plane
via the ``detach_shard``/``fold_shard_loss`` path, so even a permanently
failing worker never loses a joule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import numpy as np

from repro.telemetry.faults import ChaosPlan
from repro.telemetry.service import StreamSession, TelemetryService
from repro.telemetry.shard import Shard, ShardSummary, export_session

RUNNERS = ("serial", "thread", "process")


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Shard-worker supervision knobs (process runner)."""

    heartbeat_timeout_s: float = 30.0   # worker must heartbeat this fast
    result_timeout_s: float = 300.0     # ... and deliver results this fast
    max_restarts: int = 2               # relaunches per shard before fold
    backoff_s: float = 0.25             # base restart delay (doubles)


class TelemetryPlane(TelemetryService):
    """A ``TelemetryService`` partitioned into mergeable shards."""

    def __init__(self, n_shards: int = 2, *, runner: str = "thread",
                 chaos: Optional[ChaosPlan] = None,
                 supervisor: Optional[SupervisorConfig] = None):
        super().__init__()
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        if runner not in RUNNERS:
            raise ValueError(f"unknown runner {runner!r} (one of {RUNNERS})")
        self.runner = runner
        # shard-level chaos (worker crash/hang injection); stream-level
        # faults ride each session's own plan
        self.chaos = chaos
        self.supervisor = supervisor or SupervisorConfig()
        self.restarts = 0                       # worker relaunches, total
        self.shards: List[Shard] = [Shard(i) for i in range(n_shards)]
        self._retired: List[ShardSummary] = []
        self._assignment: Dict[str, Shard] = {}
        self._supervisor_events: List[dict] = []
        self._folded: List[int] = []            # shards folded after failure
        self._delegated = False        # process runner already dispatched
        self._pool = None

    # -- membership ----------------------------------------------------------
    def register(self, session: StreamSession, key: Optional[str] = None,
                 *, shard: Optional[int] = None) -> StreamSession:
        """Register a session and place it on a shard.

        Default placement is least-loaded (ties to the lowest shard id) —
        deterministic round-robin for a stream of registrations, so the
        same registration order always yields the same partition.
        ``shard=`` pins the session explicitly.
        """
        session = super().register(session, key)
        key = next(k for k, s in self._sessions.items() if s is session)
        if shard is None:
            target = min(self.shards, key=lambda sh: (len(sh), sh.id))
        else:
            target = self.shard(shard)
        target.add(key, session)
        self._assignment[key] = target
        return session

    def shard(self, shard_id: int) -> Shard:
        for sh in self.shards:
            if sh.id == shard_id:
                return sh
        raise KeyError(f"no shard {shard_id} "
                       f"(have {[s.id for s in self.shards]})")

    # -- drains --------------------------------------------------------------
    def poll_all(self, max_chunks: int = 1) -> int:
        """One drain pass over every shard (plane-wide ``poll_all``)."""
        if self.runner == "process":
            return self._drain_remote()
        active = [sh for sh in self.shards if sh.active()]
        if not active:
            return 0
        if self.runner == "thread" and len(active) > 1:
            pool = self._thread_pool()
            return sum(pool.map(lambda sh: sh.poll(max_chunks), active))
        return sum(sh.poll(max_chunks) for sh in active)

    def finish_all(self) -> Dict[str, object]:
        """Drain every shard to completion; key -> summary."""
        if self.runner == "process":
            self._drain_remote()
        else:
            while self.poll_all(max_chunks=64):
                pass
        return {k: s.summary for k, s in self._sessions.items()
                if s.summary is not None}

    def _thread_pool(self):
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=len(self.shards),
                thread_name_prefix="telemetry-shard")
        return self._pool

    def _drain_remote(self) -> int:
        """Dispatch every shard's pending sessions to supervised workers.

        Sessions that were already started in this process (their pipeline
        state lives here) drain locally; unstarted ones are exported —
        the parent runs the device half, publishes the trace into a
        shared ring, and the worker runs the ingest half.  One shot per
        plane: the process runner is a batch drain, not an incremental
        poll.

        Each worker is supervised (heartbeat, timeouts, pipe EOF); failed
        attempts restart with backoff, and a shard whose every attempt
        fails falls back to an in-parent drain from the published rings,
        then folds out of the live plane — see the module docstring.
        """
        import multiprocessing as mp

        total = 0
        if self._delegated:
            for sh in self.shards:
                total += sh.drain()
            return total
        self._delegated = True
        from repro.core import isa
        ctx = mp.get_context("spawn")
        class_names = isa.CLASS_INDEX.names()
        # Launch device runs in *registration* order, not shard order: a
        # shared device's sensor-noise stream is consumed run by run, so
        # the trace each session gets must not depend on how sessions were
        # grouped into shards — this is part of the partition-invariance
        # guarantee (the unsharded reference starts sessions in the same
        # registration order).
        per_shard: Dict[int, list] = {}
        jobs = []
        failed = []
        try:
            for key, s in self._sessions.items():
                if s.summary is not None or s.started or not s._steps:
                    continue       # finished/armed-here/idle: stays local
                sh = self._assignment.get(key)
                if sh is None:
                    continue
                spec, ring = export_session(key, s)
                per_shard.setdefault(sh.id, []).append((spec, ring, s))
            for sh in self.shards:
                exported = per_shard.get(sh.id, [])
                if not exported:
                    continue
                specs = [spec for spec, _, _ in exported]
                rings = [ring for _, ring, _ in exported]
                tables = {}
                for spec, _, s in exported:
                    tables.setdefault(spec["table_ref"],
                                      s.predictor.table.to_dict())
                proc, conn = self._launch_worker(ctx, class_names, sh.id,
                                                 tables, specs, attempt=0)
                jobs.append([sh, specs, rings, tables, conn, proc])
            for job in jobs:
                sh, specs, rings, tables = job[0], job[1], job[2], job[3]
                reply = self._supervise(ctx, class_names, sh, tables,
                                        specs, job)
                if reply is None:
                    # every attempt failed: rebuild the ingest half here,
                    # from the rings the parent already published — the
                    # worker never delivered, so nothing was adopted and
                    # this local drain is the exactly-once accounting
                    total += self._fallback_local(sh, specs, rings)
                    failed.append(sh)
                    continue
                for spec in specs:
                    result = reply["results"][spec["key"]]
                    sh.sessions[spec["key"]].adopt_remote(result)
                    total += int(result["samples_drained"])
        finally:
            for job in jobs:
                try:
                    job[4].close()
                except Exception:
                    pass
            for exported in per_shard.values():
                for _, ring, _ in exported:
                    ring.close()
                    ring.unlink()
        # anything armed in this process (serve-style inline sessions)
        # still drains here
        for sh in self.shards:
            total += sh.drain()
        # fold permanently-failed shards out of the live plane (their
        # now-finished history freezes into a retired summary that every
        # later snapshot still merges — exact accounting survives)
        for sh in failed:
            if any(x.id != sh.id for x in self.shards):
                from repro.train.elastic import fold_shard_loss
                fold_shard_loss(self, sh.id)
                self._folded.append(sh.id)
        return total

    # -- worker supervision ---------------------------------------------------
    def _sabotage(self, shard_id: int, attempt: int):
        """Chaos hook: should this launch attempt be sabotaged, and how?"""
        plan = self.chaos
        if plan is None or attempt >= max(plan.crash_attempts, 0):
            return None, 0.0
        if shard_id in plan.hang_shards:
            return "hang", plan.hang_s
        if shard_id in plan.crash_shards:
            return "crash", 0.0
        return None, 0.0

    def _launch_worker(self, ctx, class_names, shard_id, tables, specs,
                       attempt: int):
        sabotage, hang_s = self._sabotage(shard_id, attempt)
        parent_conn, child_conn = ctx.Pipe(duplex=False)
        proc = ctx.Process(
            target=_worker_main,
            args=(shard_id, class_names, tables, specs, child_conn,
                  sabotage, hang_s),
            daemon=True)
        proc.start()
        child_conn.close()
        return proc, parent_conn

    @staticmethod
    def _await_worker(conn, sup: SupervisorConfig):
        """Wait for heartbeat then results; (reply, None) or (None, cause)."""
        try:
            if not conn.poll(sup.heartbeat_timeout_s):
                return None, "heartbeat-timeout"
            msg = conn.recv()
            if msg.get("hb"):
                if not conn.poll(sup.result_timeout_s):
                    return None, "result-timeout"
                reply = conn.recv()
            else:
                reply = msg            # worker skipped the heartbeat
        except EOFError:
            return None, "crashed"
        if reply.get("ok"):
            return reply, None
        return None, "worker-error: " + str(reply.get("error", ""))[:500]

    def _supervise(self, ctx, class_names, sh, tables, specs, job):
        """Await one shard's worker, restarting failed attempts.

        Returns the successful reply, or ``None`` once
        ``SupervisorConfig.max_restarts`` relaunches have also failed.
        ``job[4]``/``job[5]`` track the live conn/proc so cleanup in the
        caller always sees the current attempt.
        """
        import time

        sup = self.supervisor
        attempt = 0
        while True:
            conn, proc = job[4], job[5]
            reply, cause = self._await_worker(conn, sup)
            if reply is not None:
                proc.join()
                return reply
            # tear down the failed attempt
            try:
                proc.terminate()
                proc.join()
            except Exception:
                pass
            try:
                conn.close()
            except Exception:
                pass
            attempt += 1
            self._supervisor_events.append(
                {"shard": sh.id, "attempt": attempt, "cause": cause})
            if attempt > sup.max_restarts:
                return None
            self.restarts += 1
            time.sleep(sup.backoff_s * (2 ** (attempt - 1)))
            proc, conn = self._launch_worker(ctx, class_names, sh.id,
                                             tables, specs, attempt)
            job[4], job[5] = conn, proc

    def _fallback_local(self, sh, specs, rings) -> int:
        """Permanent worker failure: drain the shard in-parent.

        The device half already ran (the traces sit in the published
        rings); only the ingest half is rebuilt, around a private copy of
        each trace.  Chaos plans still apply — ``_arm`` wraps the replay
        sampler — so the fallback reproduces exactly what the worker
        would have computed.
        """
        from repro.hw.device import SensorTrace
        from repro.telemetry.sampler import TraceReplaySampler

        for spec, ring in zip(specs, rings):
            s = sh.sessions[spec["key"]]
            if s.summary is not None or s.started:
                continue
            trace = SensorTrace(*[np.array(v) for v in ring.views()])
            s._arm(s.record, spec["markers"], TraceReplaySampler(trace))
        return sh.drain()

    # -- snapshots ------------------------------------------------------------
    def shard_summaries(self) -> List[ShardSummary]:
        """Live summaries of every populated shard, plus retired ones."""
        live = [sh.summarize() for sh in self.shards if len(sh)]
        return live + list(self._retired)

    def merged(self) -> ShardSummary:
        return functools.reduce(ShardSummary.merge, self.shard_summaries(),
                                ShardSummary())

    def snapshot(self) -> dict:
        """Merge-based snapshot: bitwise the unsharded service's."""
        out = self.merged().snapshot()
        if self._billing:
            out["billing"] = {k: fn() for k, fn in self._billing.items()}
        if self._governors:
            out["governors"] = {k: g.snapshot()
                                for k, g in self._governors.items()}
        if self.restarts or self._folded:
            # only when the supervisor actually intervened — clean runs
            # stay bitwise-identical to the unsharded service snapshot
            out["supervisor"] = {
                "restarts": self.restarts,
                "folded_shards": list(self._folded),
                "events": list(self._supervisor_events),
            }
        return out

    # -- elastic membership ---------------------------------------------------
    def detach_shard(self, shard_id: int, *,
                     rehome: bool = True) -> ShardSummary:
        """Retire a shard with exact accounting.

        The departing shard's finished sessions freeze into a
        ``ShardSummary`` that every later ``snapshot()`` still merges —
        their joules stay on the books forever.  Unfinished sessions are
        rehomed to the least-loaded survivors (``rehome=False`` drops
        them *from the plane's live set* but they remain registered, so a
        caller can still finish them by hand).  Returns the frozen
        summary.
        """
        shard = self.shard(shard_id)
        survivors = [sh for sh in self.shards if sh.id != shard_id]
        if not survivors:
            raise ValueError("cannot detach the last shard")
        moved = {k: s for k, s in shard.sessions.items()
                 if s.summary is None}
        for k in moved:
            del shard.sessions[k]
        final = shard.summarize()          # finished history only — frozen
        if len(shard):
            self._retired.append(final)
        if rehome:
            for k in sorted(moved):
                target = min(survivors, key=lambda sh: (len(sh), sh.id))
                target.add(k, moved[k])
                self._assignment[k] = target
        else:
            for k in moved:
                self._assignment.pop(k, None)
        self.shards = survivors
        return final


def _worker_main(shard_id, class_names, tables, specs, conn,
                 sabotage=None, hang_s=0.0):
    """Top-level spawn target (bound methods don't pickle across spawn)."""
    from repro.telemetry.shard import run_shard_worker
    run_shard_worker(shard_id, class_names, tables, specs, conn,
                     sabotage=sabotage, hang_s=hang_s)
