"""Telemetry shards: mergeable per-worker summaries + the worker runtime.

One monitor process tops out around ~100k devices at 10 Hz (PR 5's chunked
path); scaling past that is partitioning, not micro-optimization.  A
``Shard`` owns a subset of a fleet's ``StreamSession``s and drains them with
exactly the round-robin loop ``TelemetryService.poll_all`` uses; its
``ShardSummary`` is the CRDT-style exportable view — per-session snapshot
dicts, window tilings, drift-detector state, drain accounting — whose
``merge`` is associative and commutative over disjoint shards.  Because
every float in a merged snapshot is either a per-session value (computed by
exactly one shard) or a fleet roll-up re-summed in the canonical sorted-key
order (``service.fleet_block``), *any* partition of the same sessions into
shards reproduces the single-process ``TelemetryService.snapshot()``
bitwise.

The bottom half of this module is the worker runtime for the process
runner: the parent launches the device run, publishes the trace through a
``SharedSampleRing`` (zero-copy shared memory), and ships a spec — markers,
step grid, op counts, table payload, detector state.  A spawned worker
rebuilds each session with ``StreamSession.attached``, drains its shard,
and returns per-session results the parent folds back in with
``StreamSession.adopt_remote``.  Workers never import jax: everything on
this import path goes through the numpy-only accumulation core.

Bitwise scope note: sessions that *share* one table across different shards
with live drift repair are order-dependent by construction (a repair in one
shard would have re-priced the other's later windows).  The plane keeps
repair exact by replaying each worker's recalibration ratios onto the
parent table; the partition-invariance guarantee is stated for sessions
that do not couple through mid-run repair (``recalibrate=None`` or
per-session tables), which is also the deployment shape — a fleet shard
watches distinct devices.
"""
from __future__ import annotations

import dataclasses
import traceback
from typing import Dict, List, Optional, Tuple

from repro.core import isa
from repro.core.counting import OpCounts
from repro.core.predict import TablePredictor
from repro.core.table import EnergyTable
from repro.hw.device import SensorTrace
from repro.telemetry.align import window_tiling
from repro.telemetry.attrib import DriftDetector
from repro.telemetry.sampler import SharedSampleRing
from repro.telemetry.service import StreamSession, fleet_block

#: OpCounts aggregate attributes shipped by name (the unit vector travels
#: as a name->value dict so the worker's vector layout can differ safely).
_COUNT_AGGS = ("naive_bytes", "boundary_read_bytes", "boundary_write_bytes",
               "fused_bytes", "flops", "exec_count", "dispatch_count",
               "max_buffer_bytes", "mxu_macs_total", "mxu_macs_aligned")


def _counts_payload(counts: OpCounts) -> dict:
    """Name-keyed transport form of an ``OpCounts``.

    Unit values re-enter through ``OpCounts.add`` on the far side — adding
    each float once into a zero slot is exact (``0.0 + x == x``), so the
    rebuilt vector matches the original bit-for-bit regardless of either
    process's interning history.
    """
    vec = counts._vec
    names = isa.CLASS_INDEX.names(vec.size)
    units = {names[i]: float(vec[i]) for i in range(vec.size) if vec[i]}
    return {"units": units,
            "aggregates": {a: getattr(counts, a) for a in _COUNT_AGGS}}


def _counts_restore(payload: dict) -> OpCounts:
    counts = OpCounts()
    for name, v in payload["units"].items():
        counts.add(name, v)
    for a, v in payload["aggregates"].items():
        setattr(counts, a, v)
    return counts


@dataclasses.dataclass
class ShardSummary:
    """One shard's exportable state; ``merge`` composes disjoint shards.

    Every field is a dict keyed by session key (or a sorted tuple of shard
    ids), so ``merge`` is a disjoint union per field — associative and
    commutative.  The only cross-session floats, the fleet roll-up, are
    *recomputed* from the merged per-session dicts in sorted-key order
    (``fleet_block``), never carried as pre-summed totals; that is what
    makes the merged snapshot independent of how sessions were grouped.
    """

    shard_ids: Tuple[int, ...] = ()
    sessions: Dict[str, dict] = dataclasses.field(default_factory=dict)
    anomalies: Dict[str, int] = dataclasses.field(default_factory=dict)
    tilings: Dict[str, dict] = dataclasses.field(default_factory=dict)
    drift: Dict[str, dict] = dataclasses.field(default_factory=dict)
    samples_drained: Dict[str, int] = dataclasses.field(default_factory=dict)
    chunks_drained: Dict[str, int] = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, shard_id: int,
           sessions: Dict[str, StreamSession]) -> "ShardSummary":
        out = cls(shard_ids=(int(shard_id),))
        for key in sorted(sessions):
            s = sessions[key]
            out.sessions[key] = s.snapshot()
            out.anomalies[key] = (len(s.monitor.anomalies)
                                  if s.monitor is not None else 0)
            out.tilings[key] = window_tiling(s.windows)
            out.drift[key] = s.attributor.detector.state_dict()
            out.samples_drained[key] = s.samples_drained
            out.chunks_drained[key] = s.chunks_drained
        return out

    def merge(self, other: "ShardSummary") -> "ShardSummary":
        """Disjoint union of two shard summaries.

        A session key present in both operands with *identical* state is
        tolerated (merging a summary with itself is idempotent — the CRDT
        posture); conflicting duplicates raise, because two shards claiming
        different views of one session means the partition was wrong.
        """
        merged = ShardSummary(
            shard_ids=tuple(sorted(set(self.shard_ids)
                                   | set(other.shard_ids))))
        for field in ("sessions", "anomalies", "tilings", "drift",
                      "samples_drained", "chunks_drained"):
            a, b = getattr(self, field), getattr(other, field)
            out = dict(a)
            for k, v in b.items():
                if k in out and out[k] != v:
                    raise ValueError(
                        f"conflicting duplicate session {k!r} in "
                        f"ShardSummary.merge ({field})")
                out[k] = v
            setattr(merged, field, out)
        return merged

    def fleet(self) -> dict:
        keys = sorted(self.anomalies)
        return fleet_block(self.sessions,
                           sum(self.anomalies[k] for k in keys))

    def snapshot(self) -> dict:
        """The ``TelemetryService.snapshot()``-shaped view of this summary."""
        return {"sessions": dict(self.sessions), "fleet": self.fleet()}


class Shard:
    """One worker's slice of the fleet: sessions + the drain loop.

    The poll loop is the same rotating round-robin as
    ``TelemetryService.poll_all`` — a shard *is* a miniature service —
    so the thread/serial/process runners all execute identical code over
    their partitions.
    """

    def __init__(self, shard_id: int):
        self.id = int(shard_id)
        self.sessions: Dict[str, StreamSession] = {}
        self._cursor = 0

    def add(self, key: str, session: StreamSession) -> None:
        if key in self.sessions:
            raise KeyError(f"session {key!r} already on shard {self.id}")
        self.sessions[key] = session

    def __len__(self) -> int:
        return len(self.sessions)

    def active(self) -> List[str]:
        """Keys with started, unfinished sessions (drainable now)."""
        return [k for k, s in self.sessions.items()
                if s.summary is None and s.started]

    def poll(self, max_chunks: int = 1) -> int:
        keys = self.active()
        if not keys:
            return 0
        start = self._cursor % len(keys)
        self._cursor += 1
        total = 0
        for k in keys[start:] + keys[:start]:
            total += self.sessions[k].poll(max_chunks)
        return total

    def drain(self, max_chunks: int = 64) -> int:
        """Poll until every started session on this shard is finished."""
        total = 0
        while True:
            got = self.poll(max_chunks)
            if not got:
                return total
            total += got

    def summarize(self) -> ShardSummary:
        return ShardSummary.of(self.id, self.sessions)


# ---------------------------------------------------------------------------
# Process-runner transport: parent-side export, worker-side rebuild.
# ---------------------------------------------------------------------------
def export_session(key: str, session: StreamSession):
    """Launch a session's device run and package it for a shard worker.

    Returns ``(spec, ring)``: the spec is a picklable description of the
    ingest half (markers, step grid, counts, detector state, table
    reference) and the ring is a ``SharedSampleRing`` holding the full
    trace — sized exactly, so the worker's ``views()`` are zero-copy
    reads of the shared segment.  The caller owns the ring's lifetime
    (close + unlink after the worker reports back).
    """
    if session.monitor is not None:
        raise ValueError(
            f"session {key!r} has a fleet monitor attached; anomaly "
            "callbacks cannot cross the process boundary — keep it on a "
            "thread/serial shard")
    if callable(session.attributor.recalibrate):
        raise ValueError(
            f"session {key!r} uses a callable recalibrate strategy; only "
            "None/'rescale' ship to shard workers")
    rec, _sampler = session._launch()
    trace = rec.trace
    n = int(len(trace.times_s))
    ring = SharedSampleRing(max(n, 2))
    ring.extend(trace.times_s, trace.power_w, trace.util, trace.temp_c)
    device = session.device
    spec = {
        "key": key,
        "name": session.name,
        "device_name": device.name,
        "device_point": getattr(device, "operating_point", None),
        "session_point": session.operating_point,
        "shm_name": ring.shm_name,
        "markers": session._markers(rec, session._n),
        "steps": list(session._steps),
        "n": session._n,
        "group": session._group,
        "record": dataclasses.replace(rec, trace=None),
        "counts": _counts_payload(session.counts),
        "chunk_size": session.chunk_size,
        "ring_capacity": session.ring.capacity,
        "recalibrate": session.attributor.recalibrate,
        "detector": session.attributor.detector.state_dict(),
        "table_ref": id(session.predictor.table),
        # chaos plan + gap threshold travel with the spec: the worker
        # injects the same deterministic faults the parent would have,
        # so in-process and sharded runs see identical faulted streams
        "chaos": session.chaos,
        "gap_threshold_s": session._gap_threshold_s,
    }
    return spec, ring


def drain_shard_in_process(shard_id: int, class_names: List[str],
                           tables: Dict[int, dict],
                           specs: List[dict]) -> Dict[str, dict]:
    """Rebuild a shard from specs, drain it, return per-session results.

    Runs inside the spawned worker (also callable inline, which is how
    tests exercise the exact worker code path without a fork).  Bitwise
    discipline: the parent's ``CLASS_INDEX`` interning order is replayed
    *first*, so every rebuilt vector — counts, class-energy splits,
    bucket codes — has the layout the parent's arithmetic used; tables
    are rebuilt once per ``table_ref`` so sessions that shared a table in
    the parent share its rebuilt copy here (drift repair coupling inside
    the shard is preserved).
    """
    for name in class_names:
        isa.CLASS_INDEX.intern(name)
    predictors: Dict[int, TablePredictor] = {}
    for ref, payload in tables.items():
        payload = dict(payload)
        payload.pop("schema", None)     # to_dict stamps it; from_dict checks
        pred = TablePredictor(EnergyTable.from_dict(payload))
        pred.warm()
        predictors[ref] = pred
    shard = Shard(shard_id)
    rings: List[SharedSampleRing] = []
    sessions: Dict[str, StreamSession] = {}
    try:
        for spec in specs:
            ring = SharedSampleRing.attach(spec["shm_name"])
            rings.append(ring)
            trace = SensorTrace(*ring.views())
            detector = DriftDetector().load_state(spec["detector"])
            session = StreamSession.attached(
                predictors[spec["table_ref"]],
                _counts_restore(spec["counts"]),
                name=spec["name"], trace=trace, markers=spec["markers"],
                record=spec["record"], steps=spec["steps"], n_steps=spec["n"],
                group=spec["group"], device_name=spec["device_name"],
                device_point=spec["device_point"],
                operating_point=spec["session_point"],
                ring_capacity=spec["ring_capacity"],
                recalibrate=spec["recalibrate"], detector=detector,
                chunk_size=spec["chunk_size"],
                chaos=spec.get("chaos"),
                gap_threshold_s=spec.get("gap_threshold_s"))
            shard.add(spec["key"], session)
            sessions[spec["key"]] = session
            del trace        # keep no loose views into the shared segment
        shard.drain()
        results: Dict[str, dict] = {}
        for key in sorted(sessions):
            s = sessions[key]
            results[key] = {
                "summary": s.summary,
                "snapshot": s.snapshot(),
                "windows": list(s.windows),
                "integrator": s.integrator.state_dict(),
                "detector": s.attributor.detector.state_dict(),
                "recalibrations": list(s.recalibrations),
                "samples_drained": s.samples_drained,
                "chunks_drained": s.chunks_drained,
                "sanitizer": s.sanitizer.state_dict(),
            }
        return results
    finally:
        for s in sessions.values():
            # drop trace views into the shared segments before closing them
            s._source = None
        del sessions
        for ring in rings:
            try:
                ring.close()
            except Exception:
                pass


def run_shard_worker(shard_id: int, class_names: List[str],
                     tables: Dict[int, dict], specs: List[dict],
                     conn, sabotage: Optional[str] = None,
                     hang_s: float = 0.0) -> None:
    """Spawned-process entry point: drain one shard, send results back.

    The worker heartbeats (``{"hb": True}``) before doing any work so the
    plane's supervisor can distinguish a hung worker from a slow one.
    ``sabotage`` is the chaos hook: ``"hang"`` sleeps *before* the
    heartbeat (tripping the supervisor's heartbeat timeout), ``"crash"``
    hard-exits after it (tripping the pipe-EOF path).  Restarting is safe:
    the shared rings are read-only to workers and the drain pipeline is
    deterministic, so a relaunched attempt reproduces the lost one.
    """
    try:
        if sabotage == "hang":
            import time
            time.sleep(hang_s)
        conn.send({"hb": True, "shard": int(shard_id)})
        if sabotage == "crash":
            import os
            os._exit(3)          # a hard crash: no reply, just pipe EOF
        results = drain_shard_in_process(shard_id, class_names, tables,
                                         specs)
        conn.send({"ok": True, "results": results})
    except BaseException as exc:  # noqa: BLE001 — the parent re-raises
        conn.send({"ok": False,
                   "error": f"{exc!r}\n{traceback.format_exc()}"})
    finally:
        conn.close()
