"""Streaming power-trace math — one implementation for offline and online.

The offline measurement path (``repro.core.measure``) and the live telemetry
pipeline must agree to numerical precision, or a fleet node would "drift"
against its own post-hoc analysis.  The whole-array primitives
(``trapezoid_energy``, ``rolling_std``) are defined in ``core.measure`` —
the engine layer — and re-exported here; this module adds their streaming
counterparts:

* ``StreamingIntegrator`` — the Fig. 4 trapezoid integral as an
  O(1)-per-sample accumulator (a chunked ``extend`` for array feeds).  The
  incremental sum of segment areas is the same computation
  ``np.trapezoid`` performs, so the two are equal to float round-off.
* ``OnlineSteadyState`` — the offline plateau criterion evaluated sample
  by sample over a bounded window, for live steady-state detection.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

import numpy as np

from repro.core.measure import (rolling_std, trailing_window_moments,
                                trapezoid_energy)

__all__ = ["trapezoid_energy", "rolling_std", "StreamingIntegrator",
           "OnlineSteadyState", "PlateauState"]


class StreamingIntegrator:
    """Incremental trapezoid integration: O(1) state, O(1) per sample.

    ``add`` ingests one sample, ``extend`` a chunk (vectorized); ``energy_j``
    is always the integral over everything seen so far.  Feeding a whole
    trace through either path reproduces ``trapezoid_energy`` exactly.
    """

    def __init__(self) -> None:
        self.energy_j = 0.0
        self.n_samples = 0
        self._t_last: Optional[float] = None
        self._p_last = 0.0

    def add(self, t_s: float, power_w: float) -> float:
        """Ingest one sample; returns the energy of the new segment."""
        seg = 0.0
        if self._t_last is not None:
            seg = 0.5 * (power_w + self._p_last) * (t_s - self._t_last)
            self.energy_j += seg
        self._t_last, self._p_last = float(t_s), float(power_w)
        self.n_samples += 1
        return seg

    def extend(self, times_s: np.ndarray, power_w: np.ndarray) -> float:
        """Ingest a chunk of samples; returns the chunk's energy.

        Bitwise-identical to calling ``add`` per sample: segment areas are
        computed elementwise with the same expression, and accumulated in
        the same left-to-right order (``np.cumsum`` seeded with the running
        total replicates the scalar ``energy_j += seg`` sequence exactly).
        """
        t = np.asarray(times_s, dtype=float)
        p = np.asarray(power_w, dtype=float)
        if t.size == 0:
            return 0.0
        before = self.energy_j
        if self._t_last is not None:
            t = np.concatenate(([self._t_last], t))
            p = np.concatenate(([self._p_last], p))
        if t.size >= 2:
            segs = 0.5 * (p[1:] + p[:-1]) * (t[1:] - t[:-1])
            self.energy_j = float(
                np.cumsum(np.concatenate(([self.energy_j], segs)))[-1])
        self._t_last, self._p_last = float(t[-1]), float(p[-1])
        self.n_samples += int(np.asarray(times_s).size)
        return self.energy_j - before

    @property
    def t_last(self) -> Optional[float]:
        return self._t_last

    @property
    def p_last(self) -> float:
        return self._p_last

    def state_dict(self) -> dict:
        """Complete integrator state; ``load_state`` restores it exactly.

        The floats cross process boundaries (telemetry shard workers)
        unchanged — pickle preserves IEEE-754 bits — so an integrator
        rebuilt from this state continues the same accumulation sequence
        bit-for-bit.
        """
        return {"energy_j": self.energy_j, "n_samples": self.n_samples,
                "t_last": self._t_last, "p_last": self._p_last}

    def load_state(self, state: dict) -> "StreamingIntegrator":
        self.energy_j = float(state["energy_j"])
        self.n_samples = int(state["n_samples"])
        t_last = state["t_last"]
        self._t_last = None if t_last is None else float(t_last)
        self._p_last = float(state["p_last"])
        return self


@dataclasses.dataclass
class PlateauState:
    """Live steady-state verdict after the latest sample."""

    steady: bool                 # currently inside a detected plateau
    start_s: float               # plateau start (nan until detected)
    mean_w: float                # rolling mean power over the window
    std_w: float                 # rolling std over the window


class OnlineSteadyState:
    """Sample-by-sample plateau detection over a bounded rolling window.

    The criterion matches the offline detector in ``repro.core.measure``:
    a window of ``window_s`` seconds whose power std stays below
    ``max(rel_tol * mean, abs_floor_w)``.  State is O(window): a deque of
    (t, p) plus running sum/sum-of-squares.
    """

    def __init__(self, window_s: float = 5.0, rel_tol: float = 0.02,
                 abs_floor_w: float = 1.5, min_samples: int = 4):
        self.window_s = float(window_s)
        self.rel_tol = float(rel_tol)
        self.abs_floor_w = float(abs_floor_w)
        self.min_samples = int(min_samples)
        self._buf: deque = deque()
        self._s1 = 0.0
        self._s2 = 0.0
        self.start_s = math.nan

    def update(self, t_s: float, power_w: float) -> PlateauState:
        self._buf.append((float(t_s), float(power_w)))
        self._s1 += power_w
        self._s2 += power_w * power_w
        # eviction rule phrased exactly as the chunked path's searchsorted
        # membership test (t_j < t_i - window_s), so the two paths always
        # agree on which samples a window holds
        horizon = t_s - self.window_s
        while self._buf and self._buf[0][0] < horizon:
            _, old = self._buf.popleft()
            self._s1 -= old
            self._s2 -= old * old
        n = len(self._buf)
        mean = self._s1 / n
        var = max(self._s2 / n - mean * mean, 0.0)
        std = math.sqrt(var)
        steady = (n >= self.min_samples
                  and std < max(self.rel_tol * abs(mean), self.abs_floor_w))
        if steady and math.isnan(self.start_s):
            self.start_s = self._buf[0][0]
        elif not steady:
            self.start_s = math.nan
        return PlateauState(steady=steady, start_s=self.start_s,
                            mean_w=mean, std_w=std)

    def update_chunk(self, times_s, power_w, with_verdicts: bool = False):
        """Chunked ``update``: one vectorized pass over the whole chunk.

        Window stats come from cumulative sums over (retained window +
        chunk) via ``core.measure.trailing_window_moments`` — one
        searchsorted eviction instead of a deque walk per sample.  The
        per-sample verdict sequence and the ``start_s`` transition logic
        match the scalar path (window membership is decided by the identical
        float comparison; means/stds agree to round-off because the chunk
        path computes them from fresh sums rather than a running
        add/subtract).  Returns the final ``PlateauState``; with
        ``with_verdicts=True`` also the per-sample steady bool array.
        """
        t_new = np.asarray(times_s, dtype=float)
        p_new = np.asarray(power_w, dtype=float)
        if t_new.size == 0:
            state = self._state_now()
            return (state, np.zeros(0, dtype=bool)) if with_verdicts \
                else state
        if self._buf:
            held = np.asarray(self._buf, dtype=float)
            t = np.concatenate([held[:, 0], t_new])
            p = np.concatenate([held[:, 1], p_new])
            n0 = held.shape[0]
        else:
            t, p, n0 = t_new, p_new, 0
        left, count, mean, std = trailing_window_moments(
            t, p, self.window_s, start=n0)
        steady = ((count >= self.min_samples)
                  & (std < np.maximum(self.rel_tol * np.abs(mean),
                                      self.abs_floor_w)))
        prev = np.concatenate(([not math.isnan(self.start_s)], steady[:-1]))
        if steady[-1]:
            begins = np.nonzero(steady & ~prev)[0]
            if begins.size:        # latest steady run began inside the chunk
                self.start_s = float(t[left[begins[-1]]])
            # else: the pre-chunk plateau never broke; start_s carries over
        else:
            self.start_s = math.nan
        keep = int(left[-1])
        kept = p[keep:]
        self._buf = deque(zip(t[keep:].tolist(), kept.tolist()))
        self._s1 = float(np.sum(kept))
        self._s2 = float(np.sum(kept * kept))
        state = PlateauState(steady=bool(steady[-1]), start_s=self.start_s,
                             mean_w=float(mean[-1]), std_w=float(std[-1]))
        return (state, steady) if with_verdicts else state

    def _state_now(self) -> PlateauState:
        """The verdict as of the latest ingested sample (no new samples)."""
        n = len(self._buf)
        if n == 0:
            return PlateauState(steady=False, start_s=self.start_s,
                                mean_w=math.nan, std_w=math.nan)
        mean = self._s1 / n
        std = math.sqrt(max(self._s2 / n - mean * mean, 0.0))
        return PlateauState(steady=not math.isnan(self.start_s),
                            start_s=self.start_s, mean_w=mean, std_w=std)
