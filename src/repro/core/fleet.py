"""Fleet monitoring — "watching the wattchers" in production.

This is the machinery behind the QMCPACK case study (§5.3.2): Wattchmen is
integrated into a monitoring workflow; per-step energy predictions and
breakdowns are streamed, and anomalies (a class whose energy share spikes
versus its rolling baseline) are flagged for the developer.  In this repo
the same monitor wraps the training/serving loops of ``repro.launch``.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core import isa
from repro.core.opcount import OpCounts
from repro.core.predict import Prediction, TablePredictor
from repro.core.table import EnergyTable


@dataclasses.dataclass
class Anomaly:
    step: int
    cls: str
    share: float
    baseline_share: float
    message: str


@dataclasses.dataclass
class StepRecord:
    step: int
    prediction: Prediction
    joules_per_unit_work: float
    measured_j: Optional[float] = None     # live telemetry, when streamed

    @property
    def error_pct(self) -> Optional[float]:
        """Predicted-vs-measured error; None without live telemetry."""
        if self.measured_j is None or self.measured_j <= 0:
            return None
        return 100.0 * (self.prediction.total_j / self.measured_j - 1.0)


class EnergyMonitor:
    """Streaming per-step energy attribution with spike detection.

    ``table`` accepts an ``EnergyTable``, a ``TablePredictor``, or the
    ``repro.api.EnergyModel`` facade — in the latter cases the monitor
    shares the caller's precomputed class->energy vectors, so per-step
    prediction on the fleet hot path never re-walks the table.
    """

    def __init__(self, table, window: int = 16,
                 spike_ratio: float = 1.75, min_share: float = 0.04,
                 step_counts: Optional[OpCounts] = None,
                 governor=None):
        predictor = getattr(table, "predictor", None)   # EnergyModel
        if predictor is None and isinstance(table, TablePredictor):
            predictor = table
        if predictor is None:
            predictor = TablePredictor(table)
            predictor.warm()       # streaming hot path
        self._predictor = predictor
        self.table: EnergyTable = predictor.table
        self.window = window
        self.spike_ratio = spike_ratio
        self.min_share = min_share
        self.step_counts = step_counts
        self.governor = governor   # SweetSpotGovernor fed by live windows
        self.live = None           # StreamSession, when monitor(live=...)
        self._hist: Dict[str, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))
        self.records: List[StepRecord] = []
        self.anomalies: List[Anomaly] = []

    def set_step_counts(self, counts: OpCounts) -> None:
        """Default per-step op counts (one profile per program, §5.3.2)."""
        self.step_counts = counts

    def bind(self, service, key: Optional[str] = None) -> str:
        """Ride a ``TelemetryService``/``TelemetryPlane``: register the live
        session (and governor pane, when present) so this monitor's
        workload shows up in the fleet snapshot and drains through
        plane-wide ``poll_all``/``finish_all``.  Returns the session key.
        """
        if self.live is None:
            raise RuntimeError("no live session: create the monitor with "
                               "monitor(live=True) before bind()")
        key = key or f"{self.live.device.name}/{self.live.name}"
        service.register(self.live, key)
        if self.governor is not None and hasattr(service,
                                                 "register_governor"):
            service.register_governor(key, self.governor)
        return key

    def kernel_scope(self, name: str, variant: str = "pallas",
                     config=(), counts: Optional[OpCounts] = None):
        """Declare a kernel launch on the live session (microscopy scope).

        Delegates to ``StreamSession.kernel_scope`` — each step's aligned
        window then subdivides into per-launch kernel windows that tile the
        step's measured joules bitwise; read them back with
        ``monitor.live.kernel_report()``.  Requires ``monitor(live=...)``.
        """
        if self.live is None:
            raise RuntimeError("no live session: create the monitor with "
                               "monitor(live=True) before kernel_scope()")
        return self.live.kernel_scope(name, variant=variant, config=config,
                                      counts=counts)

    def observe(self, step: int, counts: Optional[OpCounts] = None,
                duration_s: Optional[float] = None,
                counters: Optional[dict] = None,
                work_units: float = 1.0,
                measured_j: Optional[float] = None,
                operating_point=None) -> StepRecord:
        if counts is None:
            counts = self.step_counts
            if counts is None:
                raise ValueError("no counts: pass counts= or call "
                                 "set_step_counts() first")
        if duration_s is None:
            raise ValueError("duration_s is required: the (const+static) "
                             "power term scales with it")
        pred = self._predictor.predict(counts, duration_s, counters=counters,
                                       operating_point=operating_point)
        if self.governor is not None and measured_j is not None:
            point = (operating_point if operating_point is not None
                     else self.governor.current)
            if point is not None:
                self.governor.observe(point, measured_j, duration_s,
                                      work_units)
        rec = StepRecord(step=step, prediction=pred,
                         joules_per_unit_work=pred.total_j / max(work_units, 1e-12),
                         measured_j=measured_j)
        self.records.append(rec)
        # step-level energy spike (uniform regressions move no class share —
        # the paper's QMCPACK "unusual DMC spikes")
        ehist = self._hist["__step_energy__"]
        if len(ehist) >= self.window // 2:
            base = sum(ehist) / len(ehist)
            if base > 0 and rec.joules_per_unit_work > self.spike_ratio * base:
                self.anomalies.append(Anomaly(
                    step=step, cls="__step_energy__",
                    share=rec.joules_per_unit_work, baseline_share=base,
                    message=(f"step {step}: energy/work "
                             f"{rec.joules_per_unit_work:.3e} J vs baseline "
                             f"{base:.3e} J "
                             f"(x{rec.joules_per_unit_work / base:.2f})")))
        ehist.append(rec.joules_per_unit_work)
        dyn = max(pred.dynamic_j, 1e-12)
        # per-class shares straight off the prediction's class vector —
        # no breakdown dict materialized on the fleet hot path
        vec = pred.class_energy_vec
        nz = np.nonzero(vec)[0]
        shares = vec[nz] / dyn
        name = isa.CLASS_INDEX.name
        for i, share in zip(nz, shares):
            cls = name(int(i))
            share = float(share)
            hist = self._hist[cls]
            if len(hist) >= self.window // 2:
                base = sum(hist) / len(hist)
                if share > self.min_share and base > 1e-6 \
                        and share > self.spike_ratio * base:
                    self.anomalies.append(Anomaly(
                        step=step, cls=cls, share=share, baseline_share=base,
                        message=(f"step {step}: class '{cls}' energy share "
                                 f"{share:.1%} vs baseline {base:.1%} "
                                 f"(x{share / base:.2f})")))
            hist.append(share)
        return rec

    def top_consumers(self, k: int = 10):
        """Aggregate per-class energy over all observed steps (Fig. 10)."""
        if not self.records:
            return []
        vecs = [r.prediction.class_energy_vec for r in self.records]
        agg = np.zeros(max(v.size for v in vecs))
        for v in vecs:
            agg[:v.size] += v
        top = np.argsort(-agg)[:k]
        name = isa.CLASS_INDEX.name
        return [(name(int(i)), float(agg[i])) for i in top if agg[i] != 0.0]
