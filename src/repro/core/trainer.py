"""Training phase driver — paper Fig. 2 (top half).

Since the calibration refactor the actual work lives in
``repro.core.calibrate`` as a staged, resumable pipeline (plan -> measure ->
solve -> extend -> publish).  This module keeps the historical one-call
surface: ``train_table`` runs the pipeline end to end with an ephemeral
(in-memory) ledger, exactly the old serial semantics.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

from repro.core.calibrate import BENCH_TARGET_SECONDS, REPEATS, calibrate
from repro.core.table import EnergyTable
from repro.hw.device import SimDevice


def train_table(system: str, duration_s: float = BENCH_TARGET_SECONDS,
                repeats: int = REPEATS,
                device: Optional[SimDevice] = None, *,
                run_dir=None, resume: bool = True) -> EnergyTable:
    """One-shot calibration; pass ``run_dir`` for incremental persistence
    + resume (see ``core.calibrate`` for the staged pipeline).

    As the unattended surface, records left by an obsolete plan (e.g. a
    suite change between versions) are discarded with a warning instead of
    wedging every future training attempt.
    """
    return calibrate(system, duration_s=duration_s, repeats=repeats,
                     device=device, run_dir=run_dir, resume=resume,
                     on_plan_mismatch="discard")


@functools.lru_cache(maxsize=None)
def cached_table(system: str) -> EnergyTable:
    """Deprecated: use ``repro.api.EnergyModel.from_store`` instead.

    Kept as a shim for existing imports.  Now write-through backed by the
    on-disk ``TableStore`` (plus this in-process memo), so a trained table
    survives across processes instead of being re-trained per process.
    """
    warnings.warn(
        "repro.core.trainer.cached_table is deprecated; use "
        "repro.api.EnergyModel.from_store(system) (persistent TableStore)",
        DeprecationWarning, stacklevel=2)
    from repro.core.store import default_store
    return default_store().get_or_train(system)
