"""Training phase driver — paper Fig. 2 (top half).

Runs the microbenchmark suite on a (simulated) system, measures steady-state
energies, isolates constant/static power, solves the square non-negative
system, and extends coverage — producing the ``EnergyTable`` artifact.
"""
from __future__ import annotations

import functools
import warnings
from typing import List, Optional

import numpy as np

from repro.core import coverage, measure, microbench, solver
from repro.core.table import EnergyTable
from repro.hw.device import Program, SimDevice
from repro.hw.systems import SYSTEMS, get_device

BENCH_TARGET_SECONDS = 120.0   # steady-state duration per benchmark (§6: 180s
                               # on hardware; the plateau is reached well
                               # before that on the simulated systems too)
REPEATS = 3                    # medians over repeats (paper: 5)


def train_table(system: str, duration_s: float = BENCH_TARGET_SECONDS,
                repeats: int = REPEATS,
                device: Optional[SimDevice] = None) -> EnergyTable:
    dev = device or get_device(system)
    gen = dev.chip.isa_gen
    suite = microbench.build_suite(isa_gen=gen)

    # The square-system property: one benchmark per benched class (§3.1).
    targets = microbench.benched_classes(suite)
    assert len(targets) == len(set(targets)) == len(suite), \
        "system of equations must stay square"

    # 1. constant power from idle probes (median across repeats).
    p_const = float(np.median([measure.constant_power(dev.idle(30.0))
                               for _ in range(repeats)]))

    # 2. static power from the NANOSLEEP probe.
    nanosleep = microbench.MicroBench(
        name="CTL_NANOSLEEP_probe", target="ctl.loop",
        counts=microbench._nanosleep_counts(), is_nanosleep=True)
    ns_prog = Program(nanosleep.name, nanosleep.counts,
                      iters=dev.iters_for_duration(nanosleep.counts, duration_s),
                      is_nanosleep=True)
    p_static = float(np.median([
        measure.static_power(dev.run(ns_prog), p_const)
        for _ in range(repeats)]))

    # 3. run every benchmark to steady state; median dynamic energy.
    records, dyn = [], []
    for bench in suite:
        iters = dev.iters_for_duration(bench.counts, duration_s)
        prog = Program(bench.name, bench.counts, iters=iters,
                       is_nanosleep=bench.is_nanosleep)
        runs = [dev.run(prog) for _ in range(repeats)]
        energies = [measure.dynamic_energy(r, p_const, p_static)
                    for r in runs]
        med = int(np.argsort(energies)[len(energies) // 2])
        records.append(runs[med])
        dyn.append(energies[med])

    # 4. square non-negative solve.
    system_eq = solver.build_system(suite, records, dyn, targets)
    sol = solver.solve_nonnegative(system_eq)

    table = EnergyTable(system=dev.name, p_const=p_const, p_static=p_static,
                        direct=sol.energies,
                        meta={"residual_rel": sol.residual_rel,
                              "n_benchmarks": float(len(suite)),
                              "isa_gen": float(gen)})
    # 5. coverage extension (scaling + bucketing, §3.4).
    coverage.extend_table(table, dev.chip)
    return table


@functools.lru_cache(maxsize=None)
def cached_table(system: str) -> EnergyTable:
    """Deprecated: use ``repro.api.EnergyModel.from_store`` instead.

    Kept as a shim for existing imports.  Now write-through backed by the
    on-disk ``TableStore`` (plus this in-process memo), so a trained table
    survives across processes instead of being re-trained per process.
    """
    warnings.warn(
        "repro.core.trainer.cached_table is deprecated; use "
        "repro.api.EnergyModel.from_store(system) (persistent TableStore)",
        DeprecationWarning, stacklevel=2)
    from repro.core.store import default_store
    return default_store().get_or_train(system, train_table)
