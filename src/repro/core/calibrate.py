"""The training half as a staged, resumable pipeline — paper Fig. 2 (top).

``trainer.train_table`` used to be a serial monolith: probe, run all ~76
steady-state microbenchmarks, solve, extend — all in one process lifetime,
losing everything on interruption.  This module splits it into composable
stages over the same vector currency (``isa.CLASS_INDEX``) prediction has
used since the batching refactor:

  **plan**     the microbenchmark suite, idle/NANOSLEEP probes, repeat
               schedule — and, in ``profile_fraction`` mode, the sampled
               subset of classes to actually measure — as *data*
               (``CalibrationPlan``);
  **measure**  each probe/benchmark executed to steady state and persisted
               *incrementally* to a per-run directory (one JSON record per
               spec, atomic writes), so an interrupted calibration resumes
               from the completed records and re-runs nothing;
  **solve**    NNLS over the stacked counts matrix (square in full mode;
               donor-affine-pinned reduced solve in fractional mode);
  **extend**   coverage extension (scaling + bucketing, §3.4);
  **publish**  atomic write into the ``TableStore``.

Measurement records are *order independent*: every run draws its sensor
noise from a deterministic substream keyed on (device seed, spec id,
repeat) — ``SimDevice.noise_rng`` — so a calibration interrupted after k
benchmarks and resumed later produces a table bit-identical to the
uninterrupted run.

Fractional mode folds the paper's §6/Fig. 14 bootstrap into calibration
proper: measure only a sampled fraction of the suite on the new system,
fit the donor->target affine map on the sampled classes, pin the
unmeasured columns to affine-mapped donor energies in the solve, and
affine-predict every remaining donor class (including ones the target
suite never benches).  ``calibrate_fleet`` runs the measure/solve stages
for several systems concurrently — new systems are brought up the way
``TablePredictor`` already prices batches of programs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Union)

import numpy as np

from repro.core import coverage, measure as measure_mod, microbench, solver
from repro.core.store import quarantine_file
from repro.core.table import EnergyTable
from repro.core.transfer import TransferFit, hybrid_direct, sample_classes
from repro.hw.device import Program, SimDevice
from repro.hw.systems import get_device

BENCH_TARGET_SECONDS = 120.0   # steady-state duration per benchmark (§6: 180s
                               # on hardware; the plateau is reached well
                               # before that on the simulated systems too)
REPEATS = 3                    # medians over repeats (paper: 5)
IDLE_SECONDS = 30.0            # constant-power probe duration

RECORD_VERSION = 1

KIND_IDLE = "idle"
KIND_NANOSLEEP = "nanosleep"
KIND_BENCH = "bench"


class CalibrationError(RuntimeError):
    """A pipeline stage cannot proceed (mismatched plan, missing records)."""


# ---------------------------------------------------------------------------
# Stage 1: plan.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """One unit of measurement: a probe or microbenchmark × repeat count."""

    spec_id: str               # stable id: record filename + noise-key stem
    kind: str                  # idle | nanosleep | bench
    name: str
    target: Optional[str]      # benched op class (bench kind only)
    repeats: int
    duration_s: float


@dataclasses.dataclass
class CalibrationPlan:
    """The whole campaign as data: what to run, what to solve."""

    system: str
    isa_gen: int
    duration_s: float
    repeats: int
    seed: int
    profile_fraction: Optional[float]      # None => full calibration
    donor_system: Optional[str]
    suite: List[microbench.MicroBench]
    targets: List[str]                     # benched classes, suite order
    measured: List[str]                    # classes actually run, suite order
    specs: List[ProbeSpec]
    donor_table: Optional[EnergyTable] = None
    freq_mhz: Optional[float] = None       # DVFS sweep point (None: nominal)
    power_cap_w: Optional[float] = None

    @property
    def is_fractional(self) -> bool:
        return self.profile_fraction is not None

    @property
    def spec_tag(self) -> str:
        """Spec-id suffix isolating this plan's DVFS point ("" at nominal)."""
        if self.freq_mhz is None:
            return ""
        return f"@f{self.freq_mhz:g}c{self.power_cap_w:g}"

    def spec_ids(self) -> List[str]:
        return [s.spec_id for s in self.specs]

    def fingerprint(self) -> Dict[str, Any]:
        """Identity of the campaign — resumed runs must match exactly."""
        fp = {
            "record_version": RECORD_VERSION,
            "system": self.system,
            "isa_gen": self.isa_gen,
            "duration_s": self.duration_s,
            "repeats": self.repeats,
            "seed": self.seed,
            "profile_fraction": self.profile_fraction,
            "donor_system": self.donor_system,
            "spec_ids": self.spec_ids(),
        }
        # conditional so nominal fingerprints match pre-sweep plan.json files
        if self.freq_mhz is not None:
            fp["freq_mhz"] = self.freq_mhz
            fp["power_cap_w"] = self.power_cap_w
        return fp


def plan(system: str, *, duration_s: float = BENCH_TARGET_SECONDS,
         repeats: int = REPEATS,
         profile_fraction: Optional[float] = None,
         donor: Optional[EnergyTable] = None,
         seed: int = 0,
         device: Optional[SimDevice] = None,
         operating_point=None) -> CalibrationPlan:
    """Build the campaign: suite + probes + (optionally sampled) schedule.

    ``operating_point`` pins the campaign to one (freq_mhz, power_cap_w)
    DVFS point — spec ids get a ``@f<freq>c<cap>`` suffix so a sweep's
    per-point records draw disjoint noise substreams and never collide in a
    shared run directory, and ``run_measurements`` sets the device to the
    point before measuring.
    """
    dev = device or get_device(system)
    gen = dev.chip.isa_gen
    freq_mhz = cap_w = None
    if operating_point is not None:
        from repro.dvfs.interp import as_point
        freq_mhz, cap_w = as_point(operating_point)
        if cap_w is None:
            cap_w = float(dev.chip.tdp_watts)
    suite = microbench.build_suite(isa_gen=gen)
    targets = microbench.benched_classes(suite)
    # The square-system property: one benchmark per benched class (§3.1).
    assert len(targets) == len(set(targets)) == len(suite), \
        "system of equations must stay square"

    if profile_fraction is not None:
        if donor is None:
            raise CalibrationError(
                "profile_fraction calibration needs a donor table "
                "(the Fig. 14 affine-transfer source)")
        if not 0.0 < profile_fraction <= 1.0:
            raise CalibrationError(
                f"profile_fraction must be in (0, 1], got {profile_fraction}")
        common = set(targets) & set(donor.direct)
        candidates = sorted(c for c in common if donor.direct[c] > 0)
        sampled = set(sample_classes(candidates, population=len(common),
                                     fraction=profile_fraction, seed=seed))
        # classes the donor cannot predict must be measured regardless
        forced = set(targets) - set(candidates)
        keep = sampled | forced
        measured = [t for t in targets if t in keep]
    else:
        measured = list(targets)

    tag = "" if freq_mhz is None else f"@f{freq_mhz:g}c{cap_w:g}"
    if freq_mhz is not None:
        vf = dev.vf
        if not (vf.f_min_mhz <= freq_mhz <= vf.f_max_mhz):
            raise CalibrationError(
                f"{dev.name}: frequency {freq_mhz:g} MHz outside the V/f "
                f"range [{vf.f_min_mhz:g}, {vf.f_max_mhz:g}]")
    specs = [
        ProbeSpec(spec_id=f"idle{tag}", kind=KIND_IDLE, name="IDLE_probe",
                  target=None, repeats=repeats, duration_s=IDLE_SECONDS),
        ProbeSpec(spec_id=f"nanosleep{tag}", kind=KIND_NANOSLEEP,
                  name="CTL_NANOSLEEP_probe", target="ctl.loop",
                  repeats=repeats, duration_s=duration_s),
    ]
    keep = set(measured)
    specs += [ProbeSpec(spec_id=f"bench:{b.name}{tag}", kind=KIND_BENCH,
                        name=b.name, target=b.target, repeats=repeats,
                        duration_s=duration_s)
              for b in suite if b.target in keep]
    return CalibrationPlan(
        system=dev.name, isa_gen=gen, duration_s=duration_s, repeats=repeats,
        seed=seed, profile_fraction=profile_fraction,
        donor_system=donor.system if donor is not None else None,
        suite=suite, targets=targets, measured=measured, specs=specs,
        donor_table=donor, freq_mhz=freq_mhz, power_cap_w=cap_w)


# ---------------------------------------------------------------------------
# Stage 2: measure (incremental, resumable).
# ---------------------------------------------------------------------------
class RunLedger:
    """Per-campaign record set, optionally persisted one file per spec.

    With a ``run_dir`` every completed record is written atomically as JSON
    under ``<run_dir>/records/``, and the campaign fingerprint is pinned in
    ``<run_dir>/plan.json`` so a resume against a different plan fails loud
    instead of mixing incompatible records.  Without a directory the ledger
    is an in-memory dict (the one-shot ``train_table`` path).
    """

    def __init__(self, run_dir: Optional[Union[str, os.PathLike]] = None):
        self.run_dir = pathlib.Path(run_dir) if run_dir is not None else None
        self.records: Dict[str, Dict[str, Any]] = {}

    # -- layout -------------------------------------------------------------
    def _records_dir(self) -> pathlib.Path:
        assert self.run_dir is not None
        return self.run_dir / "records"

    @staticmethod
    def _fname(spec_id: str) -> str:
        return spec_id.replace(":", "__") + ".json"

    def _write_json(self, path: pathlib.Path, payload: Mapping[str, Any]):
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
                f.flush()
                os.fsync(f.fileno())       # survive a crash mid-campaign
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # -- plan binding -------------------------------------------------------
    def bind(self, p: CalibrationPlan, resume: bool = True,
             on_mismatch: str = "raise") -> None:
        """Attach to the plan: load completed records, pin the fingerprint.

        ``on_mismatch`` decides what happens when the directory holds
        records for a *different* plan: ``"raise"`` (explicit callers —
        never mix incompatible records silently) or ``"discard"`` (warn and
        start over; the unattended ``from_store``/``get_or_train`` path,
        where stale records from an obsolete plan could otherwise wedge
        every future load).
        """
        self.records.clear()
        if self.run_dir is None:
            return
        fp_path = self.run_dir / "plan.json"
        want = p.fingerprint()
        if fp_path.exists():
            try:
                have = json.loads(fp_path.read_text())
            except ValueError as e:
                # a torn/corrupt fingerprint means the records' plan
                # identity is gone — handled exactly like a plan mismatch
                moved = quarantine_file(fp_path)
                warnings.warn(
                    f"quarantined corrupt calibration plan fingerprint "
                    f"{fp_path} -> {moved}: {e}",
                    RuntimeWarning, stacklevel=2)
                have = None
            if have != want:
                if resume and on_mismatch != "discard":
                    raise CalibrationError(
                        f"run directory {self.run_dir} holds records for a "
                        f"different calibration plan (or a corrupted "
                        f"fingerprint); pass resume=False to discard them "
                        f"or use a fresh run_dir")
                if resume:
                    warnings.warn(
                        f"discarding calibration records in {self.run_dir}: "
                        f"they belong to a different (obsolete) plan",
                        RuntimeWarning, stacklevel=2)
                shutil.rmtree(self.run_dir)
        elif self.run_dir.exists() and not resume:
            shutil.rmtree(self.run_dir)
        self._write_json(fp_path, want)
        rdir = self._records_dir()
        if not resume or not rdir.is_dir():
            return
        for spec in p.specs:
            path = rdir / self._fname(spec.spec_id)
            if not path.exists():
                continue
            try:
                rec = json.loads(path.read_text())
                if not isinstance(rec, dict):
                    raise ValueError(f"expected a JSON object, got "
                                     f"{type(rec).__name__}")
            except ValueError as e:
                # one bad record costs one re-measurement, nothing more:
                # it is moved aside and ``missing()`` picks its spec up
                moved = quarantine_file(path)
                warnings.warn(
                    f"quarantined corrupt calibration record {path} -> "
                    f"{moved}: {e}; spec {spec.spec_id!r} will be "
                    f"re-measured", RuntimeWarning, stacklevel=2)
                continue
            if rec.get("record_version") == RECORD_VERSION:
                self.records[spec.spec_id] = rec

    # -- record io ----------------------------------------------------------
    def put(self, record: Dict[str, Any]) -> None:
        self.records[record["spec_id"]] = record
        if self.run_dir is not None:
            self._write_json(
                self._records_dir() / self._fname(record["spec_id"]), record)

    def missing(self, p: CalibrationPlan) -> List[ProbeSpec]:
        return [s for s in p.specs if s.spec_id not in self.records]

    def complete(self, p: CalibrationPlan) -> bool:
        return not self.missing(p)


def _measure_one(dev: SimDevice, p: CalibrationPlan,
                 spec: ProbeSpec) -> Dict[str, Any]:
    """Execute one spec (all repeats) and reduce it to its record payload.

    Records hold only the derived observables the solve needs (powers,
    total joules, profiler counters) — a few hundred bytes per benchmark
    instead of full sensor traces.
    """
    repeats: List[Dict[str, Any]] = []
    for r in range(spec.repeats):
        key = f"calib:{spec.spec_id}:r{r}"
        if spec.kind == KIND_IDLE:
            trace = dev.idle(spec.duration_s, noise_key=key)
            repeats.append(
                {"p_const_w": measure_mod.constant_power(trace)})
        elif spec.kind == KIND_NANOSLEEP:
            counts = microbench._nanosleep_counts()
            prog = Program(spec.name, counts,
                           iters=dev.iters_for_duration(counts,
                                                        spec.duration_s),
                           is_nanosleep=True)
            rec = dev.run(prog, noise_key=key)
            ss = measure_mod.detect_steady_state(rec.trace)
            repeats.append({"ss_power_w": float(ss.power_w)})
        else:
            bench = next(b for b in p.suite if b.name == spec.name)
            iters = dev.iters_for_duration(bench.counts, spec.duration_s)
            prog = Program(bench.name, bench.counts, iters=iters,
                           is_nanosleep=bench.is_nanosleep)
            rec = dev.run(prog, noise_key=key)
            repeats.append({
                "total_j": measure_mod.total_energy(rec),
                "duration_s": float(rec.duration_s),
                "iters": int(rec.iters),
                "counters": {k: float(v) for k, v in rec.counters.items()},
            })
    return {"record_version": RECORD_VERSION, "spec_id": spec.spec_id,
            "kind": spec.kind, "name": spec.name, "target": spec.target,
            "repeats": repeats}


def run_measurements(p: CalibrationPlan,
                     ledger: Optional[RunLedger] = None,
                     device: Optional[SimDevice] = None,
                     *, limit: Optional[int] = None,
                     progress: Optional[Callable[[ProbeSpec, int, int],
                                                 None]] = None) -> RunLedger:
    """Execute (up to ``limit``) pending specs, persisting each record.

    Already-recorded specs are skipped — calling this again after an
    interruption continues exactly where the campaign stopped.

    A plan pinned to a DVFS point sets the device there for the duration of
    the measurements and restores the previous point after — the nominal
    path never touches the device (bitwise-identical records).
    """
    ledger = ledger or RunLedger()
    dev = device or get_device(p.system)
    pending = ledger.missing(p)
    total = len(p.specs)
    restore = None
    if p.freq_mhz is not None:
        restore = dev.operating_point
        dev.set_operating_point(p.freq_mhz, power_cap_w=p.power_cap_w)
    try:
        for i, spec in enumerate(pending):
            if limit is not None and i >= limit:
                break
            if progress is not None:
                progress(spec, total - len(pending) + i, total)
            ledger.put(_measure_one(dev, p, spec))
    finally:
        if restore is not None:
            dev.set_operating_point(restore)
    return ledger


# ---------------------------------------------------------------------------
# Stage 3: solve.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _SolveRecord:
    """The slice of a ``RunRecord`` the system assembly consumes."""

    iters: int
    counters: Dict[str, float]


def _powers(p: CalibrationPlan, ledger: RunLedger) -> tuple:
    idle = ledger.records.get(f"idle{p.spec_tag}")
    ns = ledger.records.get(f"nanosleep{p.spec_tag}")
    if idle is None or ns is None:
        raise CalibrationError("idle/nanosleep probe records missing")
    p_const = float(np.median([r["p_const_w"] for r in idle["repeats"]]))
    p_static = float(np.median([max(r["ss_power_w"] - p_const, 0.0)
                                for r in ns["repeats"]]))
    return p_const, p_static


def solve(p: CalibrationPlan, ledger: RunLedger) -> EnergyTable:
    """Median-reduce the records and solve the (square or pinned) system."""
    missing = ledger.missing(p)
    if missing:
        raise CalibrationError(
            f"cannot solve: {len(missing)} measurement records pending "
            f"(first: {missing[0].spec_id}); resume the measure stage first")
    p_const, p_static = _powers(p, ledger)

    bench_by_target = {b.target: b for b in p.suite}
    rows, recs, dyn = [], [], []
    for target in p.measured:
        bench = bench_by_target[target]
        rec = ledger.records[f"bench:{bench.name}{p.spec_tag}"]
        energies = [max(rep["total_j"]
                        - (p_const + p_static) * rep["duration_s"], 0.0)
                    for rep in rec["repeats"]]
        med = int(np.argsort(energies)[len(energies) // 2])
        rep = rec["repeats"][med]
        rows.append(bench)
        recs.append(_SolveRecord(iters=rep["iters"],
                                 counters=dict(rep["counters"])))
        dyn.append(energies[med])

    meta = {"n_benchmarks": float(len(rows)), "isa_gen": float(p.isa_gen)}
    if p.freq_mhz is not None:
        meta["freq_mhz"] = float(p.freq_mhz)
        meta["power_cap_w"] = float(p.power_cap_w)
    provenance: Dict[str, Any] = {
        "pipeline": "core.calibrate",
        "mode": "fractional" if p.is_fractional else "full",
        "seed": p.seed,
        "repeats": p.repeats,
        "duration_s": p.duration_s,
        "n_measured": len(p.measured),
        "n_targets": len(p.targets),
    }

    if not p.is_fractional:
        system_eq = solver.build_system(rows, recs, dyn, p.measured)
        sol = solver.solve_nonnegative(system_eq)
        direct = sol.energies
        meta["residual_rel"] = sol.residual_rel
    else:
        donor = p.donor_table
        if donor is None:
            raise CalibrationError("fractional solve needs plan.donor_table")
        direct, fit, resid = _solve_fractional(p, rows, recs, dyn, donor)
        meta.update({"residual_rel": resid,
                     "fraction": float(p.profile_fraction),
                     "r2_fit": fit.r2})
        provenance.update({"donor": p.donor_system,
                           "profile_fraction": p.profile_fraction,
                           "r2_fit": fit.r2})

    return EnergyTable(system=p.system, p_const=p_const, p_static=p_static,
                       direct=direct, meta=meta, provenance=provenance)


def _solve_fractional(p: CalibrationPlan, rows, recs, dyn,
                      donor: EnergyTable):
    """Reduced solve: measured columns free, unmeasured pinned to the donor.

    The donor->target affine map is fit by a *global energy regression*:
    under e ≈ slope·d + icept, every measured benchmark's dynamic energy
    satisfies ``y ≈ slope·(A @ d) + icept·(A @ 1)`` — two unknowns against
    all measured rows.  Because each row's big contributors (memory bytes,
    MXU MACs) dominate that regression, the map is anchored on exactly the
    classes that dominate application energy, which a per-class fit over a
    small sampled subset extrapolates to poorly (at a 10% fraction the
    sample rarely contains a memory class at all).  The unmeasured columns
    are then pinned to the mapped donor energies and the sampled columns
    solved by NNLS as usual; the per-class fit quality on the solved values
    is reported as ``r2`` (the paper's R² = 0.988 observable).
    """
    system_eq = solver.build_system(rows, recs, dyn, p.targets)
    measured = set(p.measured)
    unmeasured = [t for t in p.targets if t not in measured]
    fit_on = [t for t in p.measured if donor.direct.get(t, 0.0) > 0]
    donor_fit = np.asarray([donor.direct[c] for c in fit_on])
    donor_unmeasured = np.asarray(
        [donor.direct[c] for c in unmeasured]) if unmeasured else np.empty(0)

    # global 2-parameter fit: y ≈ slope * (A @ d) + icept * (A @ 1)
    d_all = np.asarray([donor.direct.get(c, 0.0) for c in p.targets])
    design = np.vstack([system_eq.matrix @ d_all,
                        system_eq.matrix.sum(axis=1)]).T
    (slope, icept), *_ = np.linalg.lstsq(design, system_eq.rhs, rcond=None)
    fit = TransferFit(float(slope), float(icept), 0.0, len(fit_on))

    fixed = dict(zip(unmeasured, fit.apply(donor_unmeasured)))
    sol = solver.solve_with_fixed(system_eq, fixed)
    # diagnostic r2: how well the map explains the independently solved
    # sampled classes (the Fig. 14 linear-relationship observable)
    if len(fit_on) >= 2:
        ys = np.asarray([sol.energies[c] for c in fit_on])
        pred = fit.apply(donor_fit)
        ss_tot = float(((ys - ys.mean()) ** 2).sum())
        fit = dataclasses.replace(
            fit, r2=1.0 - float(((ys - pred) ** 2).sum()) / max(ss_tot, 1e-30))
    # donor classes beyond the target suite are affine-predicted too
    direct = hybrid_direct(donor, sol.energies, fit)
    return direct, fit, sol.residual_rel


# ---------------------------------------------------------------------------
# Stages 4-5: extend, publish.
# ---------------------------------------------------------------------------
def extend(table: EnergyTable, chip=None) -> EnergyTable:
    """Coverage extension (scaling + bucketing, §3.4)."""
    coverage.extend_table(table, chip)
    return table


def publish(table: EnergyTable, store,
            allow_downgrade: bool = False) -> Optional[pathlib.Path]:
    """Atomic write into the table store; returns the written path.

    A *fractional* table is an approximation: it never silently replaces a
    fully-profiled table already in the store (returns ``None`` with a
    warning) unless ``allow_downgrade=True`` — bootstrap tables are for
    systems that do not have a full profile yet.
    """
    if (table.provenance.get("mode") == "fractional"
            and not allow_downgrade):
        existing = store.get(table.system, table.isa_gen)
        if (existing is not None
                and existing.provenance.get("mode") != "fractional"):
            warnings.warn(
                f"not publishing fractional calibration for "
                f"{table.system!r}: the store already holds a "
                f"fully-profiled table (pass allow_downgrade=True to "
                f"overwrite)", RuntimeWarning, stacklevel=2)
            return None
    return store.put(table)


# ---------------------------------------------------------------------------
# The composed pipeline.
# ---------------------------------------------------------------------------
def _resolve_donor(donor, store=None) -> Optional[EnergyTable]:
    if donor is None or isinstance(donor, EnergyTable):
        return donor
    if isinstance(donor, str):
        from repro.core.store import default_store
        s = store if store is not None else default_store()
        return s.get_or_train(donor)
    table = getattr(donor, "table", None)     # EnergyModel duck-typing
    if isinstance(table, EnergyTable):
        return table
    raise TypeError(f"donor must be an EnergyTable, EnergyModel or system "
                    f"name, got {type(donor).__name__}")


def calibrate(system: str, *, duration_s: float = BENCH_TARGET_SECONDS,
              repeats: int = REPEATS,
              profile_fraction: Optional[float] = None,
              donor=None, seed: int = 0,
              device: Optional[SimDevice] = None,
              run_dir: Optional[Union[str, os.PathLike]] = None,
              resume: bool = True,
              on_plan_mismatch: str = "raise",
              store=None,
              progress: Optional[Callable] = None) -> EnergyTable:
    """plan -> measure -> solve -> extend -> publish, end to end.

    ``run_dir`` enables incremental persistence + resume (``resume=False``
    discards stale records; ``on_plan_mismatch="discard"`` also discards
    records left by an obsolete plan instead of raising); ``store``
    publishes the finished table.  ``donor`` + ``profile_fraction`` select
    the Fig. 14 bootstrap mode.
    """
    dev = device or get_device(system)
    donor_table = _resolve_donor(donor, store)
    p = plan(system, duration_s=duration_s, repeats=repeats,
             profile_fraction=profile_fraction, donor=donor_table,
             seed=seed, device=dev)
    ledger = RunLedger(run_dir)
    ledger.bind(p, resume=resume, on_mismatch=on_plan_mismatch)
    n_resumed = len(ledger.records)
    run_measurements(p, ledger, dev, progress=progress)
    table = solve(p, ledger)
    if n_resumed:
        table.provenance["n_resumed_records"] = n_resumed
    extend(table, dev.chip)
    if store is not None:
        publish(table, store)
    return table


def calibrate_sweep(system: str, *, points: Optional[Sequence] = None,
                    base_table: Optional[EnergyTable] = None,
                    duration_s: float = BENCH_TARGET_SECONDS,
                    repeats: int = REPEATS, seed: int = 0,
                    device: Optional[SimDevice] = None,
                    run_dir: Optional[Union[str, os.PathLike]] = None,
                    resume: bool = True,
                    on_plan_mismatch: str = "raise",
                    store=None,
                    progress: Optional[Callable] = None) -> EnergyTable:
    """Multi-operating-point calibration: build the frequency family.

    Runs the full staged pipeline once per (freq_mhz, power_cap_w) point
    and attaches each solved per-point table to the anchor's
    ``operating_points`` family (schema v3), so ``TablePredictor`` can
    price any point on the grid — exactly at calibrated members,
    interpolated between them (``repro.dvfs.interp``).

    The *anchor* is ``base_table`` when given, else the store's table for
    ``system``, else a fresh nominal calibration (persisted under
    ``<run_dir>/anchor``).  Resume works at two granularities: each
    point's measurement records live in their own ``<run_dir>/f<f>c<c>``
    directory, and — when a ``store`` is given — the family is republished
    after every completed point, so an interrupted sweep restarts with the
    finished points already attached and skips them.

    ``points`` defaults to three evenly spaced frequencies across the
    device's V/f range (nominal included) at the chip's TDP cap.
    """
    dev = device or get_device(system)
    from repro.dvfs.interp import as_point

    anchor = base_table
    if anchor is None and store is not None:
        anchor = store.get(system)
    if anchor is None:
        rd = pathlib.Path(run_dir) / "anchor" if run_dir is not None else None
        anchor = calibrate(system, duration_s=duration_s, repeats=repeats,
                           seed=seed, device=dev, run_dir=rd, resume=resume,
                           on_plan_mismatch=on_plan_mismatch)
    # stamp the anchor's own operating point (it was measured at nominal)
    anchor.meta.setdefault("freq_mhz", float(dev.vf.f_nom_mhz))
    anchor.meta.setdefault("power_cap_w", float(dev.chip.tdp_watts))
    anchor_pt = (float(anchor.meta["freq_mhz"]),
                 float(anchor.meta["power_cap_w"]))

    if points is None:
        points = [(f, float(dev.chip.tdp_watts)) for f in dev.vf.grid(3)]
    for op in points:
        f, c = as_point(op)
        if c is None:
            c = float(dev.chip.tdp_watts)
        if (f, c) == anchor_pt or (f, c) in anchor.points:
            continue                 # the anchor itself / already calibrated
        pt_plan = plan(system, duration_s=duration_s, repeats=repeats,
                       seed=seed, device=dev, operating_point=(f, c))
        rd = (pathlib.Path(run_dir) / f"f{f:g}c{c:g}"
              if run_dir is not None else None)
        ledger = RunLedger(rd)
        ledger.bind(pt_plan, resume=resume, on_mismatch=on_plan_mismatch)
        run_measurements(pt_plan, ledger, dev, progress=progress)
        sub = solve(pt_plan, ledger)
        extend(sub, dev.chip)
        anchor.add_operating_point(f, c, sub)
        if store is not None:
            publish(anchor, store)   # checkpoint: resume skips this point
    if store is not None:
        publish(anchor, store)
    return anchor


def calibrate_fleet(systems: Sequence[str], *, concurrency: int = 4,
                    store=None, **kwargs) -> Dict[str, EnergyTable]:
    """Calibrate several systems concurrently.

    Plans are built serially (JAX tracing and class-index interning are not
    thread-safe); the measure/solve/extend stages — pure NumPy over already-
    interned classes, plus per-system record IO — fan out on a thread pool.
    Each system gets its own device and (when a store is given) its own
    run directory, so campaigns neither share nor clobber state.
    """
    from repro.core.store import default_store
    s = store if store is not None else default_store()
    plans: Dict[str, CalibrationPlan] = {}
    devices: Dict[str, SimDevice] = {}
    donor_table = _resolve_donor(kwargs.pop("donor", None), s)
    resume = kwargs.pop("resume", True)
    plan_kw = {k: kwargs.pop(k) for k in
               ("duration_s", "repeats", "profile_fraction", "seed")
               if k in kwargs}
    if kwargs:
        raise TypeError(f"calibrate_fleet got unexpected keyword arguments "
                        f"{sorted(kwargs)}")
    for name in systems:
        devices[name] = get_device(name)
        plans[name] = plan(name, donor=donor_table, device=devices[name],
                           **plan_kw)

    def _one(name: str) -> EnergyTable:
        p = plans[name]
        ledger = RunLedger(s.run_dir(name))
        ledger.bind(p, resume=resume)
        run_measurements(p, ledger, devices[name])
        table = solve(p, ledger)
        extend(table, devices[name].chip)
        publish(table, s)
        return table

    with ThreadPoolExecutor(max_workers=max(concurrency, 1)) as pool:
        tables = list(pool.map(_one, systems))
    return dict(zip(systems, tables))
