"""End-to-end evaluation: run workloads, predict with every model, MAPE.

Reproduces the paper's Figures 6-9 / Tables 4-7 pipeline: for each workload,
ground truth is the device's NVML-style energy counter; predictions come from
AccelWattch-style (A), Guser-style (G), Wattchmen-Direct (B) and
Wattchmen-Pred (C).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.api import EnergyModel, PredictJob
from repro.core import baselines, predict as predict_mod
from repro.core.table import EnergyTable
from repro.hw.device import Program
from repro.workloads.suite import Workload, build_workloads


@dataclasses.dataclass
class WorkloadResult:
    name: str
    family: str
    duration_s: float
    measured_j: float
    predictions: Dict[str, float]          # model label -> J
    coverage_direct: float
    coverage_pred: float
    breakdown: Dict[str, float]            # Wattchmen-Pred bucket breakdown


@dataclasses.dataclass
class EvalReport:
    system: str
    results: List[WorkloadResult]

    def mape(self, model: str) -> float:
        return predict_mod.mape(
            [(r.predictions[model], r.measured_j) for r in self.results])

    def mape_table(self) -> Dict[str, float]:
        models = self.results[0].predictions.keys() if self.results else []
        return {m: self.mape(m) for m in models}

    def mean_coverage(self, mode: str = "direct") -> float:
        vals = [r.coverage_direct if mode == "direct" else r.coverage_pred
                for r in self.results]
        return sum(vals) / max(len(vals), 1)


def evaluate_system(system: str,
                    table: Optional[EnergyTable] = None,
                    workloads: Optional[Sequence[Workload]] = None,
                    with_accelwattch: bool = True,
                    with_guser: bool = True,
                    model: Optional[EnergyModel] = None) -> EvalReport:
    # an explicit table always wins (the transfer/hybrid-table pattern),
    # even when a model is also supplied
    if table is not None and (model is None or model.table is not table):
        model = EnergyModel(table, system=system)
    elif model is None:
        model = EnergyModel.from_store(system)
    dev = model.device
    wls = list(workloads) if workloads is not None else build_workloads(
        isa_gen=dev.chip.isa_gen)
    aw = baselines.train_accelwattch() if with_accelwattch else None
    gu = baselines.train_guser(system) if with_guser else None

    # Ground truth for every workload, then one batched prediction pass per
    # Wattchmen mode — the table lookups amortize across the whole suite.
    recs = []
    for wl in wls:
        iters = dev.iters_for_duration(wl.counts, wl.target_seconds)
        rec = dev.run(Program(wl.name, wl.counts, iters=iters))
        recs.append((wl, rec, wl.counts.scaled(rec.iters)))
    p_directs = model.predict_many(
        [PredictJob(total, rec.duration_s, counters=rec.counters,
                    mode="direct", name=wl.name)
         for wl, rec, total in recs])
    p_preds = model.predict_many(
        [PredictJob(total, rec.duration_s, counters=rec.counters,
                    mode="pred", name=wl.name)
         for wl, rec, total in recs])

    results = []
    for (wl, rec, total), p_direct, p_pred in zip(recs, p_directs, p_preds):
        preds: Dict[str, float] = {}
        preds["wattchmen_direct"] = p_direct.total_j
        preds["wattchmen_pred"] = p_pred.total_j
        if aw is not None:
            preds["accelwattch"] = aw.predict_energy(total, rec.duration_s,
                                                     rec.counters)
        if gu is not None:
            preds["guser"] = gu.predict_energy(total, rec.duration_s,
                                               rec.counters)
        results.append(WorkloadResult(
            name=wl.name, family=wl.family, duration_s=rec.duration_s,
            measured_j=rec.energy_counter_j, predictions=preds,
            coverage_direct=p_direct.coverage, coverage_pred=p_pred.coverage,
            breakdown=p_pred.by_bucket))
    return EvalReport(system=system, results=results)
