"""The per-instruction energy table artifact (training-phase output, §3.5)."""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional, Tuple

from repro.core import isa

DIRECT = "direct"
SCALED = "scaled"
BUCKET = "bucket"
MISS = "miss"


@dataclasses.dataclass
class EnergyTable:
    """Output of the training phase: powers + per-class energies."""

    system: str
    p_const: float                      # W
    p_static: float                     # W (all-resources-active)
    direct: Dict[str, float]            # J/unit, from the NNLS solve
    scaled: Dict[str, float] = dataclasses.field(default_factory=dict)
    bucket_means: Dict[str, float] = dataclasses.field(default_factory=dict)
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------
    def lookup(self, cls: str, mode: str = "pred") -> Tuple[float, str]:
        """Energy for a class.

        ``direct`` mode = Wattchmen-Direct (table hits only);
        ``pred`` mode = Wattchmen-Pred (direct -> scaled -> bucket, §3.4).
        """
        v = self.direct.get(cls)
        if v is not None:
            return v, DIRECT
        if mode == "direct":
            return 0.0, MISS
        v = self.scaled.get(cls)
        if v is not None:
            return v, SCALED
        bucket = isa.bucket_of(cls)
        if bucket is not None and bucket in self.bucket_means:
            return self.bucket_means[bucket], BUCKET
        return 0.0, MISS

    # ------------------------------------------------------------------
    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(dataclasses.asdict(self), indent=1))

    @classmethod
    def load(cls, path) -> "EnergyTable":
        d = json.loads(pathlib.Path(path).read_text())
        return cls(**d)
