"""The per-instruction energy table artifact (training-phase output, §3.5)."""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional, Tuple

from repro.core import isa

DIRECT = "direct"
SCALED = "scaled"
BUCKET = "bucket"
MISS = "miss"

# Serialized-table schema.  Bump whenever the on-disk shape of the table (its
# fields or their meaning) changes; the ``TableStore`` keys files by this
# version so stale artifacts are never silently deserialized.
SCHEMA_VERSION = 1


class TableSchemaError(ValueError):
    """A serialized table does not match the current schema."""


@dataclasses.dataclass
class EnergyTable:
    """Output of the training phase: powers + per-class energies."""

    system: str
    p_const: float                      # W
    p_static: float                     # W (all-resources-active)
    direct: Dict[str, float]            # J/unit, from the NNLS solve
    scaled: Dict[str, float] = dataclasses.field(default_factory=dict)
    bucket_means: Dict[str, float] = dataclasses.field(default_factory=dict)
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------
    def lookup(self, cls: str, mode: str = "pred") -> Tuple[float, str]:
        """Energy for a class.

        ``direct`` mode = Wattchmen-Direct (table hits only);
        ``pred`` mode = Wattchmen-Pred (direct -> scaled -> bucket, §3.4).
        """
        v = self.direct.get(cls)
        if v is not None:
            return v, DIRECT
        if mode == "direct":
            return 0.0, MISS
        v = self.scaled.get(cls)
        if v is not None:
            return v, SCALED
        bucket = isa.bucket_of(cls)
        if bucket is not None and bucket in self.bucket_means:
            return self.bucket_means[bucket], BUCKET
        return 0.0, MISS

    # ------------------------------------------------------------------
    @property
    def isa_gen(self) -> int:
        return int(self.meta.get("isa_gen", 0))

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        d = dataclasses.asdict(self)
        d["schema"] = SCHEMA_VERSION
        p.write_text(json.dumps(d, indent=1))

    @classmethod
    def load(cls, path) -> "EnergyTable":
        d = json.loads(pathlib.Path(path).read_text())
        if not isinstance(d, dict):
            raise TableSchemaError(f"{path}: expected a JSON object, "
                                   f"got {type(d).__name__}")
        version = d.pop("schema", None)
        if version != SCHEMA_VERSION:
            raise TableSchemaError(
                f"{path}: schema version {version!r} does not match "
                f"current version {SCHEMA_VERSION} — retrain or migrate "
                f"the table")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise TableSchemaError(
                f"{path}: unknown table fields {unknown} (known: "
                f"{sorted(known)})")
        missing = sorted(k for k in ("system", "p_const", "p_static",
                                     "direct") if k not in d)
        if missing:
            raise TableSchemaError(f"{path}: missing required fields "
                                   f"{missing}")
        return cls(**d)
