"""The per-instruction energy table artifact (training-phase output, §3.5).

Since the calibration refactor the table is *array-backed*: per-class
energies live in dense NumPy vectors over ``isa.CLASS_INDEX`` (one energy
vector + one provenance-mask pair per coverage tier), the same currency axis
``OpCounts`` and ``TablePredictor`` already use.  ``direct`` / ``scaled``
remain available as dict-compatible **views** for existing callers and for
the JSON round-trip — class *names* stay the serialization format; integer
ids are process-lifetime stable only.

Mutations through the views (``table.direct[c] = e``) write through to the
vectors and bump an internal version, so resolved energy vectors
(``energy_vectors``) and any ``TablePredictor`` bound to the table re-derive
automatically.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import tempfile
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

from repro.core import isa

DIRECT = "direct"
SCALED = "scaled"
BUCKET = "bucket"
MISS = "miss"

# Serialized-table schema.  Bump whenever the on-disk shape of the table (its
# fields or their meaning) changes; the ``TableStore`` keys files by this
# version so stale artifacts are never silently deserialized.
#
#   v1  dict-of-dicts dataclass dump (pre array-backed table)
#   v2  adds the required ``provenance`` record (calibration pipeline
#       lineage: stages run, donor table, profile fraction, resume count)
#   v3  adds the frequency axis: an optional ``operating_points`` family of
#       per-(freq_mhz, power_cap_w) sub-tables calibrated by the DVFS sweep
#       stages; the top-level fields are the nominal *anchor* point (whose
#       frequency/cap live in ``meta``), so a v2 table is exactly a v3 table
#       with an empty family — legacy tables load as a one-point family and
#       predict bitwise-identically.
#
# ``TableStore`` migrates older files in place at load time (``core.store``).
SCHEMA_VERSION = 3

_REQUIRED_FIELDS = ("system", "p_const", "p_static", "direct")
_KNOWN_FIELDS = ("system", "p_const", "p_static", "direct", "scaled",
                 "bucket_means", "meta", "provenance", "operating_points")
# Sub-table fields serialized per operating point (everything but identity).
_POINT_FIELDS = ("p_const", "p_static", "direct", "scaled", "bucket_means",
                 "meta")


class TableSchemaError(ValueError):
    """A serialized table does not match the current schema."""


def payload_checksum(d: Mapping[str, Any]) -> str:
    """sha256 over the canonical dump of a JSON payload.

    The ``checksum`` key itself is excluded, so the digest can be stored
    inside the payload it covers.  Canonical form is the same
    ``indent=1, sort_keys=True`` rendering the writers use, so a digest
    computed at save time matches one recomputed from the parsed file.
    """
    body = {k: v for k, v in d.items() if k != "checksum"}
    blob = json.dumps(body, indent=1, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def write_json_atomic(path, payload: Mapping[str, Any]) -> None:
    """Crash-safe JSON publish: tmp file + fsync + atomic rename.

    A reader — this process after a crash, or a fleet node sharing the
    directory — either sees the previous complete file or the new
    complete file, never a torn write.
    """
    p = pathlib.Path(path)
    fd, tmp = tempfile.mkstemp(dir=p.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class ClassVecView(Mapping):
    """Dict-compatible view over one coverage tier of an ``EnergyTable``.

    Reads behave like the old per-tier dict (``direct`` / ``scaled``):
    membership is provenance-mask membership (an explicit 0.0 J entry is
    *present* — NNLS legitimately zeroes classes).  Writes go through the
    table's vectors and bump its version so resolved energy vectors stay
    coherent.
    """

    __slots__ = ("_table", "_tier")

    def __init__(self, table: "EnergyTable", tier: str):
        self._table = table
        self._tier = tier

    def _arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        t = self._table
        return ((t._e_direct, t._m_direct) if self._tier == DIRECT
                else (t._e_scaled, t._m_scaled))

    # -- reads --------------------------------------------------------------
    def __getitem__(self, cls: str) -> float:
        e, m = self._arrays()
        i = isa.CLASS_INDEX.id(cls)
        if i is None or i >= m.size or not m[i]:
            raise KeyError(cls)
        return float(e[i])

    def get(self, cls: str, default=None):
        e, m = self._arrays()
        i = isa.CLASS_INDEX.id(cls)
        if i is None or i >= m.size or not m[i]:
            return default
        return float(e[i])

    def __contains__(self, cls) -> bool:
        _, m = self._arrays()
        i = isa.CLASS_INDEX.id(cls)
        return i is not None and i < m.size and bool(m[i])

    def __iter__(self) -> Iterator[str]:
        _, m = self._arrays()
        name = isa.CLASS_INDEX.name
        return iter([name(int(i)) for i in np.nonzero(m)[0]])

    def __len__(self) -> int:
        _, m = self._arrays()
        return int(np.count_nonzero(m))

    def items(self) -> List[Tuple[str, float]]:
        e, m = self._arrays()
        name = isa.CLASS_INDEX.name
        return [(name(int(i)), float(e[i])) for i in np.nonzero(m)[0]]

    def keys(self):
        return list(self)

    def values(self):
        e, m = self._arrays()
        return [float(e[i]) for i in np.nonzero(m)[0]]

    def as_dict(self) -> Dict[str, float]:
        return dict(self.items())

    def __eq__(self, other) -> bool:
        if isinstance(other, ClassVecView):
            return dict(self.items()) == dict(other.items())
        if isinstance(other, Mapping):
            return dict(self.items()) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:
        return f"ClassVecView({self._tier}, {dict(self.items())!r})"

    # -- writes (write-through to the vectors) ------------------------------
    def __setitem__(self, cls: str, value: float) -> None:
        self._table.set_energy(cls, float(value), self._tier)

    def __delitem__(self, cls: str) -> None:
        _, m = self._arrays()
        i = isa.CLASS_INDEX.id(cls)
        if i is None or i >= m.size or not m[i]:
            raise KeyError(cls)
        m[i] = False
        self._table._bump()

    def update(self, other: Mapping[str, float]) -> None:
        for cls, e in other.items():
            self[cls] = e

    def pop(self, cls: str, *default):
        try:
            v = self[cls]
        except KeyError:
            if default:
                return default[0]
            raise
        del self[cls]
        return v

    def setdefault(self, cls: str, default: float = 0.0) -> float:
        v = self.get(cls)
        if v is None:
            self[cls] = default
            return default
        return v

    def clear(self) -> None:
        _, m = self._arrays()
        m[:] = False
        self._table._bump()


class _BucketMeans(dict):
    """Per-bucket mean energies; mutation bumps the owning table's version."""

    __slots__ = ("_table",)

    def __init__(self, table: "EnergyTable", *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._table = table

    def _touch(self):
        self._table._bump()

    def __setitem__(self, k, v):
        super().__setitem__(k, v)
        self._touch()

    def __delitem__(self, k):
        super().__delitem__(k)
        self._touch()

    def update(self, *args, **kwargs):
        super().update(*args, **kwargs)
        self._touch()

    def pop(self, *args):
        v = super().pop(*args)
        self._touch()
        return v

    def setdefault(self, k, default=None):
        v = super().setdefault(k, default)
        self._touch()
        return v

    def popitem(self):
        v = super().popitem()
        self._touch()
        return v

    def __ior__(self, other):
        super().update(other)
        self._touch()
        return self

    def clear(self):
        super().clear()
        self._touch()


class EnergyTable:
    """Output of the training phase: powers + per-class energies.

    Array-backed over ``isa.CLASS_INDEX``; ``direct``/``scaled`` are
    write-through dict views, ``energy_vectors`` the resolved dense form.
    """

    def __init__(self, system: str, p_const: float, p_static: float,
                 direct: Optional[Mapping[str, float]] = None,
                 scaled: Optional[Mapping[str, float]] = None,
                 bucket_means: Optional[Mapping[str, float]] = None,
                 meta: Optional[Mapping[str, float]] = None,
                 provenance: Optional[Mapping[str, Any]] = None,
                 operating_points: Optional[List[Mapping[str, Any]]] = None):
        self.system = system
        self.p_const = float(p_const)
        self.p_static = float(p_static)
        n = len(isa.CLASS_INDEX)
        self._e_direct = np.zeros(n)
        self._m_direct = np.zeros(n, dtype=bool)
        self._e_scaled = np.zeros(n)
        self._m_scaled = np.zeros(n, dtype=bool)
        self._bucket_means = _BucketMeans(
            self, {str(b): float(v) for b, v in (bucket_means or {}).items()})
        self.meta: Dict[str, float] = dict(meta or {})
        self.provenance: Dict[str, Any] = dict(provenance or {})
        self._version = 0
        self._vec_cache: Optional[Tuple[int, np.ndarray, np.ndarray]] = None
        self._points: Dict[Tuple[float, float], "EnergyTable"] = {}
        self._op_cache: Dict[Any, Tuple[Any, Any]] = {}
        for cls, e in (direct or {}).items():
            self.set_energy(cls, float(e), DIRECT)
        for cls, e in (scaled or {}).items():
            self.set_energy(cls, float(e), SCALED)
        for entry in (operating_points or []):
            e = dict(entry)
            f = float(e.pop("freq_mhz"))
            c = float(e.pop("power_cap_w"))
            self.add_operating_point(
                f, c, EnergyTable(system=self.system, **e))

    # -- vector plumbing ----------------------------------------------------
    def _bump(self) -> None:
        self._version += 1
        self._vec_cache = None

    def _ensure(self, n: int) -> None:
        if self._e_direct.size < n:
            grow = max(n, len(isa.CLASS_INDEX))
            for attr in ("_e_direct", "_e_scaled"):
                v = np.zeros(grow)
                v[:getattr(self, attr).size] = getattr(self, attr)
                setattr(self, attr, v)
            for attr in ("_m_direct", "_m_scaled"):
                m = np.zeros(grow, dtype=bool)
                m[:getattr(self, attr).size] = getattr(self, attr)
                setattr(self, attr, m)

    def set_energy(self, cls: str, energy: float, tier: str = DIRECT) -> None:
        """Set one class energy in a tier (the supported write path)."""
        i = isa.CLASS_INDEX.intern(cls)
        self._ensure(i + 1)
        if tier == DIRECT:
            self._e_direct[i] = energy
            self._m_direct[i] = True
        elif tier == SCALED:
            self._e_scaled[i] = energy
            self._m_scaled[i] = True
        else:
            raise ValueError(f"unknown tier {tier!r} (expected direct/scaled)")
        self._bump()

    def invalidate_cache(self) -> None:
        """Drop resolved vectors (call after out-of-band mutation)."""
        self._bump()

    @property
    def version(self) -> int:
        """Monotonic mutation counter; resolved vectors key on it."""
        return self._version

    def _bucket_vec(self) -> np.ndarray:
        v = np.zeros(len(isa.BUCKET_ORDER))
        for b, e in self._bucket_means.items():
            code = isa.BUCKET_CODE.get(b)
            if code is not None:
                v[code] = e
        return v

    def energy_vectors(self, n: Optional[int] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """``(e_direct, e_pred)`` resolved over the first ``n`` class ids.

        ``e_direct`` is Wattchmen-Direct (table hits only, 0 J elsewhere);
        ``e_pred`` is Wattchmen-Pred (direct -> scaled -> bucket-mean, §3.4).
        Cached per table version; extended as the class index grows.
        """
        want = len(isa.CLASS_INDEX) if n is None else int(n)
        cache = self._vec_cache
        if cache is not None and cache[0] == self._version \
                and cache[1].size >= want:
            return cache[1][:want], cache[2][:want]
        self._ensure(want)
        ed, md = self._e_direct[:want], self._m_direct[:want]
        es, ms = self._e_scaled[:want], self._m_scaled[:want]
        codes = isa.CLASS_INDEX.bucket_codes(want)
        e_pred = np.where(md, ed, np.where(ms, es, self._bucket_vec()[codes]))
        e_direct = np.where(md, ed, 0.0)
        self._vec_cache = (self._version, e_direct, e_pred)
        return e_direct, e_pred

    def known_energies(self, n: Optional[int] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """``(values, mask)`` of measured-or-scaled energies per class id.

        The pre-bucketing tiers only (direct wins over scaled on overlap) —
        what the coverage machinery averages into bucket means.
        """
        want = len(isa.CLASS_INDEX) if n is None else int(n)
        self._ensure(want)
        md, ms = self._m_direct[:want], self._m_scaled[:want]
        values = np.where(md, self._e_direct[:want],
                          np.where(ms, self._e_scaled[:want], 0.0))
        return values, md | ms

    # -- frequency family (schema v3) ---------------------------------------
    @property
    def points(self) -> Dict[Tuple[float, float], "EnergyTable"]:
        """Extra calibrated operating points: ``(freq_mhz, cap_w) -> table``.

        The top-level table itself is the *anchor* point (its frequency and
        cap, when known, live in ``meta['freq_mhz']``/``meta['power_cap_w']``).
        """
        return self._points

    def has_family(self) -> bool:
        return bool(self._points)

    def anchor_point(self) -> Optional[Tuple[float, float]]:
        """``(freq_mhz, power_cap_w)`` the anchor was calibrated at, or
        ``None`` for pre-v3 tables that never recorded it."""
        f = self.meta.get("freq_mhz")
        if f is None:
            return None
        return (float(f), float(self.meta.get("power_cap_w", 0.0)))

    def add_operating_point(self, freq_mhz: float, power_cap_w: float,
                            table: "EnergyTable") -> None:
        """Attach a per-point calibration to the family."""
        if table._points:
            raise ValueError("operating-point sub-tables cannot nest "
                             "families of their own")
        self._points[(float(freq_mhz), float(power_cap_w))] = table
        self._op_cache.clear()
        self._bump()

    def family(self) -> List[Tuple[Optional[float], Optional[float],
                                   "EnergyTable"]]:
        """All calibrated points incl. the anchor: ``(freq, cap, table)``,
        sorted by frequency (anchor first when its point is unknown)."""
        f, c = (self.anchor_point() or (None, None))
        out: List[Tuple[Optional[float], Optional[float], "EnergyTable"]] = \
            [(f, c, self)]
        for (pf, pc), t in self._points.items():
            out.append((pf, pc, t))
        out.sort(key=lambda e: (0 if e[0] is None else 1,
                                0.0 if e[0] is None else e[0]))
        return out

    def at(self, freq_mhz: float, power_cap_w: Optional[float] = None):
        """Resolve the family at an operating point (``dvfs.interp``).

        Exact at calibrated anchors — returns that point's own vectors, so
        predictions there are bitwise-identical to the per-point table.
        Results are cached and invalidated when any family member mutates.
        """
        from repro.dvfs.interp import resolve
        key = (float(freq_mhz),
               None if power_cap_w is None else float(power_cap_w))
        stamp = (self._version,
                 tuple(t._version for _, t in sorted(self._points.items())))
        hit = self._op_cache.get(key)
        if hit is not None and hit[0] == stamp:
            return hit[1]
        rp = resolve(self, key[0], key[1])
        self._op_cache[key] = (stamp, rp)
        return rp

    def copy(self) -> "EnergyTable":
        """Deep, independent copy (family included) — the backing store for
        ``EnergyModel.fork()`` so in-place drift repair stays local."""
        d = self.to_dict()
        d.pop("schema", None)
        return EnergyTable.from_dict(d, origin=f"<copy:{self.system}>")

    # -- dict-compatible surface --------------------------------------------
    @property
    def direct(self) -> ClassVecView:
        return ClassVecView(self, DIRECT)

    @direct.setter
    def direct(self, value: Mapping[str, float]) -> None:
        self._m_direct[:] = False
        for cls, e in value.items():
            self.set_energy(cls, float(e), DIRECT)
        self._bump()

    @property
    def scaled(self) -> ClassVecView:
        return ClassVecView(self, SCALED)

    @scaled.setter
    def scaled(self, value: Mapping[str, float]) -> None:
        self._m_scaled[:] = False
        for cls, e in value.items():
            self.set_energy(cls, float(e), SCALED)
        self._bump()

    @property
    def bucket_means(self) -> _BucketMeans:
        return self._bucket_means

    @bucket_means.setter
    def bucket_means(self, value: Mapping[str, float]) -> None:
        self._bucket_means = _BucketMeans(
            self, {str(b): float(v) for b, v in value.items()})
        self._bump()

    # ------------------------------------------------------------------
    def lookup(self, cls: str, mode: str = "pred") -> Tuple[float, str]:
        """Energy for a class.

        ``direct`` mode = Wattchmen-Direct (table hits only);
        ``pred`` mode = Wattchmen-Pred (direct -> scaled -> bucket, §3.4).
        """
        i = isa.CLASS_INDEX.id(cls)
        if i is not None and i < self._m_direct.size and self._m_direct[i]:
            return float(self._e_direct[i]), DIRECT
        if mode == "direct":
            return 0.0, MISS
        if i is not None and i < self._m_scaled.size and self._m_scaled[i]:
            return float(self._e_scaled[i]), SCALED
        bucket = isa.bucket_of(cls)
        if bucket is not None and bucket in self._bucket_means:
            return self._bucket_means[bucket], BUCKET
        return 0.0, MISS

    # ------------------------------------------------------------------
    @property
    def isa_gen(self) -> int:
        return int(self.meta.get("isa_gen", 0))

    def __eq__(self, other) -> bool:
        """Physical-artifact equality: powers, energies, meta.

        ``provenance`` (calibration lineage — resume counts, donor, stage
        notes) deliberately does not participate: a resumed calibration
        must compare equal to the uninterrupted run that measured the same
        records.
        """
        if not isinstance(other, EnergyTable):
            return NotImplemented
        return (self.system == other.system
                and self.p_const == other.p_const
                and self.p_static == other.p_static
                and dict(self.direct.items()) == dict(other.direct.items())
                and dict(self.scaled.items()) == dict(other.scaled.items())
                and dict(self._bucket_means) == dict(other._bucket_means)
                and self.meta == other.meta
                and self._points == other._points)

    def __repr__(self) -> str:
        return (f"EnergyTable(system={self.system!r}, "
                f"direct={len(self.direct)}, scaled={len(self.scaled)}, "
                f"p_const={self.p_const:.1f}W, p_static={self.p_static:.1f}W)")

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": SCHEMA_VERSION,
            "system": self.system,
            "p_const": self.p_const,
            "p_static": self.p_static,
            "direct": dict(self.direct.items()),
            "scaled": dict(self.scaled.items()),
            "bucket_means": dict(self._bucket_means),
            "meta": dict(self.meta),
            "provenance": dict(self.provenance),
            "operating_points": [
                {"freq_mhz": f, "power_cap_w": c,
                 **{k: v for k, v in t.to_dict().items()
                    if k in _POINT_FIELDS}}
                for (f, c), t in sorted(self._points.items())
            ],
        }

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        payload = self.to_dict()
        payload["checksum"] = payload_checksum(payload)
        write_json_atomic(p, payload)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any],
                  origin: str = "<dict>") -> "EnergyTable":
        """Construct from an already schema-checked v3 payload."""
        unknown = sorted(set(d) - set(_KNOWN_FIELDS))
        if unknown:
            raise TableSchemaError(
                f"{origin}: unknown table fields {unknown} (known: "
                f"{sorted(_KNOWN_FIELDS)})")
        missing = sorted(k for k in _REQUIRED_FIELDS if k not in d)
        if missing:
            raise TableSchemaError(f"{origin}: missing required fields "
                                   f"{missing}")
        return cls(**d)

    @classmethod
    def load(cls, path) -> "EnergyTable":
        d = json.loads(pathlib.Path(path).read_text())
        if not isinstance(d, dict):
            raise TableSchemaError(f"{path}: expected a JSON object, "
                                   f"got {type(d).__name__}")
        # verified *after* the structural checks, so a hand-edited file
        # still gets the specific schema/field error it deserves; the
        # digest then catches value-level corruption those checks can't
        checksum = d.pop("checksum", None)   # absent in pre-checksum files
        digest = payload_checksum(d) if checksum is not None else None
        version = d.pop("schema", None)
        if version != SCHEMA_VERSION:
            raise TableSchemaError(
                f"{path}: schema version {version!r} does not match "
                f"current version {SCHEMA_VERSION} — retrain or migrate "
                f"the table (TableStore migrates v1/v2 files automatically)")
        table = cls.from_dict(d, origin=str(path))
        if checksum is not None and checksum != digest:
            raise TableSchemaError(
                f"{path}: checksum mismatch — the file is corrupt (torn "
                f"write, bit rot, or a hand edit without restamping)")
        return table
