"""Persistent on-disk store for trained ``EnergyTable`` artifacts.

The paper's table is the reusable artifact: trained once per system
(~76 steady-state microbenchmarks, minutes of device time), then applied to
any workload.  ``trainer.cached_table``'s ``lru_cache`` only survived one
process; the store keeps JSON tables on disk — keyed by system, hardware
ISA generation and the serialized-schema version — so a table trained on a
profiling host can be shipped to (or mounted by) every node of a serving
fleet and loaded in milliseconds instead of retrained.

Layout: one JSON file per key under the store root, e.g.

    sim-v5e-air__gen0__v3.json

plus one *run directory* per key under ``<root>/runs/`` holding the
incremental measurement records of an in-flight calibration
(``core.calibrate``), so an interrupted training campaign resumes from the
completed records instead of re-running minutes of steady-state benchmarks.

The root defaults to ``$REPRO_TABLE_STORE`` or ``~/.cache/repro/tables``.
Schema validation happens in ``EnergyTable.load``; files with a stale or
alien schema are reported (and treated as misses by ``get``), never
silently deserialized — except older v1/v2 files, which carry the same
class-name payload the current table is built from and are migrated in
place at load time (``migrate_table_dict``).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import warnings
from typing import Any, Callable, Dict, List, Optional

from repro.core.table import (SCHEMA_VERSION, EnergyTable, TableSchemaError,
                              payload_checksum, write_json_atomic)

_ENV_ROOT = "REPRO_TABLE_STORE"
_KEY_RE = re.compile(r"^(?P<system>.+)__gen(?P<gen>\d+)__v(?P<ver>\d+)$")


def quarantine_file(path) -> Optional[pathlib.Path]:
    """Move a corrupt artifact aside (``<name>.corrupt[-N]``), never delete.

    The bad bytes stay on disk as evidence while the original path frees
    up for a fresh publish; returns the quarantine path (None if the move
    itself failed — e.g. a concurrent reader already moved it).
    """
    p = pathlib.Path(path)
    dst = p.with_name(p.name + ".corrupt")
    n = 0
    while dst.exists():
        n += 1
        dst = p.with_name(f"{p.name}.corrupt-{n}")
    try:
        os.replace(p, dst)
    except OSError:
        return None
    return dst


# ---------------------------------------------------------------------------
# Schema migration.  v1 (pre array-backed table) serialized the same
# name-keyed payload v2 reads; v2 added the required ``provenance`` record;
# v3 added the optional ``operating_points`` frequency family — a v2 table
# is a v3 table with an empty family (a one-point family at its unrecorded
# nominal anchor), so the payload migrates without touching the energies
# and predicts bitwise-identically.
# ---------------------------------------------------------------------------
def migrate_table_dict(d: Dict[str, Any]) -> Dict[str, Any]:
    """Migrate a raw serialized-table payload to the current schema.

    Returns a new dict with ``schema == SCHEMA_VERSION``; raises
    ``TableSchemaError`` for versions with no migration path.
    """
    version = d.get("schema")
    if version == SCHEMA_VERSION:
        return dict(d)
    if version in (1, 2):
        out = dict(d)
        out["schema"] = SCHEMA_VERSION
        out.setdefault("operating_points", [])
        prov = dict(out.get("provenance") or {})
        prov["migrated_from_schema"] = version
        out["provenance"] = prov
        return out
    raise TableSchemaError(
        f"no migration path from schema version {version!r} to "
        f"{SCHEMA_VERSION}")


def default_root() -> pathlib.Path:
    env = os.environ.get(_ENV_ROOT)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "tables"


def _system_isa_gen(system: str) -> Optional[int]:
    """ISA generation for a registered system (None when unknown)."""
    from repro.hw.systems import SYSTEMS
    cfg = SYSTEMS.get(system)
    return None if cfg is None else int(cfg.chip.isa_gen)


class TableStore:
    """Directory of trained energy tables, keyed system+isa_gen+schema."""

    def __init__(self, root: Optional[os.PathLike] = None):
        self.root = pathlib.Path(root) if root is not None else default_root()

    # -- keys ---------------------------------------------------------------
    def key_for(self, system: str, isa_gen: Optional[int] = None) -> str:
        if isa_gen is None:
            isa_gen = _system_isa_gen(system)
        if isa_gen is None:
            raise KeyError(
                f"unknown system {system!r}: pass isa_gen= explicitly for "
                f"systems outside repro.hw.systems.SYSTEMS")
        return f"{system}__gen{int(isa_gen)}__v{SCHEMA_VERSION}"

    def path_for(self, system: str, isa_gen: Optional[int] = None) -> pathlib.Path:
        return self.root / (self.key_for(system, isa_gen) + ".json")

    def run_dir(self, system: str,
                isa_gen: Optional[int] = None) -> pathlib.Path:
        """Per-key directory for incremental calibration records."""
        return self.root / "runs" / self.key_for(system, isa_gen)

    # -- read ---------------------------------------------------------------
    def _migrate_older(self, system: str,
                       isa_gen: Optional[int]) -> Optional[EnergyTable]:
        """Load + upgrade an older-schema file for this key, if one exists.

        The migrated table is published back under the current-version path
        (atomic), so the next reader — this process or a fleet node sharing
        the store — loads the current schema directly.
        """
        key = self.key_for(system, isa_gen)
        stem = key.rsplit("__v", 1)[0]
        for old in range(SCHEMA_VERSION - 1, 0, -1):
            path = self.root / f"{stem}__v{old}.json"
            if not path.exists():
                continue
            try:
                d = json.loads(path.read_text())
                if not isinstance(d, dict):
                    raise TableSchemaError(f"{path}: not a JSON object")
                table = EnergyTable.from_dict(
                    {k: v for k, v in migrate_table_dict(d).items()
                     if k != "schema"}, origin=str(path))
            except (TableSchemaError, ValueError) as e:
                moved = quarantine_file(path)
                warnings.warn(f"quarantined unmigratable energy table "
                              f"{path} -> {moved}: {e}",
                              RuntimeWarning, stacklevel=3)
                return None
            self.put(table)
            return table
        return None

    def get(self, system: str, isa_gen: Optional[int] = None) -> Optional[EnergyTable]:
        """Load a table, or None on miss / stale schema (warned, not raised).

        Older-schema files for the same system+gen are migrated in place
        (a migration is milliseconds; the retrain it avoids is minutes).
        """
        path = self.path_for(system, isa_gen)
        if not path.exists():
            return self._migrate_older(system, isa_gen)
        try:
            return EnergyTable.load(path)
        except (TableSchemaError, ValueError) as e:
            # a miss triggers a minutes-long retrain — never do that
            # silently, and never leave the bad bytes squatting on the
            # publish path (the retrain's put() needs it free)
            moved = quarantine_file(path)
            warnings.warn(f"quarantined unreadable energy table {path} -> "
                          f"{moved}: {e}", RuntimeWarning, stacklevel=2)
            return None

    def get_or_train(self, system: str,
                     train: Optional[Callable[[str], EnergyTable]] = None,
                     ) -> EnergyTable:
        """Store-through training: load on hit, train + persist on miss.

        The default trainer is the staged calibration pipeline with its
        run directory under this store — an interrupted training campaign
        resumes from the completed measurement records on the next call.
        """
        table = self.get(system)
        if table is not None:
            return table
        if train is None:
            from repro.core.calibrate import calibrate

            def train(s: str) -> EnergyTable:
                # unattended path: records from an obsolete plan are
                # discarded (warned), never allowed to wedge the load
                return calibrate(s, run_dir=self.run_dir(s), resume=True,
                                 on_plan_mismatch="discard")
        table = train(system)
        self.put(table)
        return table

    # -- write --------------------------------------------------------------
    def put(self, table: EnergyTable) -> pathlib.Path:
        path = self.path_for(table.system, table.isa_gen)
        self.root.mkdir(parents=True, exist_ok=True)
        # EnergyTable.save is tmp + fsync + atomic rename (and stamps the
        # content checksum), so a fleet node reading concurrently — or
        # after a mid-write crash — never sees a half-written table
        table.save(path)
        return path

    # -- kernel energy tier -------------------------------------------------
    def kernel_table_path(self, system: str) -> pathlib.Path:
        """Second-tier artifact: measured J/op per kernel launch config.

        The ``__kernels__`` stem cannot match ``_KEY_RE`` (no ``__gen<n>``
        segment), so ``keys()``/``entries()`` never confuse the two tiers.
        """
        from repro.core.kernel_table import KERNEL_SCHEMA_VERSION
        return self.root / f"{system}__kernels__v{KERNEL_SCHEMA_VERSION}.json"

    def get_kernel_table(self, system: str):
        """Load the system's ``KernelEnergyTable``, or None on miss/stale."""
        from repro.core.kernel_table import KernelEnergyTable, KernelTableError
        path = self.kernel_table_path(system)
        if not path.exists():
            return None
        try:
            d = json.loads(path.read_text())
            if not isinstance(d, dict):
                raise KernelTableError(f"{path}: not a JSON object")
            checksum = d.pop("checksum", None)
            if checksum is not None and checksum != payload_checksum(d):
                raise KernelTableError(f"{path}: checksum mismatch — the "
                                       f"file is corrupt")
            return KernelEnergyTable.from_dict(d)
        except (KernelTableError, ValueError, KeyError, TypeError) as e:
            moved = quarantine_file(path)
            warnings.warn(f"quarantined unreadable kernel energy table "
                          f"{path} -> {moved}: {e}",
                          RuntimeWarning, stacklevel=2)
            return None

    def put_kernel_table(self, ktable) -> pathlib.Path:
        """Checksummed crash-safe publish, same discipline as ``put``."""
        path = self.kernel_table_path(ktable.system)
        self.root.mkdir(parents=True, exist_ok=True)
        payload = ktable.to_dict()
        payload["checksum"] = payload_checksum(payload)
        write_json_atomic(path, payload)
        return path

    def evict(self, system: str, isa_gen: Optional[int] = None) -> bool:
        path = self.path_for(system, isa_gen)
        if path.exists():
            path.unlink()
            return True
        return False

    # -- inspection ---------------------------------------------------------
    def keys(self) -> List[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.json")
                      if _KEY_RE.match(p.stem))

    def entries(self) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for key in self.keys():
            m = _KEY_RE.match(key)
            assert m is not None
            out[key] = {"isa_gen": int(m.group("gen")),
                        "schema": int(m.group("ver"))}
        return out


_DEFAULT_STORE: Optional[TableStore] = None


def default_store() -> TableStore:
    """Process-wide store rooted at the default (env-overridable) location."""
    global _DEFAULT_STORE
    if _DEFAULT_STORE is None or _DEFAULT_STORE.root != default_root():
        _DEFAULT_STORE = TableStore()
    return _DEFAULT_STORE
