"""Persistent on-disk store for trained ``EnergyTable`` artifacts.

The paper's table is the reusable artifact: trained once per system
(~76 steady-state microbenchmarks, minutes of device time), then applied to
any workload.  ``trainer.cached_table``'s ``lru_cache`` only survived one
process; the store keeps JSON tables on disk — keyed by system, hardware
ISA generation and the serialized-schema version — so a table trained on a
profiling host can be shipped to (or mounted by) every node of a serving
fleet and loaded in milliseconds instead of retrained.

Layout: one JSON file per key under the store root, e.g.

    sim-v5e-air__gen0__v1.json

The root defaults to ``$REPRO_TABLE_STORE`` or ``~/.cache/repro/tables``.
Schema validation happens in ``EnergyTable.load``; files with a stale or
alien schema are reported (and treated as misses by ``get``), never
silently deserialized.
"""
from __future__ import annotations

import os
import pathlib
import re
import tempfile
import warnings
from typing import Callable, Dict, List, Optional

from repro.core.table import SCHEMA_VERSION, EnergyTable, TableSchemaError

_ENV_ROOT = "REPRO_TABLE_STORE"
_KEY_RE = re.compile(r"^(?P<system>.+)__gen(?P<gen>\d+)__v(?P<ver>\d+)$")


def default_root() -> pathlib.Path:
    env = os.environ.get(_ENV_ROOT)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "tables"


def _system_isa_gen(system: str) -> Optional[int]:
    """ISA generation for a registered system (None when unknown)."""
    from repro.hw.systems import SYSTEMS
    cfg = SYSTEMS.get(system)
    return None if cfg is None else int(cfg.chip.isa_gen)


class TableStore:
    """Directory of trained energy tables, keyed system+isa_gen+schema."""

    def __init__(self, root: Optional[os.PathLike] = None):
        self.root = pathlib.Path(root) if root is not None else default_root()

    # -- keys ---------------------------------------------------------------
    def key_for(self, system: str, isa_gen: Optional[int] = None) -> str:
        if isa_gen is None:
            isa_gen = _system_isa_gen(system)
        if isa_gen is None:
            raise KeyError(
                f"unknown system {system!r}: pass isa_gen= explicitly for "
                f"systems outside repro.hw.systems.SYSTEMS")
        return f"{system}__gen{int(isa_gen)}__v{SCHEMA_VERSION}"

    def path_for(self, system: str, isa_gen: Optional[int] = None) -> pathlib.Path:
        return self.root / (self.key_for(system, isa_gen) + ".json")

    # -- read ---------------------------------------------------------------
    def get(self, system: str, isa_gen: Optional[int] = None) -> Optional[EnergyTable]:
        """Load a table, or None on miss / stale schema (warned, not raised)."""
        path = self.path_for(system, isa_gen)
        if not path.exists():
            return None
        try:
            return EnergyTable.load(path)
        except (TableSchemaError, ValueError) as e:
            # a miss triggers a minutes-long retrain — never do that silently
            warnings.warn(f"ignoring unreadable energy table {path}: {e}",
                          RuntimeWarning, stacklevel=2)
            return None

    def get_or_train(self, system: str,
                     train: Optional[Callable[[str], EnergyTable]] = None,
                     ) -> EnergyTable:
        """Store-through training: load on hit, train + persist on miss."""
        table = self.get(system)
        if table is not None:
            return table
        if train is None:
            from repro.core.trainer import train_table
            train = train_table
        table = train(system)
        self.put(table)
        return table

    # -- write --------------------------------------------------------------
    def put(self, table: EnergyTable) -> pathlib.Path:
        path = self.path_for(table.system, table.isa_gen)
        self.root.mkdir(parents=True, exist_ok=True)
        # atomic publish: a fleet node reading concurrently never sees a
        # half-written table
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        os.close(fd)
        try:
            table.save(tmp)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    def evict(self, system: str, isa_gen: Optional[int] = None) -> bool:
        path = self.path_for(system, isa_gen)
        if path.exists():
            path.unlink()
            return True
        return False

    # -- inspection ---------------------------------------------------------
    def keys(self) -> List[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.json")
                      if _KEY_RE.match(p.stem))

    def entries(self) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for key in self.keys():
            m = _KEY_RE.match(key)
            assert m is not None
            out[key] = {"isa_gen": int(m.group("gen")),
                        "schema": int(m.group("ver"))}
        return out


_DEFAULT_STORE: Optional[TableStore] = None


def default_store() -> TableStore:
    """Process-wide store rooted at the default (env-overridable) location."""
    global _DEFAULT_STORE
    if _DEFAULT_STORE is None or _DEFAULT_STORE.root != default_root():
        _DEFAULT_STORE = TableStore()
    return _DEFAULT_STORE
