"""Microbenchmark suite — the paper's §3.2 for the TPU op-class ISA.

Each microbenchmark is a real JAX program: an unrolled ``lax.scan`` loop whose
body is dominated by the *target* op class, with whatever ancillary ops the
construction forces (loop bookkeeping, broadcasts, converts, reductions…).
Exactly as in the paper, ancillary ops are not a bug: the ops that are
ancillary here are the primary ops of another benchmark, and the square
system of equations (§3.1) attributes every contribution.

Benchmarks are only *traced* (``jax.make_jaxpr`` over ShapeDtypeStructs) to
obtain their per-iteration op counts; the simulated device then "runs" them
for the steady-state duration (§3.3).  On real hardware the same functions
would be jitted and executed — nothing in their construction is
simulation-specific.

Collective benchmarks are specified analytically (wire bytes per chip) since
they describe the per-chip program of a shard_map over a pod slice; the
equivalent shard_map programs are in ``repro.parallel``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counting import OpCounts
from repro.core.opcount import count_fn

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32
I8 = jnp.int8


@dataclasses.dataclass
class MicroBench:
    """One microbenchmark: a name, its target class, per-iteration counts."""

    name: str
    target: str                  # primary op class this bench introduces
    counts: OpCounts             # per program-iteration (one scan execution)
    is_nanosleep: bool = False


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _looped(body: Callable, length: int = 64, unroll: int = 16):
    """scan(length) whose body applies ``body`` ``unroll`` times."""
    def fn(c0, *extra):
        def step(c, _):
            for _ in range(unroll):
                c = body(c, *extra)
            return c, ()
        c, _ = jax.lax.scan(step, c0, None, length=length)
        return c
    return fn


# ---------------------------------------------------------------------------
# Builders.  Each returns (fn, args) to be traced.
# ---------------------------------------------------------------------------
_REGISTRY: List[Tuple[str, str, Callable[[], Tuple[Callable, tuple]]]] = []


def _bench(name: str, target: str):
    def deco(builder):
        _REGISTRY.append((name, target, builder))
        return builder
    return deco


def _unbenched(name: str, target: str):
    """Classes deliberately left without a direct microbenchmark.

    The paper's premise (§3.4): "given the significant number of GPU
    instructions ... it is difficult to measure all of them".  These classes
    exercise the coverage machinery — Wattchmen-Pred recovers them via
    bucketing; Wattchmen-Direct attributes zero (its V100 19% vs Pred 14%).
    """
    def deco(builder):
        return builder
    return deco


# ---- MXU -------------------------------------------------------------------
@_bench("MXU_DOT_BF16_bench", "dot.bf16")
def _b_dot_bf16():
    fn = _looped(lambda c, w: jnp.dot(c, w), length=16, unroll=4)
    return fn, (_sds((1024, 1024), BF16), _sds((1024, 1024), BF16))


@_bench("MXU_DOT_F32_bench", "dot.f32")
def _b_dot_f32():
    fn = _looped(lambda c, w: jnp.dot(c, w), length=16, unroll=4)
    return fn, (_sds((512, 512), F32), _sds((512, 512), F32))


@_bench("MXU_DOT_INT8_bench", "dot.int8")
def _b_dot_int8():
    def body(c, w):
        acc = jax.lax.dot_general(c, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return (acc >> 7).astype(jnp.int8)
    fn = _looped(body, length=16, unroll=4)
    return fn, (_sds((1024, 1024), I8), _sds((1024, 1024), I8))


def _conv_body(c, k):
    return jax.lax.conv_general_dilated(
        c, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@_bench("MXU_CONV_BF16_bench", "conv.bf16")
def _b_conv_bf16():
    fn = _looped(_conv_body, length=8, unroll=2)
    return fn, (_sds((8, 64, 64, 32), BF16), _sds((3, 3, 32, 32), BF16))


@_bench("MXU_CONV_F32_bench", "conv.f32")
def _b_conv_f32():
    fn = _looped(_conv_body, length=8, unroll=2)
    return fn, (_sds((8, 64, 64, 32), F32), _sds((3, 3, 32, 32), F32))


# ---- VPU transcendental ------------------------------------------------------
_TRANS = {
    "exp": jnp.exp, "log": jnp.log, "tanh": jnp.tanh,
    "logistic": jax.nn.sigmoid, "rsqrt": jax.lax.rsqrt, "sqrt": jnp.sqrt,
    "erf": jax.lax.erf, "sin": jnp.sin, "cos": jnp.cos,
    "pow": lambda x: jax.lax.pow(x, jnp.asarray(1.73, x.dtype)),
}
for _opname, _opfn in _TRANS.items():
    for _dt, _tag in ((F32, "f32"), (BF16, "bf16")):
        def _mk_trans(opfn=_opfn, dt=_dt):
            fn = _looped(lambda c, opfn=opfn: opfn(c), unroll=32)
            return fn, (_sds((512, 2048), dt),)
        _bench(f"VPU_{_opname.upper()}_{_tag}_bench",
               f"{_opname}.{_tag}")(_mk_trans)

# ---- VPU simple --------------------------------------------------------------
_SIMPLE = {
    "add": lambda c: c + 1.5, "mul": lambda c: c * 1.0001,
    "sub": lambda c: c - 0.25, "div": lambda c: c / 1.0001,
    "max": lambda c: jnp.maximum(c, 0.1), "min": lambda c: jnp.minimum(c, 9.9),
}
for _opname, _opfn in _SIMPLE.items():
    for _dt, _tag in ((F32, "f32"), (BF16, "bf16")):
        def _mk_simple(opfn=_opfn, dt=_dt):
            fn = _looped(lambda c, opfn=opfn: opfn(c), unroll=32)
            return fn, (_sds((512, 2048), dt),)
        _bench(f"VPU_{_opname.upper()}_{_tag}_bench",
               f"{_opname}.{_tag}")(_mk_simple)


for _dt, _tag in ((F32, "f32"), (BF16, "bf16")):
    def _mk_cmp(dt=_dt):
        def body(c, t):
            m = c > t                     # target cmp
            return c + m.astype(c.dtype)  # ancillary convert+add
        fn = _looped(body, unroll=32)
        return fn, (_sds((512, 2048), dt), _sds((512, 2048), dt))
    _bench(f"VPU_CMP_{_tag}_bench", f"cmp.{_tag}")(_mk_cmp)

    def _mk_select(dt=_dt):
        def body(c, m):
            return jnp.where(m, c, c * 0.5)   # select target, mul ancillary
        fn = _looped(body, unroll=32)
        return fn, (_sds((512, 2048), dt), _sds((512, 2048), jnp.bool_))
    _bench(f"VPU_SELECT_{_tag}_bench", f"select.{_tag}")(_mk_select)


@_bench("VPU_REDUCE_ADD_bench", "reduce.add.f32")
def _b_reduce_add():
    def body(c):
        return c - jnp.sum(c, axis=-1, keepdims=True) * 1e-6
    return _looped(body, unroll=8), (_sds((512, 2048), F32),)


@_bench("VPU_REDUCE_MAX_bench", "reduce.max.f32")
def _b_reduce_max():
    def body(c):
        return c - jnp.max(c, axis=-1, keepdims=True) * 1e-6
    return _looped(body, unroll=8), (_sds((512, 2048), F32),)


@_unbenched("VPU_CUMSUM_bench", "cumsum.f32")
def _b_cumsum():
    def body(c):
        return jnp.cumsum(c, axis=-1) * 1e-3
    return _looped(body, unroll=4), (_sds((512, 2048), F32),)


# ---- VPU integer -------------------------------------------------------------
_INT_OPS = {
    "add": lambda c: c + 3, "mul": lambda c: c * 5,
    "and": lambda c: c & 0x7FFF, "or": lambda c: c | 0x11,
    "xor": lambda c: c ^ 0x5A5A, "shift": lambda c: c << 1,
}
for _opname, _opfn in _INT_OPS.items():
    def _mk_int(opfn=_opfn):
        fn = _looped(lambda c, opfn=opfn: opfn(c), unroll=32)
        return fn, (_sds((512, 2048), I32),)
    _bench(f"INT_{_opname.upper()}_bench", f"{_opname}.int")(_mk_int)


@_bench("INT_CMP_bench", "cmp.int")
def _b_cmp_int():
    def body(c):
        m = c > 0
        return c ^ m.astype(I32)
    return _looped(body, unroll=32), (_sds((512, 2048), I32),)


@_bench("INT_SELECT_bench", "select.int")
def _b_select_int():
    def body(c, m):
        return jnp.where(m, c, c + 1)
    return _looped(body, unroll=32), (_sds((512, 2048), I32),
                                      _sds((512, 2048), jnp.bool_))


@_bench("RNG_BITS_bench", "rng.bits")
def _b_rng():
    def fn(c0):
        key = jax.random.key(0)
        def step(c, _):
            bits = jax.random.bits(key, c.shape, jnp.uint32)
            return c ^ bits, ()
        c, _ = jax.lax.scan(step, c0, None, length=64)
        return c
    return fn, (_sds((1024, 2048), jnp.uint32),)


# ---- Converts (F2F case-study family) ---------------------------------------
@_bench("CVT_BF16_F32_bench", "convert.bf16.f32")
def _b_cvt_b2f():
    def body(c, x):
        return c + x.astype(F32)           # bf16->f32 target, add ancillary
    return _looped(body, unroll=32), (_sds((512, 2048), F32),
                                      _sds((512, 2048), BF16))


@_bench("CVT_F32_BF16_bench", "convert.f32.bf16")
def _b_cvt_f2b():
    def body(c):
        h = c.astype(F32)                  # 1 bf16->f32
        acc = c
        for i in range(8):                 # 8 f32->bf16
            acc = acc + (h * (1.0 + i)).astype(BF16)
        return acc
    return _looped(body, unroll=4), (_sds((512, 2048), BF16),)


@_bench("CVT_INT_FLOAT_bench", "convert.int.float")
def _b_cvt_i2f():
    def body(c, ix):
        return c + ix.astype(F32)
    return _looped(body, unroll=32), (_sds((512, 2048), F32),
                                      _sds((512, 2048), I32))


@_bench("CVT_FLOAT_INT_bench", "convert.float.int")
def _b_cvt_f2i():
    def body(c, fx):
        return c + (fx * 2.0).astype(I32)
    return _looped(body, unroll=32), (_sds((512, 2048), I32),
                                      _sds((512, 2048), F32))


# ---- Moves / layout ----------------------------------------------------------
@_bench("MOVE_BCAST_bench", "bcast")
def _b_bcast():
    def body(c, row):
        return c + jnp.broadcast_to(row[None, :], c.shape)
    return _looped(body, unroll=8), (_sds((1024, 2048), F32), _sds((2048,), F32))


@_bench("MOVE_TRANSPOSE_bench", "transpose")
def _b_transpose():
    def body(c):
        return jnp.transpose(c) * 1.0001
    return _looped(body, unroll=8), (_sds((1024, 1024), F32),)


@_bench("MOVE_CONCAT_bench", "concat")
def _b_concat():
    def body(c):
        h = jnp.concatenate([c, c], axis=0)
        return h[:512] + h[512:] * 1e-6
    return _looped(body, unroll=8), (_sds((512, 2048), F32),)


@_bench("MOVE_SLICE_bench", "slice")
def _b_slice():
    def fn(c0, big):
        def step(c, i):
            for j in range(8):
                s = jax.lax.dynamic_slice(big, ((i + j) % 8 * 1024, 0),
                                          (1024, 1024))
                c = c + s
            return c, ()
        c, _ = jax.lax.scan(step, c0, jnp.arange(64, dtype=I32))
        return c
    return fn, (_sds((1024, 1024), F32), _sds((8192, 1024), F32))


@_unbenched("MOVE_DUS_bench", "dus")
def _b_dus():
    def fn(x0, u):
        def step(x, i):
            for _ in range(8):
                x = jax.lax.dynamic_update_slice(x, u, (i % 8 * 1024, 0))
            return x, ()
        x, _ = jax.lax.scan(step, x0, jnp.arange(64, dtype=I32))
        return x
    return fn, (_sds((8192, 1024), F32), _sds((1024, 1024), F32))


@_bench("MOVE_GATHER_bench", "gather")
def _b_gather():
    def body(c, table, idx):
        return c + table[idx]
    return _looped(body, unroll=8), (_sds((1024, 1024), F32),
                                     _sds((16384, 1024), F32),
                                     _sds((1024,), I32))


@_bench("MOVE_SCATTER_bench", "scatter")
def _b_scatter():
    def body(x, u, idx):
        return x.at[idx].add(u)
    return _looped(body, unroll=4), (_sds((16384, 1024), F32),
                                     _sds((1024, 1024), F32),
                                     _sds((1024,), I32))


@_bench("MOVE_IOTA_bench", "iota")
def _b_iota():
    def body(c):
        return c + jax.lax.broadcasted_iota(F32, c.shape, 1)
    return _looped(body, unroll=8), (_sds((1024, 2048), F32),)


@_unbenched("MOVE_PAD_bench", "pad")
def _b_pad():
    def body(c):
        h = jnp.pad(c, ((1, 1), (1, 1)))
        return h[1:-1, 1:-1] * 1.0001
    return _looped(body, unroll=8), (_sds((512, 2048), F32),)


@_unbenched("MOVE_SORT_bench", "sort")
def _b_sort():
    def body(c):
        return jnp.sort(c, axis=-1) * 1.0001
    return _looped(body, unroll=2), (_sds((256, 2048), F32),)


# ---- Memory hierarchy --------------------------------------------------------
@_bench("MEM_HBM_READ_bench", "hbm.read")
def _b_hbm_read():
    def fn(acc0, xs):
        def step(acc, row):
            return acc + jnp.sum(row), ()
        acc, _ = jax.lax.scan(step, acc0, xs)
        return acc
    return fn, (_sds((), F32), _sds((64, 4_000_000), F32))


@_bench("MEM_HBM_WRITE_bench", "hbm.write")
def _b_hbm_write():
    def fn(c0):
        def step(c, _):
            y = jnp.broadcast_to(c[:1] * 1.0001, (4_000_000,))
            return c, y
        c, ys = jax.lax.scan(step, c0, None, length=64)
        return ys
    return fn, (_sds((8,), F32),)


@_bench("MEM_VMEM_READ_bench", "vmem.read")
def _b_vmem_read():
    # bf16 resident reduce: same reduce units as VPU_REDUCE_ADD but half the
    # bytes/elem — the data-width variation that separates byte-priced
    # columns from element-priced columns (paper's multi-width tests, §3.2).
    def body(c):
        return c - jnp.sum(c, axis=-1, keepdims=True).astype(BF16) * 1e-3
    return _looped(body, unroll=8), (_sds((512, 4096), BF16),)


# ---- Control ------------------------------------------------------------------
def _nanosleep_counts(n_iters: int = 1_000_000) -> OpCounts:
    c = OpCounts()
    c.add("ctl.loop", float(n_iters))
    c.exec_count = float(n_iters)
    return c


# ---- Collectives (analytic per-chip programs) ---------------------------------
def _collective_counts(cls: str, wire_bytes: float) -> OpCounts:
    c = OpCounts()
    c.add(cls, wire_bytes)
    # ancillary: buffer traverse + a touch of VPU work (reduce for ar/rs)
    c.add("add.f32", wire_bytes / 8.0)
    c.boundary_read_bytes = wire_bytes * 0.5
    c.boundary_write_bytes = wire_bytes * 0.5
    c.naive_bytes = wire_bytes
    c.max_buffer_bytes = wire_bytes
    c.dispatch_count = 4.0
    c.exec_count = 8.0
    return c


_COLLECTIVE_BENCHES = [
    ("ICI_ALL_REDUCE_bench", "ici.all_reduce", 256e6),
    ("ICI_ALL_GATHER_bench", "ici.all_gather", 256e6),
    ("ICI_REDUCE_SCATTER_bench", "ici.reduce_scatter", 256e6),
    ("ICI_ALL_TO_ALL_bench", "ici.all_to_all", 128e6),
    ("ICI_PERMUTE_bench", "ici.permute", 256e6),
]


# ---------------------------------------------------------------------------
# Suite assembly.
# ---------------------------------------------------------------------------
def build_suite(isa_gen: int = 0) -> List[MicroBench]:
    """Trace every microbenchmark and return the suite.

    ``isa_gen`` makes the *profiler* arch-aware (NSight on H100 reports
    HGMMA); the benchmarks themselves are the fixed, gen-0-designed suite —
    which is exactly why Direct-mode coverage drops on newer hardware.
    """
    suite: List[MicroBench] = []
    for name, target, builder in _REGISTRY:
        fn, args = builder()
        counts = count_fn(fn, *args, isa_gen=isa_gen)
        suite.append(MicroBench(name=name, target=target, counts=counts))
    for name, cls, wire in _COLLECTIVE_BENCHES:
        suite.append(MicroBench(name=name, target=cls,
                                counts=_collective_counts(cls, wire)))
    suite.append(MicroBench(name="CTL_NANOSLEEP_bench", target="ctl.loop",
                            counts=_nanosleep_counts(), is_nanosleep=True))
    return suite


def benched_classes(suite: List[MicroBench]) -> List[str]:
    return [b.target for b in suite]
