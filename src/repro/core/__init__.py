"""Wattchmen core: the paper's contribution as a composable library.

These modules are the *engine*; the public surface is the ``EnergyModel``
facade in ``repro.api`` (train/load/from_store + profile/predict/measure/
compare/attribute/monitor).  Engine map:

Training phase:  ``calibrate.calibrate(system)`` -> ``EnergyTable``
                 (staged + resumable: plan -> measure -> solve -> extend ->
                 publish; ``trainer.train_table`` is the one-shot shim)
Persistence:     ``store.TableStore`` (on-disk, schema-versioned JSON +
                 per-run calibration records)
Prediction:      ``predict.TablePredictor`` (amortized lookups) /
                 ``predict.predict`` (one-shot)
Profiler:        ``opcount.count_fn`` (jaxpr) + ``repro.hlo`` (compiled HLO)
Streaming:       ``fleet.EnergyMonitor``
"""
