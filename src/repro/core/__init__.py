"""Wattchmen core: the paper's contribution as a composable library.

Training phase:  ``trainer.train_table(system)`` -> ``EnergyTable``
Prediction:      ``predict.predict(table, counts, duration, counters)``
Profiler:        ``opcount.count_fn`` (jaxpr) + ``repro.hlo`` (compiled HLO)
"""
