"""Wattchmen core: the paper's contribution as a composable library.

These modules are the *engine*; the public surface is the ``EnergyModel``
facade in ``repro.api`` (train/load/from_store + profile/predict/measure/
compare/attribute/monitor).  Engine map:

Training phase:  ``trainer.train_table(system)`` -> ``EnergyTable``
Persistence:     ``store.TableStore`` (on-disk, schema-versioned JSON)
Prediction:      ``predict.TablePredictor`` (amortized lookups) /
                 ``predict.predict`` (one-shot)
Profiler:        ``opcount.count_fn`` (jaxpr) + ``repro.hlo`` (compiled HLO)
Streaming:       ``fleet.EnergyMonitor``
"""
