"""Prediction & attribution phase — paper §3.5, as matrix algebra.

Inputs per application: profiled op counts (``core.opcount``), execution
time, and memory counters (HBM/VMEM bytes — the cache-hit-rate analogue).
Output: total energy plus a fine-grained breakdown by op class and by
micro-architectural bucket, with const/static separated — the artifact the
case studies (§5.3) consume.

The paper's linear model (Eq. 3, ``E = Σ units_i · energy_i``) is a dot
product over the op-class space, and this module computes it as one: the
``TablePredictor`` resolves the bound table into dense energy vectors over
``isa.CLASS_INDEX``, a single prediction is ``units · e``, and a batch
(``predict_batch``) is one ``C @ e``-style pass over a stacked counts
matrix.  Both paths run the identical kernel, so batched totals are
bitwise-equal to per-program totals.
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import isa
from repro.core.counting import counts_matrix
# the accumulation core defines OpCounts; importing it from there (not the
# jax front-end ``core.opcount``) keeps this module importable in processes
# without jax — telemetry shard workers price windows through it
from repro.core.counting import OpCounts
from repro.core.table import EnergyTable

# How predicted traffic is split when no profiled counters are available
# (pure static prediction from a lowered program).
_DEFAULT_HBM_BOUNDARY_FRAC = 0.85
_DEFAULT_FUSED_LEAK = 0.05


class Prediction:
    """One workload's energy prediction + attribution.

    ``by_class``/``by_bucket`` are materialized lazily from the underlying
    per-class energy vector (``class_energy_vec``), so fleet-scale batched
    prediction never pays for breakdown dicts nobody reads.
    """

    __slots__ = ("total_j", "const_j", "static_j", "dynamic_j", "coverage",
                 "duration_s", "_by_class", "_by_bucket", "_class_vec",
                 "_bucket_vec")

    def __init__(self, total_j: float, const_j: float, static_j: float,
                 dynamic_j: float,
                 by_class: Optional[Mapping[str, float]] = None,
                 by_bucket: Optional[Mapping[str, float]] = None,
                 coverage: float = 1.0, duration_s: float = 0.0, *,
                 class_vec: Optional[np.ndarray] = None,
                 bucket_vec: Optional[np.ndarray] = None):
        self.total_j = float(total_j)
        self.const_j = float(const_j)
        self.static_j = float(static_j)
        self.dynamic_j = float(dynamic_j)
        self.coverage = float(coverage)   # energy-weighted direct fraction
        self.duration_s = float(duration_s)
        self._by_class = dict(by_class) if by_class is not None else None
        self._by_bucket = dict(by_bucket) if by_bucket is not None else None
        self._class_vec = class_vec
        self._bucket_vec = bucket_vec    # dynamic J over isa.BUCKET_ORDER
        if self._class_vec is None and self._by_class is None:
            self._by_class = {}

    # -- breakdowns ---------------------------------------------------------
    @property
    def class_energy_vec(self) -> np.ndarray:
        """Per-class dynamic joules over ``isa.CLASS_INDEX`` ids."""
        if self._class_vec is None:
            items = list((self._by_class or {}).items())
            ids = [isa.CLASS_INDEX.intern(cls) for cls, _ in items]
            v = np.zeros(len(isa.CLASS_INDEX))
            if ids:
                v[ids] = [e for _, e in items]
            self._class_vec = v
        return self._class_vec

    @property
    def by_class(self) -> Dict[str, float]:
        if self._by_class is None:
            v = self._class_vec
            name = isa.CLASS_INDEX.name
            self._by_class = {name(int(i)): float(v[i])
                              for i in np.nonzero(v)[0]}
        return self._by_class

    @property
    def by_bucket(self) -> Dict[str, float]:
        if self._by_bucket is None:
            out: Dict[str, float] = {}
            if self._bucket_vec is not None:
                out = {isa.BUCKET_ORDER[i]: float(s)
                       for i, s in enumerate(self._bucket_vec) if s != 0.0}
            elif self._class_vec is not None:
                v = self._class_vec
                if v.size:
                    codes = isa.CLASS_INDEX.bucket_codes(v.size)
                    sums = np.bincount(codes, weights=v,
                                       minlength=len(isa.BUCKET_ORDER))
                    out = {isa.BUCKET_ORDER[i]: float(s)
                           for i, s in enumerate(sums) if s != 0.0}
            else:
                for cls, e in (self._by_class or {}).items():
                    b = isa.bucket_of(cls) or isa.UNKNOWN_BUCKET
                    out[b] = out.get(b, 0.0) + e
            out["static"] = self.static_j
            out["const"] = self.const_j
            self._by_bucket = out
        return self._by_bucket

    def top_classes(self, k: int = 10):
        return sorted(self.by_class.items(), key=lambda kv: -kv[1])[:k]

    def __repr__(self) -> str:
        return (f"Prediction(total_j={self.total_j:.4g}, "
                f"dynamic_j={self.dynamic_j:.4g}, "
                f"coverage={self.coverage:.3f}, "
                f"duration_s={self.duration_s:.4g})")


def traffic_from_counts(counts: OpCounts) -> Dict[str, float]:
    """Static traffic estimate when no profiled counters exist (dry-run path)."""
    f = _DEFAULT_HBM_BOUNDARY_FRAC
    leak = counts.fused_bytes * _DEFAULT_FUSED_LEAK
    return {
        "hbm_read_bytes": counts.boundary_read_bytes * f + 0.5 * leak,
        "hbm_write_bytes": counts.boundary_write_bytes * f + 0.5 * leak,
        "vmem_read_bytes": counts.boundary_read_bytes * (1 - f),
        "vmem_write_bytes": counts.boundary_write_bytes * (1 - f),
    }


def _is_point_sequence(op) -> bool:
    """True when ``op`` is a per-job sequence of operating points rather
    than one point: a bare ``(freq, cap)`` pair of scalars is one point."""
    if op is None or hasattr(op, "freq_mhz") or isinstance(op, (str, bytes)):
        return False
    if not isinstance(op, Sequence):
        return False
    if len(op) == 2 and all(x is None or isinstance(x, (int, float))
                            for x in op):
        return False
    return True


_COUNTER_TO_CLASS = {
    "hbm_read_bytes": "hbm.read",
    "hbm_write_bytes": "hbm.write",
    "vmem_read_bytes": "vmem.read",
    "vmem_write_bytes": "vmem.write",
}
_COUNTER_CLASSES = frozenset(_COUNTER_TO_CLASS.values())
_COUNTER_ITEMS = tuple(_COUNTER_TO_CLASS.items())
# counter classes are canonical -> their ids are fixed at import time
_COUNTER_IDS = np.asarray([isa.CLASS_INDEX.intern(c)
                           for c in _COUNTER_TO_CLASS.values()])

# below this batch size the XLA dispatch overhead exceeds the whole plain
# computation; the fused predictor silently uses the plain path (bitwise
# the same either way, so the switch is invisible)
_FUSED_MIN_JOBS = 32


def _build_fused_kernel():
    """Jitted fused hot path (lazy: the only jax import in this module).

    One XLA computation produces both elementwise energy products (direct
    and pred vectors share a single pass over the counts matrix) and the
    per-bucket reduction that ``Prediction.by_bucket`` otherwise recomputes
    per row with ``np.bincount``.  Only *elementwise* work runs under XLA
    — an IEEE multiply is the same bits everywhere — while the row
    reductions that define totals stay in numpy, so the fused path is
    bitwise-identical to the plain one.  Runs under ``enable_x64`` (the
    thread-local flag, not the global config) so float64 counts are not
    silently downcast.
    """
    import jax
    from jax.experimental import enable_x64

    @functools.partial(jax.jit, static_argnames=("direct_mode", "n_buckets"))
    def _kernel(c_mat, e_direct, e_pred, codes, mem, ids, *,
                direct_mode, n_buckets):
        # one traversal of the counts matrix feeds both products, the
        # counter-column fold and the bucket reduction; XLA fuses it all
        vd = c_mat * e_direct
        vp = c_mat * e_pred
        val, other = (vd, vp) if direct_mode else (vp, vd)
        e = e_direct if direct_mode else e_pred
        # counter columns folded on device: still exactly one IEEE add per
        # element, the same bits as the plain path's ``val[:, ci] += v``
        vfin = val.at[:, ids].add(mem * e[ids])
        # bucket bincount as a one-hot matmul: (jobs x classes) @
        # (classes x buckets), no transposes materialized
        buckets = vfin @ jax.nn.one_hot(codes, n_buckets, dtype=val.dtype)
        return val, vfin, other, buckets

    def _view(a):
        """Zero-copy numpy view of a CPU jax buffer (copy as last resort)."""
        try:
            return np.from_dlpack(a)
        except Exception:
            return np.asarray(a)

    def _feed(a):
        """Zero-copy numpy -> jax import (device_put copies; dlpack not)."""
        try:
            return jax.dlpack.from_dlpack(a)
        except Exception:
            return a

    feeds: dict = {}

    def _feed_cached(a):
        """Identity-keyed feed cache for call-stable arrays (the energy
        vectors and bucket codes persist across calls until the table is
        invalidated; re-exporting them every call is pure overhead).
        Holding ``a`` in the entry keeps its id() valid while cached."""
        hit = feeds.get(id(a))
        if hit is not None and hit[0] is a:
            return hit[1]
        j = _feed(a)
        if len(feeds) > 12:
            feeds.clear()
        feeds[id(a)] = (a, j)
        return j

    def run(c_mat, e_direct, e_pred, codes, mem, direct_mode, n_buckets):
        with enable_x64():
            val, vfin, other, buckets = _kernel(
                _feed(c_mat), _feed_cached(e_direct), _feed_cached(e_pred),
                _feed_cached(codes), _feed(mem), _feed_cached(_COUNTER_IDS),
                direct_mode=direct_mode, n_buckets=n_buckets)
        # everything comes back as zero-copy read-only views; retained
        # Predictions copy their own rows out below
        return _view(val), _view(vfin), _view(other), _view(buckets)

    return run


class TablePredictor:
    """Prediction engine bound to one table's resolved energy vectors.

    Since the array-backed table refactor, ``EnergyTable`` itself resolves
    into dense energy vectors over ``isa.CLASS_INDEX`` — ``e_pred``
    (Wattchmen-Pred: direct -> scaled -> bucket) and ``e_direct``
    (Wattchmen-Direct: direct hits only, 0 J elsewhere) — cached per table
    version and extended lazily as the index grows.  The predictor is the
    prediction *kernel* over those vectors; mutations through the table's
    dict views invalidate them automatically, and ``invalidate()`` remains
    for out-of-band mutation of table internals.
    """

    def __init__(self, table: EnergyTable, *, fused: bool = False):
        self.table = table
        self._fused_requested = bool(fused)
        self._fused_kernel = None        # built lazily; False = unavailable

    def _vectors(self, n: int):
        """(e_direct, e_pred) resolved for the first ``n`` class ids."""
        return self.table.energy_vectors(n)

    # -- fused (jitted) hot path --------------------------------------------
    def enable_fused(self) -> bool:
        """Opt into the jitted hot path; True when jax is available.

        Bitwise-identical totals to the plain path (see
        ``_build_fused_kernel``); processes without jax fall back
        silently, so telemetry shard workers can flip this on untested.
        """
        self._fused_requested = True
        return self._ensure_fused() is not None

    def _ensure_fused(self):
        if not self._fused_requested or self._fused_kernel is False:
            return None
        if self._fused_kernel is None:
            try:
                self._fused_kernel = _build_fused_kernel()
            except Exception as e:           # no jax in this process
                warnings.warn(f"fused predict path unavailable ({e}); "
                              f"using the plain numpy path", RuntimeWarning,
                              stacklevel=3)
                self._fused_kernel = False
                return None
        return self._fused_kernel

    def warm(self) -> None:
        """Precompute the class->energy vectors for the whole index.

        Worth it on long-lived predictors (the facade, the fleet monitor);
        one-shot callers stay lazy and only resolve the classes they see.
        """
        self._vectors(len(isa.CLASS_INDEX))

    def invalidate(self) -> None:
        """Drop the resolved vectors after a mutation of the bound table."""
        self.table.invalidate_cache()

    # -- operating points ---------------------------------------------------
    @staticmethod
    def _as_point(op):
        """Normalize to ``(freq_mhz, cap|None)`` or ``None`` (nominal)."""
        if op is None:
            return None
        from repro.dvfs.interp import as_point
        return as_point(op)

    def point_powers(self, operating_point=None):
        """``(p_const, p_static)`` at an operating point (table's own when
        ``None`` — the bitwise legacy path)."""
        p = self._as_point(operating_point)
        if p is None:
            return self.table.p_const, self.table.p_static
        rp = self.table.at(p[0], p[1])
        return rp.p_const, rp.p_static

    # -- the kernel ---------------------------------------------------------
    def _predict_rows(self, counts_list: Sequence[OpCounts],
                      durations: Sequence[float],
                      counters_list: Sequence[Optional[Mapping[str, float]]],
                      mode: str, point=None) -> List[Prediction]:
        """One vectorized pass over a stacked counts matrix.

        Every public prediction path funnels through here — a single
        ``predict`` is a 1-row batch — so batched and per-program totals
        come from literally the same float operations (bitwise equal).

        ``point`` (a normalized ``(freq_mhz, cap|None)``) swaps the energy
        vectors and powers for the family-resolved ones (``EnergyTable.at``);
        ``None`` is the nominal anchor — the unchanged legacy expressions.
        """
        n_jobs = len(counts_list)
        n = len(isa.CLASS_INDEX)
        direct_mode = mode == "direct"
        c_mat = counts_matrix(counts_list, n)
        c_mat[:, _COUNTER_IDS] = 0.0          # memory priced from counters
        if point is None:
            e_direct, e_pred = self._vectors(n)
            p_const, p_static = self.table.p_const, self.table.p_static
        else:
            rp = self.table.at(point[0], point[1])
            e_direct, e_pred = rp.vectors(n)
            p_const, p_static = rp.p_const, rp.p_static

        # memory counters: profiled when given, static traffic model else
        mem = np.empty((n_jobs, len(_COUNTER_ITEMS)))
        need_default = [i for i, c in enumerate(counters_list) if c is None]
        if need_default:
            f = _DEFAULT_HBM_BOUNDARY_FRAC
            br = np.asarray([counts_list[i].boundary_read_bytes
                             for i in need_default])
            bw = np.asarray([counts_list[i].boundary_write_bytes
                             for i in need_default])
            leak = np.asarray([counts_list[i].fused_bytes
                               for i in need_default]) * _DEFAULT_FUSED_LEAK
            mem[need_default, 0] = br * f + 0.5 * leak
            mem[need_default, 1] = bw * f + 0.5 * leak
            mem[need_default, 2] = br * (1 - f)
            mem[need_default, 3] = bw * (1 - f)
        given = [i for i, c in enumerate(counters_list) if c is not None]
        if given:
            # one fancy assignment beats n_jobs*4 scalar ndarray stores on
            # the batched-window ingestion path
            mem[given] = [[counters_list[i].get(key, 0.0)
                           for key, _ in _COUNTER_ITEMS] for i in given]

        kern = self._ensure_fused() if n_jobs >= _FUSED_MIN_JOBS else None
        if kern is not None:
            codes = isa.CLASS_INDEX.bucket_codes(n)
            val, val_fin, other, bucket_j = kern(
                c_mat, e_direct, e_pred, codes, mem, direct_mode,
                len(isa.BUCKET_ORDER))
            # np.sum over the same float64 values in the same layout runs
            # the identical pairwise reduction the plain path runs below —
            # and the mode's own sum is reused for the cover/direct twin
            # whose plain-path floats are expression-for-expression the
            # same (``c_mat * e`` appears twice below), so everything the
            # plain path derives stays bitwise while one full product +
            # one full reduction disappear
            dyn = np.sum(val, axis=1)
            osum = np.sum(other, axis=1)
            if direct_mode:
                direct, cover = dyn.copy(), osum
            else:
                cover, direct = dyn.copy(), osum
        else:
            bucket_j = None
            val = c_mat * (e_direct if direct_mode else e_pred)
            val_fin = val            # counter columns land in place below
            dyn = val.sum(axis=1)
            cover = (c_mat * e_pred).sum(axis=1)  # pred-mode energy, all work
            direct = (c_mat * e_direct).sum(axis=1)  # ... direct hits only

        for j, (_, cls) in enumerate(_COUNTER_ITEMS):
            ci = int(_COUNTER_IDS[j])
            units = mem[:, j]
            v = units * (e_direct[ci] if direct_mode else e_pred[ci])
            if bucket_j is None:
                val[:, ci] += v  # the fused kernel already folded these in
            dyn += v
            cover += units * e_pred[ci]
            direct += units * e_direct[ci]

        dur = np.asarray(durations, dtype=float)
        const = p_const * dur
        static = p_static * dur
        total = const + static + dyn
        coverage = np.ones(n_jobs)
        pos = cover > 0
        coverage[pos] = direct[pos] / cover[pos]

        # copy each row out of the batch matrix so a retained Prediction
        # doesn't pin the whole (n_jobs x n_classes) array via a view
        if bucket_j is None:
            return [Prediction(total[i], const[i], static[i], dyn[i],
                               coverage=coverage[i], duration_s=dur[i],
                               class_vec=val_fin[i].copy())
                    for i in range(n_jobs)]
        # bucket rows stay views: the whole bucket matrix is n_buckets
        # floats per job, cheaper pinned than copied
        return [Prediction(total[i], const[i], static[i], dyn[i],
                           coverage=coverage[i], duration_s=dur[i],
                           class_vec=val_fin[i].copy(),
                           bucket_vec=bucket_j[i])
                for i in range(n_jobs)]

    # -- public surface -----------------------------------------------------
    def predict(self, counts: OpCounts, duration_s: float,
                counters: Optional[Mapping[str, float]] = None,
                mode: str = "pred", operating_point=None) -> Prediction:
        return self._predict_rows([counts], [duration_s], [counters], mode,
                                  self._as_point(operating_point))[0]

    def predict_batch(self, counts_list: Sequence[OpCounts],
                      durations: Sequence[float],
                      counters_list: Optional[Sequence[
                          Optional[Mapping[str, float]]]] = None,
                      mode: Union[str, Sequence[str]] = "pred",
                      operating_point=None) -> List[Prediction]:
        """Batched prediction: one matrix pass instead of N table walks.

        ``mode`` may be a single string or a per-job sequence; the same goes
        for ``operating_point`` (an ``OperatingPoint``/tuple/frequency, or a
        per-job sequence of them).  Mixed batches are split into one pass
        per distinct (mode, point) pair, order preserved.
        """
        n_jobs = len(counts_list)
        if counters_list is None:
            counters_list = [None] * n_jobs
        if _is_point_sequence(operating_point):
            pts = [self._as_point(p) for p in operating_point]
        else:
            pts = [self._as_point(operating_point)] * n_jobs
        modes = [mode] * n_jobs if isinstance(mode, str) else list(mode)
        if isinstance(mode, str) and all(p == pts[0] for p in pts):
            return self._predict_rows(counts_list, durations, counters_list,
                                      mode, pts[0])
        out: List[Optional[Prediction]] = [None] * n_jobs
        keys = list(zip(modes, pts))
        for key in dict.fromkeys(keys):          # unique, first-seen order
            ix = [i for i, k in enumerate(keys) if k == key]
            preds = self._predict_rows([counts_list[i] for i in ix],
                                       [durations[i] for i in ix],
                                       [counters_list[i] for i in ix],
                                       key[0], key[1])
            for i, p in zip(ix, preds):
                out[i] = p
        return out  # type: ignore[return-value]


def predict(table: EnergyTable, counts: OpCounts, duration_s: float,
            counters: Optional[Mapping[str, float]] = None,
            mode: str = "pred") -> Prediction:
    """Predict energy for a profiled application run.

    ``mode``: "direct" = Wattchmen-Direct, "pred" = Wattchmen-Pred (§3.4).
    ``counters``: profiled memory counters; fall back to the static traffic
    model when absent (e.g. predicting from a dry-run compile).

    One-shot convenience over ``TablePredictor``; hold a ``TablePredictor``
    (or the ``repro.api.EnergyModel`` facade, which owns one) when predicting
    for many workloads against the same table.
    """
    return TablePredictor(table).predict(counts, duration_s,
                                         counters=counters, mode=mode)


def mape(pairs) -> float:
    """Mean absolute percent error over (predicted, actual) pairs."""
    errs = [abs(p - a) / a for p, a in pairs if a > 0]
    return 100.0 * sum(errs) / max(len(errs), 1)
