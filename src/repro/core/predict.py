"""Prediction & attribution phase — paper §3.5.

Inputs per application: profiled op counts (``core.opcount``), execution
time, and memory counters (HBM/VMEM bytes — the cache-hit-rate analogue).
Output: total energy plus a fine-grained breakdown by op class and by
micro-architectural bucket, with const/static separated — the artifact the
case studies (§5.3) consume.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Mapping, Optional

from repro.core import isa
from repro.core.opcount import OpCounts
from repro.core.table import DIRECT, EnergyTable

# How predicted traffic is split when no profiled counters are available
# (pure static prediction from a lowered program).
_DEFAULT_HBM_BOUNDARY_FRAC = 0.85
_DEFAULT_FUSED_LEAK = 0.05


@dataclasses.dataclass
class Prediction:
    total_j: float
    const_j: float
    static_j: float
    dynamic_j: float
    by_class: Dict[str, float]
    by_bucket: Dict[str, float]
    coverage: float            # energy-weighted fraction attributed directly
    duration_s: float

    def top_classes(self, k: int = 10):
        return sorted(self.by_class.items(), key=lambda kv: -kv[1])[:k]


def traffic_from_counts(counts: OpCounts) -> Dict[str, float]:
    """Static traffic estimate when no profiled counters exist (dry-run path)."""
    f = _DEFAULT_HBM_BOUNDARY_FRAC
    leak = counts.fused_bytes * _DEFAULT_FUSED_LEAK
    return {
        "hbm_read_bytes": counts.boundary_read_bytes * f + 0.5 * leak,
        "hbm_write_bytes": counts.boundary_write_bytes * f + 0.5 * leak,
        "vmem_read_bytes": counts.boundary_read_bytes * (1 - f),
        "vmem_write_bytes": counts.boundary_write_bytes * (1 - f),
    }


_COUNTER_TO_CLASS = {
    "hbm_read_bytes": "hbm.read",
    "hbm_write_bytes": "hbm.write",
    "vmem_read_bytes": "vmem.read",
    "vmem_write_bytes": "vmem.write",
}
_COUNTER_CLASSES = frozenset(_COUNTER_TO_CLASS.values())


class TablePredictor:
    """Prediction engine bound to one table, amortizing lookups across calls.

    ``EnergyTable.lookup`` walks direct -> scaled -> bucket per class per
    call; at fleet scale (``predict_many`` over thousands of workloads, the
    streaming ``EnergyMonitor``) the same classes recur on every call, so the
    predictor resolves each class once into ``(direct-mode J, pred-mode J,
    provenance)`` and every later prediction is a dict hit.

    The cache snapshots the table: mutate the bound ``EnergyTable`` after
    construction (e.g. re-running ``coverage.extend_table``) and call
    ``invalidate()``, or predictions keep using the old energies.
    """

    def __init__(self, table: EnergyTable):
        self.table = table
        # cls -> (e_direct, e_pred, how_pred).  Direct-mode energy is
        # derivable from the pred-mode walk: a direct hit is the same value,
        # anything else is a direct-mode miss (0 J).
        self._cache: Dict[str, tuple] = {}

    def _entry(self, cls: str) -> tuple:
        ent = self._cache.get(cls)
        if ent is None:
            e_pred, how_pred = self.table.lookup(cls, mode="pred")
            e_direct = e_pred if how_pred == DIRECT else 0.0
            ent = (e_direct, e_pred, how_pred)
            self._cache[cls] = ent
        return ent

    def warm(self) -> None:
        """Precompute the class->energy vector for every table-known class.

        Worth it on long-lived predictors (the facade, the fleet monitor);
        one-shot callers stay lazy and only resolve the classes they see.
        """
        for cls in (set(self.table.direct) | set(self.table.scaled)
                    | _COUNTER_CLASSES):
            self._entry(cls)

    def invalidate(self) -> None:
        """Drop cached entries after a mutation of the bound table."""
        self._cache.clear()

    def predict(self, counts: OpCounts, duration_s: float,
                counters: Optional[Mapping[str, float]] = None,
                mode: str = "pred") -> Prediction:
        table = self.table
        entry = self._entry
        direct_mode = mode == "direct"
        const_j = table.p_const * duration_s
        static_j = table.p_static * duration_s
        by_class: Dict[str, float] = defaultdict(float)
        direct_j = 0.0   # coverage numerator (pred-mode energy of direct hits)
        cover_j = 0.0    # coverage denominator (pred-mode energy of all work)
        dyn_j = 0.0

        def _account(cls: str, n: float) -> None:
            nonlocal direct_j, cover_j, dyn_j
            e_direct, e_pred, how_pred = entry(cls)
            v = n * (e_direct if direct_mode else e_pred)
            by_class[cls] += v
            dyn_j += v
            cover_j += n * e_pred
            if how_pred == DIRECT:
                direct_j += n * e_pred

        for cls, units in counts.units.items():
            if cls in _COUNTER_CLASSES:
                continue
            _account(cls, units)

        mem = (dict(counters) if counters is not None
               else traffic_from_counts(counts))
        for key, cls in _COUNTER_TO_CLASS.items():
            _account(cls, mem.get(key, 0.0))

        by_bucket: Dict[str, float] = defaultdict(float)
        for cls, v in by_class.items():
            by_bucket[isa.bucket_of(cls) or "unknown"] += v
        by_bucket["static"] = static_j
        by_bucket["const"] = const_j

        coverage = direct_j / cover_j if cover_j > 0 else 1.0
        return Prediction(total_j=const_j + static_j + dyn_j,
                          const_j=const_j, static_j=static_j, dynamic_j=dyn_j,
                          by_class=dict(by_class), by_bucket=dict(by_bucket),
                          coverage=coverage, duration_s=duration_s)


def predict(table: EnergyTable, counts: OpCounts, duration_s: float,
            counters: Optional[Mapping[str, float]] = None,
            mode: str = "pred") -> Prediction:
    """Predict energy for a profiled application run.

    ``mode``: "direct" = Wattchmen-Direct, "pred" = Wattchmen-Pred (§3.4).
    ``counters``: profiled memory counters; fall back to the static traffic
    model when absent (e.g. predicting from a dry-run compile).

    One-shot convenience over ``TablePredictor``; hold a ``TablePredictor``
    (or the ``repro.api.EnergyModel`` facade, which owns one) when predicting
    for many workloads against the same table.
    """
    return TablePredictor(table).predict(counts, duration_s,
                                         counters=counters, mode=mode)


def mape(pairs) -> float:
    """Mean absolute percent error over (predicted, actual) pairs."""
    errs = [abs(p - a) / a for p, a in pairs if a > 0]
    return 100.0 * sum(errs) / max(len(errs), 1)
