"""The system of energy equations and its non-negative solve — paper §3.1.

Each microbenchmark contributes one row: the work-unit counts of every
benched op class it executes (ancillary included), with memory columns
populated from *profiler counters* (HBM/VMEM bytes — the hit-rate analogue).
The RHS is the measured dynamic energy of the run.  The system is kept
square (one benchmark per class, asserted) and solved with a non-negative
least-squares solver; the residual staying ≈0 validates the linear model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np
from scipy import optimize

from repro.core import isa
from repro.core.counting import counts_matrix
from repro.core.microbench import MicroBench
from repro.hw.device import RunRecord

# Op-class columns fed from profiler counters instead of jaxpr counts.
COUNTER_CLASSES = {
    "hbm.read": "hbm_read_bytes",
    "hbm.write": "hbm_write_bytes",
    "vmem.read": "vmem_read_bytes",
    "vmem.write": "vmem_write_bytes",
}


@dataclasses.dataclass
class EnergySystem:
    classes: List[str]
    matrix: np.ndarray          # (n_bench, n_class) work units per run
    rhs: np.ndarray             # (n_bench,) measured dynamic energy (J)
    bench_names: List[str]


@dataclasses.dataclass
class Solution:
    energies: Dict[str, float]   # J per work unit
    residual_rel: float          # ||Ax-b|| / ||b||
    system: EnergySystem


def build_system(suite: Sequence[MicroBench],
                 records: Sequence[RunRecord],
                 dynamic_energies: Sequence[float],
                 classes: Sequence[str]) -> EnergySystem:
    """Assemble the (square) system.

    ``classes`` is the benched-class list; anything a benchmark executes
    outside it contributes energy the solve cannot place — kept small by
    suite construction, and the residual check catches violations.

    Assembly is one shot over the class index: the suite's per-iteration
    unit vectors are stacked into a counts matrix, scaled by each run's
    iteration count, and the benched-class columns are gathered out —
    memory columns replaced by the runs' profiled counters.
    """
    classes = list(classes)
    full = counts_matrix([b.counts for b in suite])      # (n_bench, |index|)
    full *= np.asarray([r.iters for r in records], dtype=float)[:, None]
    col_ids = [isa.CLASS_INDEX.intern(c) for c in classes]
    a = full[:, col_ids]
    for j, cls in enumerate(classes):
        counter_key = COUNTER_CLASSES.get(cls)
        if counter_key is not None:
            a[:, j] = [rec.counters.get(counter_key, 0.0) for rec in records]
    return EnergySystem(classes=classes, matrix=a,
                        rhs=np.asarray(dynamic_energies, dtype=np.float64),
                        bench_names=[b.name for b in suite])


def solve_nonnegative(system: EnergySystem) -> Solution:
    """Column-scaled NNLS (enforces real, non-negative energies — §3.1)."""
    a, b = system.matrix, system.rhs
    scale = np.maximum(np.abs(a).max(axis=0), 1e-30)
    a_s = a / scale
    x_s, _ = optimize.nnls(a_s, b, maxiter=10 * a.shape[1])
    x = x_s / scale
    resid = float(np.linalg.norm(a @ x - b) / max(np.linalg.norm(b), 1e-30))
    energies = {c: float(v) for c, v in zip(system.classes, x)}
    return Solution(energies=energies, residual_rel=resid, system=system)


def solve_with_fixed(system: EnergySystem,
                     fixed: Dict[str, float]) -> Solution:
    """NNLS over the free columns with some class energies pinned.

    The fractional-calibration path (paper §6 / Fig. 14): classes whose
    energies are already known — affine-mapped from a donor table — have
    their contribution ``A[:, fixed] @ e_fixed`` subtracted from the RHS,
    and the remaining (sampled) columns are solved as usual.  The returned
    ``energies`` cover both groups; the residual is over the *full* system
    so a bad donor map still shows up.
    """
    free_ix = [j for j, c in enumerate(system.classes) if c not in fixed]
    fixed_ix = [j for j, c in enumerate(system.classes) if c in fixed]
    e_fixed = np.asarray([fixed[system.classes[j]] for j in fixed_ix])
    rhs = system.rhs - system.matrix[:, fixed_ix] @ e_fixed
    sub = EnergySystem(classes=[system.classes[j] for j in free_ix],
                       matrix=system.matrix[:, free_ix],
                       rhs=np.maximum(rhs, 0.0),
                       bench_names=list(system.bench_names))
    sol = solve_nonnegative(sub)
    energies = dict(sol.energies)
    energies.update({system.classes[j]: float(e)
                     for j, e in zip(fixed_ix, e_fixed)})
    x = np.asarray([energies[c] for c in system.classes])
    resid = float(np.linalg.norm(system.matrix @ x - system.rhs)
                  / max(np.linalg.norm(system.rhs), 1e-30))
    return Solution(energies=energies, residual_rel=resid, system=system)
