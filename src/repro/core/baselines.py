"""Baseline energy models the paper compares against (§4.3).

**AccelWattch-style (A)**: a component-bucket *power* model calibrated on a
*differently-configured reference system* (``sim-v5e-ref`` — the analogue of
AccelWattch's own 250W/1417MHz V100 vs CloudLab's 300W/1530MHz V100,
§2.3.1).  It fits per-bucket power coefficients from average bench power via
constrained least squares (their quadratic-programming step) and predicts
``E = P_avg × T``.  Its brittleness is structural: the reference environment's
constant/static power and per-unit energies simply are not the deployment
system's.

**Guser-style (G)**: per-class max-power methodology — for each class, take
the *maximum* power its benchmark reaches and amortize total energy over
units (§4.3: "take the maximum power and multiply by execution time, rather
than integrating a steady-state power trace").  Constant/static energy is
folded into the per-unit values (their documented overprediction source);
control-flow classes are not modeled.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import numpy as np
from scipy import optimize

from repro.core import isa, measure, microbench
from repro.core.opcount import OpCounts
from repro.core.solver import COUNTER_CLASSES
from repro.hw.device import Program
from repro.hw.systems import get_device

_ACCELWATTCH_REF_SYSTEM = "sim-v5e-ref"


def _bucket_unit_sums(counts: OpCounts) -> np.ndarray:
    """Units per bucket code (``isa.BUCKET_ORDER``) in one bincount."""
    v = counts._vec
    if not v.size:
        return np.zeros(len(isa.BUCKET_ORDER))
    codes = isa.CLASS_INDEX.bucket_codes(v.size)
    return np.bincount(codes, weights=v, minlength=len(isa.BUCKET_ORDER))


# ---------------------------------------------------------------------------
# AccelWattch-style.
# ---------------------------------------------------------------------------
class AccelWattchModel:
    """Bucket-level power model calibrated on the reference system."""

    def __init__(self, buckets: Dict[str, float], p_idle: float):
        self.buckets = buckets          # W per (unit/s) per bucket
        self.p_idle = p_idle

    def predict_energy(self, counts: OpCounts, duration_s: float,
                       counters: Optional[dict] = None) -> float:
        rates = _bucket_unit_sums(counts) / duration_s
        if counters:
            mem_rate = sum(counters.get(k, 0.0) for k in
                           ("hbm_read_bytes", "hbm_write_bytes")) / duration_s
            rates[isa.BUCKET_CODE[isa.BUCKET_MEM]] += mem_rate
        p = self.p_idle + sum(self.buckets.get(b, 0.0) * rates[code]
                              for b, code in isa.BUCKET_CODE.items()
                              if b != isa.UNKNOWN_BUCKET)
        return p * duration_s


@functools.lru_cache(maxsize=None)
def train_accelwattch(ref_system: str = _ACCELWATTCH_REF_SYSTEM,
                      duration_s: float = 60.0) -> AccelWattchModel:
    dev = get_device(ref_system)
    suite = microbench.build_suite(isa_gen=dev.chip.isa_gen)
    buckets = sorted(set(isa.ALL_BUCKETS))
    col = {b: j for j, b in enumerate(buckets)}
    rows, pw = [], []
    counter_ids = [isa.CLASS_INDEX.intern(c) for c in COUNTER_CLASSES]
    for bench in suite:
        iters = dev.iters_for_duration(bench.counts, duration_s)
        rec = dev.run(Program(bench.name, bench.counts, iters=iters,
                              is_nanosleep=bench.is_nanosleep))
        t = rec.duration_s
        masked = bench.counts.vector()
        masked[counter_ids] = 0.0        # memory column fed from counters
        codes = isa.CLASS_INDEX.bucket_codes(masked.size)
        sums = np.bincount(codes, weights=masked,
                           minlength=len(isa.BUCKET_ORDER))
        r = np.zeros(len(buckets))
        for b in buckets:
            r[col[b]] = sums[isa.BUCKET_CODE[b]] * rec.iters / t
        r[col[isa.BUCKET_MEM]] += (rec.counters["hbm_read_bytes"]
                                   + rec.counters["hbm_write_bytes"]) / t
        rows.append(r)
        pw.append(rec.avg_power_w)
    a = np.asarray(rows)
    p_idle = measure.constant_power(dev.idle(30.0))
    b_vec = np.asarray(pw) - p_idle
    scale = np.maximum(np.abs(a).max(axis=0), 1e-30)
    x, _ = optimize.nnls(a / scale, np.maximum(b_vec, 0.0))
    return AccelWattchModel({bk: float(v) for bk, v in
                             zip(buckets, x / scale)}, float(p_idle))


# ---------------------------------------------------------------------------
# Guser-style.
# ---------------------------------------------------------------------------
class GuserModel:
    def __init__(self, per_unit: Dict[str, float]):
        self.per_unit = per_unit        # J/unit with static+const amortized
        self._unit_vec = np.zeros(0)    # per_unit over the class index

    def _vec(self, n: int) -> np.ndarray:
        if self._unit_vec.size < n:
            ids = {cls: isa.CLASS_INDEX.intern(cls)
                   for cls in self.per_unit}       # intern before sizing
            v = np.zeros(max(n, len(isa.CLASS_INDEX)))
            for cls, e in self.per_unit.items():
                if not cls.startswith("ctl."):   # Guser: no control flow
                    v[ids[cls]] = e
            self._unit_vec = v
        return self._unit_vec[:n]

    def predict_energy(self, counts: OpCounts, duration_s: float,
                       counters: Optional[dict] = None) -> float:
        v = counts._vec
        e = float(v @ self._vec(v.size)) if v.size else 0.0
        if counters:
            for key, cls in (("hbm_read_bytes", "hbm.read"),
                             ("hbm_write_bytes", "hbm.write")):
                e += counters.get(key, 0.0) * self.per_unit.get(cls, 0.0)
        return e


@functools.lru_cache(maxsize=None)
def train_guser(system: str, duration_s: float = 60.0) -> GuserModel:
    dev = get_device(system)
    suite = microbench.build_suite(isa_gen=dev.chip.isa_gen)
    per_unit: Dict[str, float] = {}
    for bench in suite:
        if bench.is_nanosleep:
            continue
        iters = dev.iters_for_duration(bench.counts, duration_s)
        rec = dev.run(Program(bench.name, bench.counts, iters=iters))
        p_idle = measure.constant_power(dev.idle(10.0))
        p_max = float(np.max(rec.trace.power_w)) - p_idle  # max power, not steady
        if bench.target in COUNTER_CLASSES:
            key = {"hbm.read": "hbm_read_bytes",
                   "hbm.write": "hbm_write_bytes",
                   "vmem.read": "vmem_read_bytes",
                   "vmem.write": "vmem_write_bytes"}[bench.target]
            units_total = rec.counters.get(key, 0.0)
        else:
            units_total = bench.counts.units.get(bench.target, 0.0) * rec.iters
        if units_total > 0:
            # amortize TOTAL energy (P_max × T): const+static folded in
            per_unit[bench.target] = p_max * rec.duration_s / units_total
    return GuserModel(per_unit)
