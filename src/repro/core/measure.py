"""Steady-state energy measurement — the paper's §3.3.

Given NVML-style sampled telemetry, we: (1) detect the steady-state phase of
a run (rolling-std plateau, Fig. 4), (2) integrate power over it, (3)
subtract constant energy (idle probe) and static energy (active-but-idle
NANOSLEEP probe, Oles et al.) to obtain the *dynamic* energy used as the
right-hand side of the system of equations:

    E_total = (P_const + P_static) * T_exec + E_dynamic        (Eq. 2)

Only telemetry enters here — never the device's hidden model.

The numerical primitives (``trapezoid_energy``, ``rolling_std``) are
defined here and reused by the live pipeline in ``repro.telemetry.stream``
(which accumulates them incrementally), so offline analysis and streaming
ingestion can never disagree about what a trace contains.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.hw.device import RunRecord, SensorTrace


def trapezoid_energy(times_s: np.ndarray, power_w: np.ndarray) -> float:
    """Energy (J) of a sampled power signal by trapezoid integration."""
    return float(np.trapezoid(power_w, times_s))


def rolling_std(p: np.ndarray, w: int) -> np.ndarray:
    """Population std of every length-``w`` window of ``p`` (vectorized).

    Returns an array of length ``len(p) - w + 1``; empty when ``w > len(p)``.
    Uses cumulative sums: var = E[x^2] - E[x]^2, clipped at 0 against float
    cancellation.
    """
    p = np.asarray(p, dtype=float)
    n = p.size
    if w > n:
        return np.empty(0)
    c1 = np.concatenate(([0.0], np.cumsum(p)))
    c2 = np.concatenate(([0.0], np.cumsum(p * p)))
    s1 = c1[w:] - c1[:-w]
    s2 = c2[w:] - c2[:-w]
    var = np.maximum(s2 / w - (s1 / w) ** 2, 0.0)
    return np.sqrt(var)


def trailing_window_moments(t: np.ndarray, p: np.ndarray, window_s: float,
                            start: int = 0):
    """Per-sample stats of the trailing time window ending at each sample.

    For every sample ``i >= start``, the window holds samples ``j`` with
    ``t[i] - t[j] <= window_s`` (the online plateau detector's eviction
    rule).  Returns ``(left, count, mean, std)`` arrays over ``i`` in
    ``[start, len(t))``: the window's left index, its population count, and
    its power mean/std via cumulative sums — one vectorized pass instead of
    one deque walk per sample.
    """
    t = np.asarray(t, dtype=float)
    p = np.asarray(p, dtype=float)
    i = np.arange(start, t.size)
    left = np.searchsorted(t, t[i] - window_s, side="left")
    c1 = np.concatenate(([0.0], np.cumsum(p)))
    c2 = np.concatenate(([0.0], np.cumsum(p * p)))
    count = i + 1 - left
    mean = (c1[i + 1] - c1[left]) / count
    var = np.maximum((c2[i + 1] - c2[left]) / count - mean * mean, 0.0)
    return left, count, mean, np.sqrt(var)


@dataclasses.dataclass
class SteadyState:
    power_w: float          # steady-state mean power
    start_s: float          # detected start of the plateau
    rel_std: float          # residual relative std inside the plateau


def detect_steady_state(trace: SensorTrace, window_s: float = 5.0,
                        rel_tol: float = 0.02) -> SteadyState:
    """Find the earliest plateau where rolling power std stays < rel_tol."""
    t, p = trace.times_s, trace.power_w
    if len(t) < 8:
        return SteadyState(float(np.mean(p)), float(t[0]), 1.0)
    dt = float(np.median(np.diff(t)))
    w = max(int(window_s / max(dt, 1e-9)), 4)
    mean_all = float(np.mean(p[-max(w, 4):]))
    # rolling std via cumulative sums, earliest window under the threshold
    n = len(p)
    stds = rolling_std(p, w)
    hits = np.nonzero(stds[:max(n - w, 0)] < max(rel_tol * mean_all, 1.5))[0]
    best_start = int(hits[0]) if hits.size else n - w
    plateau = p[best_start:]
    return SteadyState(power_w=float(np.mean(plateau)),
                       start_s=float(t[best_start]),
                       rel_std=float(np.std(plateau) / max(np.mean(plateau), 1e-9)))


def integrate_trace(trace: SensorTrace) -> float:
    """Approximate energy by integrating the sampled power (Fig. 4 method).

    Same implementation the streaming path accumulates incrementally
    (``telemetry.stream.StreamingIntegrator``).
    """
    return trapezoid_energy(trace.times_s, trace.power_w)


def total_energy(rec: RunRecord, use_counter: bool = False) -> float:
    """Total energy of a run.

    The paper found trace integration within 1% of the NVML energy counter;
    we default to the steady-state formulation (P_ss × T) used for
    microbenchmarks, falling back to trapezoid integration for short runs.
    """
    if use_counter:
        return rec.energy_counter_j
    ss = detect_steady_state(rec.trace)
    steady_span = rec.duration_s - ss.start_s
    if steady_span <= 0.5 * rec.duration_s:
        return integrate_trace(rec.trace)
    # startup segment integrated directly + plateau via P_ss * T
    t, p = rec.trace.times_s, rec.trace.power_w
    mask = t <= ss.start_s
    e_startup = float(np.trapezoid(p[mask], t[mask])) if mask.sum() > 1 else 0.0
    return e_startup + ss.power_w * steady_span


def constant_power(idle_trace: SensorTrace) -> float:
    """Constant (lowest-power-state) power from an idle probe — median over
    samples to reject sensor noise (§3.3.1)."""
    return float(np.median(idle_trace.power_w))


def static_power(nanosleep_rec: RunRecord, p_const: float) -> float:
    """Static (shared-resource) power from the active-but-idle probe."""
    ss = detect_steady_state(nanosleep_rec.trace)
    return max(ss.power_w - p_const, 0.0)


def dynamic_energy(rec: RunRecord, p_const: float, p_static: float,
                   clip: bool = True) -> float:
    """E_dynamic = E_total - (P_const + P_static) * T   (Eq. 2)."""
    e = total_energy(rec) - (p_const + p_static) * rec.duration_s
    return max(e, 0.0) if clip else e
