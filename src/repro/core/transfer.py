"""Cross-system table transfer — paper §6 "Profiler Overhead" / Fig. 14.

The paper observes a strong linear relationship (R² = 0.988) between the
air- and water-cooled V100 per-instruction energy tables and exploits it:
fit an affine map on a random subset (10% / 50%) of classes measured on the
new system, predict the rest from the old system's table, and keep the same
prediction accuracy while profiling a fraction of the suite.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core import coverage
from repro.core.table import EnergyTable


@dataclasses.dataclass
class TransferFit:
    slope: float
    intercept: float
    r2: float
    n_common: int


def fit_affine(src: EnergyTable, dst: EnergyTable,
               classes: List[str]) -> TransferFit:
    xs = np.array([src.direct[c] for c in classes])
    ys = np.array([dst.direct[c] for c in classes])
    a = np.vstack([xs, np.ones_like(xs)]).T
    (slope, intercept), *_ = np.linalg.lstsq(a, ys, rcond=None)
    pred = slope * xs + intercept
    ss_res = float(((ys - pred) ** 2).sum())
    ss_tot = float(((ys - ys.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-30)
    return TransferFit(float(slope), float(intercept), r2, len(classes))


def r2_between(src: EnergyTable, dst: EnergyTable) -> float:
    common = sorted(set(src.direct) & set(dst.direct))
    common = [c for c in common if src.direct[c] > 0 and dst.direct[c] > 0]
    return fit_affine(src, dst, common).r2


def transfer_table(src: EnergyTable, dst: EnergyTable, fraction: float,
                   seed: int = 0, chip=None) -> Tuple[EnergyTable, TransferFit]:
    """Build a dst-system table measuring only ``fraction`` of its classes.

    The sampled classes keep their measured (dst) energies; the rest are
    affine-mapped from the src system's table (Fig. 14 methodology).
    """
    rng = np.random.default_rng(seed)
    common = sorted(set(src.direct) & set(dst.direct))
    nonzero = [c for c in common if src.direct[c] > 0]
    k = max(int(round(fraction * len(common))), 2)
    sample = list(rng.choice(nonzero, size=min(k, len(nonzero)),
                             replace=False))
    fit = fit_affine(src, dst, sample)
    direct: Dict[str, float] = {}
    for c in common:
        if c in sample:
            direct[c] = dst.direct[c]
        else:
            direct[c] = max(fit.slope * src.direct[c] + fit.intercept, 0.0)
    out = EnergyTable(system=f"{dst.system}-transfer{int(fraction*100)}",
                      p_const=dst.p_const, p_static=dst.p_static,
                      direct=direct,
                      meta={"fraction": fraction, "r2_fit": fit.r2})
    coverage.extend_table(out, chip)
    return out, fit
