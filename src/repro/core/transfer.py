"""Cross-system table transfer — paper §6 "Profiler Overhead" / Fig. 14.

The paper observes a strong linear relationship (R² = 0.988) between the
air- and water-cooled V100 per-instruction energy tables and exploits it:
fit an affine map on a random subset (10% / 50%) of classes measured on the
new system, predict the rest from the old system's table, and keep the same
prediction accuracy while profiling a fraction of the suite.

Since the calibration refactor this module is the *vector* form of that
machinery, shared with the pipeline's ``profile_fraction`` mode
(``core.calibrate``): fits and applications are array operations over
``isa.CLASS_INDEX``, and a hybrid table predicts **every** donor class the
sampled fraction never measured — including classes measured only on the
donor system (the previous implementation silently dropped those, which is
exactly the coverage Fig. 14 is meant to buy).

``transfer_table`` is kept as a thin compatibility shim over the shared
pieces (sampling + ``fit_affine`` + ``hybrid_direct``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from repro.core import coverage, isa
from repro.core.table import EnergyTable


@dataclasses.dataclass
class TransferFit:
    slope: float
    intercept: float
    r2: float
    n_common: int

    def apply(self, energies: np.ndarray) -> np.ndarray:
        """Affine-map donor energies onto the target system (clipped >= 0)."""
        return np.maximum(self.slope * np.asarray(energies, dtype=float)
                          + self.intercept, 0.0)


def fit_affine_xy(xs: np.ndarray, ys: np.ndarray) -> TransferFit:
    """Least-squares affine fit ``y ≈ slope*x + intercept`` on raw vectors."""
    xs = np.asarray(xs, dtype=float)
    ys = np.asarray(ys, dtype=float)
    a = np.vstack([xs, np.ones_like(xs)]).T
    (slope, intercept), *_ = np.linalg.lstsq(a, ys, rcond=None)
    pred = slope * xs + intercept
    ss_res = float(((ys - pred) ** 2).sum())
    ss_tot = float(((ys - ys.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-30)
    return TransferFit(float(slope), float(intercept), r2, len(xs))


def fit_affine(src: EnergyTable, dst: EnergyTable,
               classes: Sequence[str]) -> TransferFit:
    """Fit the donor->target map on the classes measured on both systems."""
    ids = np.asarray([isa.CLASS_INDEX.intern(c) for c in classes])
    e_src, _ = src.energy_vectors()
    e_dst, _ = dst.energy_vectors()
    return fit_affine_xy(e_src[ids], e_dst[ids])


def r2_between(src: EnergyTable, dst: EnergyTable) -> float:
    common = sorted(set(src.direct) & set(dst.direct))
    common = [c for c in common if src.direct[c] > 0 and dst.direct[c] > 0]
    return fit_affine(src, dst, common).r2


def hybrid_direct(src: EnergyTable, measured: Mapping[str, float],
                  fit: TransferFit) -> Dict[str, float]:
    """Direct entries of a hybrid table: measured wins, donor affine-fills.

    Every donor class without a measurement is predicted through the fit —
    including classes the target suite never benches at all (src-only),
    which previously fell out of the hybrid entirely.
    """
    direct = dict(measured)
    donor = [(c, e) for c, e in src.direct.items() if c not in direct]
    if donor:
        predicted = fit.apply(np.asarray([e for _, e in donor]))
        direct.update({c: float(p) for (c, _), p in zip(donor, predicted)})
    return direct


def sample_classes(candidates: Sequence[str], population: int,
                   fraction: float, seed: int = 0) -> List[str]:
    """The Fig. 14 random subset: ``fraction`` of ``population`` classes,
    drawn (without replacement) from the measurable ``candidates``."""
    rng = np.random.default_rng(seed)
    k = max(int(round(fraction * population)), 2)
    return list(rng.choice(list(candidates), size=min(k, len(candidates)),
                           replace=False))


def transfer_table(src: EnergyTable, dst: EnergyTable, fraction: float,
                   seed: int = 0, chip=None) -> Tuple[EnergyTable, TransferFit]:
    """Build a dst-system table measuring only ``fraction`` of its classes.

    Compatibility shim over the shared transfer pieces: the sampled classes
    keep their measured (dst) energies; everything else in the donor table
    is affine-mapped (Fig. 14 methodology).  The pipeline equivalent is
    ``EnergyModel.train(system, profile_fraction=..., donor=...)``, which
    measures only the sampled microbenchmarks in the first place.
    """
    common = sorted(set(src.direct) & set(dst.direct))
    nonzero = [c for c in common if src.direct[c] > 0]
    sample = sample_classes(nonzero, population=len(common),
                            fraction=fraction, seed=seed)
    fit = fit_affine(src, dst, sample)
    direct = hybrid_direct(src, {c: dst.direct[c] for c in sample}, fit)
    out = EnergyTable(system=f"{dst.system}-transfer{int(fraction*100)}",
                      p_const=dst.p_const, p_static=dst.p_static,
                      direct=direct,
                      meta={"fraction": fraction, "r2_fit": fit.r2},
                      provenance={"mode": "transfer_shim",
                                  "donor": src.system,
                                  "profile_fraction": fraction,
                                  "n_sampled": len(sample)})
    coverage.extend_table(out, chip)
    return out, fit
