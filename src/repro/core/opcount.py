"""Dynamic op counting over jaxprs — the NSight-SASS-opcode-count analogue.

The paper profiles applications with NSight Compute to obtain SASS opcode
counts (§3.5).  On the JAX/TPU side the equivalent is a walk over the closed
jaxpr: every equation contributes *work units* to a canonical op class
(``core.isa``), with ``scan`` bodies multiplied through their trip counts so
the result is the **dynamic** count — what actually executes, not what the
source mentions once.  The walk is hardware-generation aware: newer
generations issue new MMA forms (``dot_small``/``dot_group``) for the same
source program, mirroring NSight reporting HGMMA on H100 where V100 reports
HMMA (paper §5.2.2-5.2.3).

This module is one of two *front-ends* over the shared accumulation core
(``repro.core.counting``); ``repro.hlo.opcount`` is the other.  The front-end
owns only what is jaxpr-specific: primitive-name tables, aval shape/dtype
extraction, and the producer/consumer dataflow pass that classifies every
operand/result as *fused* (stays in VMEM/VREGs inside an XLA fusion —
elementwise chains, dot epilogues) or *boundary* (crosses a fusion boundary
and is a candidate for HBM traffic).  All pricing — MMA-generation
selection, convert classes, collective wire bytes, trip-count
multiplication, reduce/sort/scatter rules — comes from the core, so the two
counters cannot drift.

The ``OpCounts`` currency itself (an array over ``isa.CLASS_INDEX``) is
defined in ``repro.core.counting`` and re-exported here for compatibility.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Any, Callable, Dict, Mapping, Optional

import jax
import numpy as np

from repro.core import isa
from repro.core import counting
from repro.core.counting import OpCounts  # noqa: F401  (compat re-export)

# Ops that are pure metadata on TPU (relayouts handled by 'transpose').
# The state primitives (get/swap — pallas ref reads/writes) are free here:
# their traffic is the kernel's block streaming, priced once at the
# ``pallas_call`` boundary from the grid × block-shape bytes.
_FREE_PRIMS = {
    "reshape", "squeeze", "expand_dims", "bitcast_convert_type",
    "stop_gradient", "copy", "random_wrap", "random_unwrap", "random_seed",
    "split", "device_put", "sharding_constraint", "layout_constraint",
    "optimization_barrier", "pvary", "axis_index", "debug_callback",
    "get", "swap", "program_id", "num_programs",
}

_UNARY_ELEMWISE = {
    "exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "erf", "sin", "cos",
    "neg", "abs", "sign", "floor", "ceil", "round", "not", "log1p", "expm1",
    "exp2", "log2", "cbrt", "tan", "asin", "acos", "atan", "sinh", "cosh",
    "erfc", "erf_inv", "is_finite", "integer_pow", "square", "real", "imag",
    "reduce_precision", "population_count", "clz",
}
_BINARY_ELEMWISE = {
    "add", "mul", "sub", "div", "max", "min", "pow", "rem", "atan2",
    "and", "or", "xor", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "nextafter", "complex",
}
_COMPARE = {"eq", "ne", "lt", "le", "gt", "ge"}
_REDUCE_ADD = {"reduce_sum", "reduce_prod", "reduce_and", "reduce_or",
               "reduce_xor"}
_REDUCE_MAX = {"reduce_max", "reduce_min", "argmax", "argmin"}
_CUM = {"cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp"}

# Primitives whose results live inside a fusion (VMEM/VREG resident).
# Slicing/layout ops fuse with their consumers in XLA.
_FUSABLE_PRIMS = (_UNARY_ELEMWISE | _BINARY_ELEMWISE | _COMPARE | _CUM | {
    "select_n", "clamp", "convert_element_type", "broadcast_in_dim", "iota",
    "pad", "slice", "rev", "add_any", "concatenate", "transpose",
    "dynamic_slice", "gather",
})

# Collective primitives (appear inside shard_map'd jaxprs): primitive name ->
# canonical class.  Wire-bytes formulas live in the shared core
# (``counting.COLLECTIVE_WIRE``), written against the local per-chip bytes —
# exactly what a shard_map'd jaxpr observes.
_COLLECTIVE_CLASS: Dict[str, str] = {
    "psum": "ici.all_reduce",
    "psum2": "ici.all_reduce",
    "psum_invariant": "ici.all_reduce",
    "all_gather": "ici.all_gather",
    "psum_scatter": "ici.reduce_scatter",
    "reduce_scatter": "ici.reduce_scatter",
    "all_to_all": "ici.all_to_all",
    "ppermute": "ici.permute",
}

# Back-compat alias: (class name, wire-bytes fn of (local_bytes, axis_size)).
_COLLECTIVES: Dict[str, Any] = {
    prim: (cls, counting.COLLECTIVE_WIRE[cls])
    for prim, cls in _COLLECTIVE_CLASS.items()
}


def _aval_bytes(aval) -> float:
    try:
        return float(math.prod(aval.shape) * np.dtype(aval.dtype).itemsize)
    except Exception:
        return 0.0


def _aval_elems(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


def _dtype_tag(aval) -> str:
    try:
        return counting.dtype_tag(np.dtype(aval.dtype).name)
    except Exception:
        return "f32"


def _dot_dims(eqn):
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    k = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(s for d, s in enumerate(lhs.shape) if d not in lc and d not in lb)
    n = math.prod(s for d, s in enumerate(rhs.shape) if d not in rc and d not in rb)
    return batch, m, n, k


def _conv_macs(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    fgc = eqn.params.get("feature_group_count", 1) or 1
    k_spatial = math.prod(rhs.shape[d] for d in dn.rhs_spec[2:])
    in_ch = rhs.shape[dn.rhs_spec[1]]
    return float(_aval_elems(out) * k_spatial * in_ch / fgc)


class _FuseInfo:
    """Producer/consumer dataflow classification for one jaxpr scope."""

    def __init__(self, jaxpr):
        self.fusable_out = set()        # ids of vars produced by fusable eqns
        self.cons_total: Dict[int, int] = defaultdict(int)
        self.cons_fusable: Dict[int, int] = defaultdict(int)
        for eqn in jaxpr.eqns:
            fus = eqn.primitive.name in _FUSABLE_PRIMS
            for v in eqn.invars:
                if hasattr(v, "aval") and not _is_literal(v):
                    self.cons_total[id(v)] += 1
                    if fus:
                        self.cons_fusable[id(v)] += 1
            if fus:
                for ov in eqn.outvars:
                    self.fusable_out.add(id(ov))

    def read_is_fused(self, v) -> bool:
        return id(v) in self.fusable_out

    def write_is_fused(self, v) -> bool:
        tot = self.cons_total.get(id(v), 0)
        return tot > 0 and self.cons_fusable.get(id(v), 0) == tot


def _is_literal(v) -> bool:
    return type(v).__name__ == "Literal"


class _Ctx:
    def __init__(self, axis_sizes: Mapping[str, int], isa_gen: int = 0):
        self.axis_sizes = dict(axis_sizes)
        self.isa_gen = int(isa_gen)


def _axis_size(ctx: _Ctx, axes) -> int:
    if axes is None:
        return 1
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= int(a) if isinstance(a, int) else int(ctx.axis_sizes.get(a, 1))
    return max(n, 1)


def _eqn_io(eqn, fuse: _FuseInfo, force_boundary_reads: bool = False):
    """(boundary_read, boundary_write, fused, max_buf) bytes for one eqn."""
    b_read = b_write = fused = max_buf = 0.0
    for v in eqn.invars:
        if not hasattr(v, "aval"):
            continue
        b = _aval_bytes(v.aval)
        max_buf = max(max_buf, b)
        if not force_boundary_reads and fuse.read_is_fused(v):
            fused += b
        else:
            b_read += b
    for v in eqn.outvars:
        b = _aval_bytes(v.aval)
        max_buf = max(max_buf, b)
        if fuse.write_is_fused(v):
            fused += b
        else:
            b_write += b
    return b_read, b_write, fused, max_buf


# Sliced-access primitives touch only the moved elements, not their full
# operands (a gather reads the gathered rows, not the whole table).
def _sliced_io(eqn, fuse: "_FuseInfo"):
    out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
    name = eqn.primitive.name
    max_buf = max((_aval_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval")), default=0.0)
    w_fused = all(fuse.write_is_fused(v) for v in eqn.outvars)
    b_write, f_write = (0.0, out_b) if w_fused else (out_b, 0.0)
    if name in ("slice", "dynamic_slice", "rev", "gather"):
        return out_b, b_write, f_write, max(max_buf, out_b)
    if name == "dynamic_update_slice":
        upd = _aval_bytes(eqn.invars[1].aval)
        return upd, upd, 0.0, max(max_buf, upd)
    if name.startswith("scatter"):
        upd = (_aval_bytes(eqn.invars[2].aval) if len(eqn.invars) > 2
               else out_b)
        return 2.0 * upd, upd, 0.0, max(max_buf, upd)
    return out_b, b_write, f_write, max_buf


def _block_bytes(bm) -> float:
    """Per-grid-step VMEM bytes for one pallas BlockMapping."""
    try:
        shape = getattr(bm, "block_shape", ()) or ()
        n = 1.0
        for d in shape:
            try:
                n *= float(int(d))
            except (TypeError, ValueError):
                pass            # Squeezed/None/mapped dims contribute 1
        asd = getattr(bm, "array_shape_dtype", None)
        item = np.dtype(asd.dtype).itemsize if asd is not None else 4
        return n * float(item)
    except Exception:
        return 0.0


def _find_eqns(jaxpr, name: str, depth: int = 3):
    """Yield eqns named ``name`` in ``jaxpr`` and (shallowly) nested calls."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in getattr(jaxpr, "eqns", ()):
        if eqn.primitive.name == name:
            yield eqn
        elif depth > 0:
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("body_jaxpr")) if eqn.params else None
            if sub is not None:
                yield from _find_eqns(sub, name, depth - 1)


def _pallas_while_trips(body_jaxpr) -> int:
    """Upper-bound trip count for traced-bound loops in a pallas body.

    A dynamically bounded ``fori_loop`` (e.g. the causal flash-attention
    K sweep, whose upper bound depends on ``program_id``) lowers to a
    ``while`` whose trip count the jaxpr does not carry.  Each trip reads
    a block-sized slice of a full-length ref, so the full-dim/slice-dim
    ratio of the largest ``get`` inside the loop bounds the trips — for
    flash that is ``s / block_k``.  An upper bound by construction
    (early q blocks run fewer causal trips).
    """
    trips = 1
    for weqn in _find_eqns(body_jaxpr, "while"):
        wbody = weqn.params.get("body_jaxpr")
        if wbody is None:
            continue
        for geqn in _find_eqns(wbody, "get"):
            if not geqn.invars or not geqn.outvars:
                continue
            ref = getattr(geqn.invars[0], "aval", None)
            outv = getattr(geqn.outvars[0], "aval", None)
            r = _aval_elems(ref) if ref is not None else 0.0
            o = _aval_elems(outv) if outv is not None else 0.0
            if r > 0 and o > 0 and r > o:
                trips = max(trips, int(math.ceil(r / o)))
    return trips


def _count_eqn(eqn, out: OpCounts, mult: float, ctx: _Ctx,
               fuse: _FuseInfo) -> None:
    name = eqn.primitive.name
    if name in _FREE_PRIMS:
        return

    # ---- higher-order primitives: recurse -------------------------------
    if name == "scan":
        body = count_jaxpr(eqn.params["jaxpr"], axis_sizes=ctx.axis_sizes,
                           isa_gen=ctx.isa_gen)
        counting.merge_loop_body(out, body, float(eqn.params["length"]), mult)
        # scanned-over arrays are part of the working set
        big = max((_aval_bytes(v.aval) for v in list(eqn.invars)
                   + list(eqn.outvars) if hasattr(v, "aval")), default=0.0)
        out.note_buffer(big)
        return
    if name == "while":
        trips = float(ctx.axis_sizes.get("__while_trips__", 1))
        body = count_jaxpr(eqn.params["body_jaxpr"], axis_sizes=ctx.axis_sizes,
                           isa_gen=ctx.isa_gen)
        counting.merge_loop_body(out, body, trips, mult)
        return
    if name == "cond":
        branches = [count_jaxpr(b, axis_sizes=ctx.axis_sizes,
                                isa_gen=ctx.isa_gen)
                    for b in eqn.params["branches"]]
        counting.merge_best_branch(out, branches, mult)
        return
    if name in ("jit", "pjit", "closed_call", "core_call", "remat2", "remat",
                "custom_vjp_call_jaxpr", "xla_call", "custom_jvp_call",
                "custom_vjp_call", "custom_jvp_call_jaxpr"):
        sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
               or eqn.params.get("fun_jaxpr"))
        if sub is not None:
            out.merge(count_jaxpr(sub, axis_sizes=ctx.axis_sizes,
                                  isa_gen=ctx.isa_gen), mult)
        return
    if name == "shard_map":
        mesh = eqn.params.get("mesh")
        sizes = dict(ctx.axis_sizes)
        if mesh is not None:
            try:
                sizes.update({str(k): int(v) for k, v in mesh.shape.items()})
            except Exception:
                pass
        sub = eqn.params.get("jaxpr")
        if sub is not None:
            out.merge(count_jaxpr(sub, axis_sizes=sizes,
                                  isa_gen=ctx.isa_gen), mult)
        return

    if name == "pallas_call":
        gm = eqn.params.get("grid_mapping")
        body = eqn.params.get("jaxpr")
        if gm is not None and body is not None:
            try:
                grid = 1
                for g in getattr(gm, "grid", ()) or ():
                    try:
                        grid *= int(g)
                    except (TypeError, ValueError):
                        pass    # symbolic dims count as 1
                grid = max(grid, 1)
                sizes = dict(ctx.axis_sizes)
                if "__while_trips__" not in sizes:
                    trips = _pallas_while_trips(body)
                    if trips > 1:
                        sizes["__while_trips__"] = trips
                inner = count_jaxpr(body, axis_sizes=sizes,
                                    isa_gen=ctx.isa_gen)
                # Inside the kernel every ref access is VMEM-resident: the
                # body's "boundary" traffic never leaves the core, and its
                # fusion roots are not separate launches — one pallas_call
                # is one dispatch, booked below.
                inner.fused_bytes += (inner.boundary_read_bytes
                                      + inner.boundary_write_bytes)
                inner.boundary_read_bytes = 0.0
                inner.boundary_write_bytes = 0.0
                inner.dispatch_count = 0.0
                # the kernel body runs once per grid step; each step pays
                # loop/control overhead like a scan trip
                counting.merge_loop_body(out, inner, float(grid), mult)
                # Block streaming: every grid step reads its input blocks
                # from HBM and writes its output blocks back, so boundary
                # traffic is grid x block bytes.  Operands whose block is
                # the full array (e.g. K/V in flash attention) are re-read
                # on every step — this is where block_q/block_k genuinely
                # move the energy.
                mappings = list(getattr(gm, "block_mappings", ()) or ())
                n_out = int(getattr(gm, "num_outputs", len(eqn.outvars))
                            or len(eqn.outvars))
                in_maps = mappings[:len(mappings) - n_out]
                out_maps = mappings[len(mappings) - n_out:]
                read_b = sum(_block_bytes(bm) for bm in in_maps)
                write_b = sum(_block_bytes(bm) for bm in out_maps)
                out.add_io(grid * read_b, grid * write_b, 0.0, mult)
                # resident set per grid step: all blocks live in VMEM at once
                out.note_buffer(read_b + write_b)
                out.exec_count += mult
                out.dispatch_count += mult      # one launch per pallas_call
                return
            except Exception:
                pass            # fall through to the unknown-prim fallback

    # ---- collectives -----------------------------------------------------
    if name in _COLLECTIVE_CLASS:
        n = _axis_size(ctx, eqn.params.get("axes",
                                           eqn.params.get("axis_name")))
        tensor_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                           if hasattr(v, "aval"))
        counting.add_collective(out, _COLLECTIVE_CLASS[name], tensor_bytes,
                                n, mult)
        return

    out.exec_count += mult
    # Fusion roots approximate kernel dispatches (a chain of fused
    # elementwise ops is one launch on real hardware).
    if any(not fuse.write_is_fused(v) for v in eqn.outvars):
        out.dispatch_count += mult
    out_aval = eqn.outvars[0].aval if eqn.outvars else None

    # ---- MXU -------------------------------------------------------------
    if name == "dot_general":
        batch, m, n, k = _dot_dims(eqn)
        raw = np.dtype(eqn.invars[0].aval.dtype).name
        dt = {"int8": "int8", "uint8": "int8", "int4": "int4",
              "uint4": "int4", "float8_e4m3fn": "fp8",
              "float8_e5m2": "fp8"}.get(raw) or _dtype_tag(eqn.invars[0].aval)
        counting.add_dot(out, isa_gen=ctx.isa_gen, dt=dt,
                         batch=batch, m=m, n=n, k=k, mult=mult)
        br, bw, f, mb = _eqn_io(eqn, fuse, force_boundary_reads=True)
        out.add_io(br, bw, f, mult)
        out.note_buffer(mb)
        return
    if name == "conv_general_dilated":
        dt = _dtype_tag(eqn.invars[0].aval)
        counting.add_conv(out, dt=dt, macs=_conv_macs(eqn), mult=mult)
        br, bw, f, mb = _eqn_io(eqn, fuse, force_boundary_reads=True)
        out.add_io(br, bw, f, mult)
        out.note_buffer(mb)
        return

    # ---- everything else: traffic + class units ---------------------------
    if name in ("gather", "dynamic_slice", "dynamic_update_slice", "slice",
                "rev") or name.startswith("scatter"):
        br, bw, f, mb = _sliced_io(eqn, fuse)
    else:
        br, bw, f, mb = _eqn_io(eqn, fuse,
                                force_boundary_reads=name in ("sort", "top_k"))
    out.add_io(br, bw, f, mult)
    out.note_buffer(mb)

    if name == "convert_element_type":
        src = _dtype_tag(eqn.invars[0].aval)
        dst = _dtype_tag(out_aval)
        cls = counting.convert_class(src, dst)
        if cls is not None:
            out.add(isa.group_class(cls), mult * _aval_elems(out_aval))
        return

    elems_out = _aval_elems(out_aval) if out_aval is not None else 0.0

    if name in _UNARY_ELEMWISE:
        dt = _dtype_tag(out_aval)
        out.add(isa.group_class(f"{name}.{dt}"), mult * elems_out)
        out.flops += mult * elems_out
        return
    if name in _BINARY_ELEMWISE:
        dt = _dtype_tag(out_aval)
        out.add(isa.group_class(f"{name}.{dt}"), mult * elems_out)
        out.flops += mult * elems_out
        return
    if name in _COMPARE:
        dt = _dtype_tag(eqn.invars[0].aval)
        out.add(isa.group_class(f"cmp.{dt}"), mult * elems_out)
        return
    if name == "select_n":
        dt = _dtype_tag(out_aval)
        out.add(isa.group_class(f"select.{dt}"), mult * elems_out)
        return
    if name == "clamp":
        dt = _dtype_tag(out_aval)
        out.add(isa.group_class(f"max.{dt}"), mult * 2 * elems_out)
        return
    if name in _REDUCE_ADD:
        counting.add_reduce(out, False, _aval_elems(eqn.invars[0].aval), mult)
        return
    if name in _REDUCE_MAX:
        counting.add_reduce(out, True, _aval_elems(eqn.invars[0].aval), mult)
        return
    if name in _CUM:
        out.add("cumsum.f32", mult * elems_out)
        out.flops += mult * elems_out
        return
    if name == "broadcast_in_dim":
        out.add("bcast", mult * elems_out)
        return
    if name == "transpose":
        out.add("transpose", mult * elems_out)
        return
    if name == "concatenate":
        out.add("concat", mult * elems_out)
        return
    if name in ("slice", "dynamic_slice", "rev"):
        out.add("slice", mult * elems_out)
        return
    if name == "dynamic_update_slice":
        out.add("dus", mult * _aval_elems(eqn.invars[1].aval))
        return
    if name == "gather":
        out.add("gather", mult * elems_out)
        return
    if name.startswith("scatter"):
        upd = eqn.invars[2].aval if len(eqn.invars) > 2 else out_aval
        out.add(counting.scatter_class(ctx.isa_gen), mult * _aval_elems(upd))
        return
    if name == "iota":
        out.add("iota", mult * elems_out)
        return
    if name == "pad":
        out.add("pad", mult * elems_out)
        return
    if name in ("sort", "top_k"):
        n_in = _aval_elems(eqn.invars[0].aval)
        dim = eqn.invars[0].aval.shape[-1] if eqn.invars[0].aval.shape else 2
        out.add("sort", mult * counting.sort_units(n_in, dim))
        return
    if name in ("random_bits", "threefry2x32", "random_fold_in",
                "random_gamma"):
        out.add("rng.bits", mult * max(elems_out, 1.0))
        return

    # Unknown primitive: emit a raw class so the coverage machinery
    # (bucketing) sees it rather than silently dropping the work.
    dt = _dtype_tag(out_aval) if out_aval is not None else "f32"
    out.add(isa.group_class(f"{name}.{dt}"), mult * max(elems_out, 1.0))


def count_jaxpr(closed_jaxpr, *, axis_sizes: Optional[Mapping[str, int]] = None,
                isa_gen: int = 0) -> OpCounts:
    """Count dynamic work units in a (closed) jaxpr."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    ctx = _Ctx(axis_sizes or {}, isa_gen=isa_gen)
    fuse = _FuseInfo(jaxpr)
    out = OpCounts()
    for eqn in jaxpr.eqns:
        _count_eqn(eqn, out, 1.0, ctx, fuse)
    return out


def count_fn(fn: Callable, *args, axis_sizes: Optional[Mapping[str, int]] = None,
             isa_gen: int = 0, **kwargs) -> OpCounts:
    """Trace ``fn`` with ShapeDtypeStruct/array args and count its work."""
    jx = jax.make_jaxpr(fn)(*args, **kwargs)
    return count_jaxpr(jx, axis_sizes=axis_sizes, isa_gen=isa_gen)
