"""Coverage extension: scaling, grouping, bucketing — paper §3.4.

*Grouping* happens upstream in ``isa.group_class`` (modifier folding).
*Scaling* derives unmeasured memory-hierarchy entries from measured ratios.
*Bucketing* averages known energies per micro-architectural bucket and uses
the average for any class without a direct or scaled entry.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Optional

import numpy as np

from repro.core import isa
from repro.core.table import EnergyTable
from repro.hw.spec import ChipSpec


def apply_scaling(table: EnergyTable, chip: Optional[ChipSpec] = None) -> None:
    """Scaling rules (paper: e(LDG@L2) = e(LDG@L1) * e(STG@L2)/e(STG@L1)).

    - ``vmem.write`` from the measured read/write ratio at the HBM level.
    - ``dcn.transfer`` from the ICI energy scaled by the public
      link-bandwidth ratio (no cross-pod microbenchmark in the suite).
    """
    d = table.direct
    if ("vmem.write" not in d and "vmem.read" in d
            and d.get("hbm.read", 0) > 0 and "hbm.write" in d):
        table.scaled["vmem.write"] = (
            d["vmem.read"] * d["hbm.write"] / d["hbm.read"])
    if "dcn.transfer" not in d and "ici.all_to_all" in d and chip is not None:
        ratio = chip.ici_link_bandwidth / max(chip.dcn_bandwidth, 1.0)
        table.scaled["dcn.transfer"] = d["ici.all_to_all"] * ratio


def compute_bucket_means(table: EnergyTable) -> None:
    """Per-bucket averages over *known* energies (direct + scaled)."""
    groups: Dict[str, list] = defaultdict(list)
    for cls, e in {**table.direct, **table.scaled}.items():
        b = isa.bucket_of(cls)
        if b is not None and e > 0:
            groups[b].append(e)
    table.bucket_means = {b: float(np.mean(v)) for b, v in groups.items() if v}


def extend_table(table: EnergyTable, chip: Optional[ChipSpec] = None) -> None:
    apply_scaling(table, chip)
    compute_bucket_means(table)
