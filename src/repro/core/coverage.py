"""Coverage extension: scaling, grouping, bucketing — paper §3.4.

*Grouping* happens upstream in ``isa.group_class`` (modifier folding).
*Scaling* derives unmeasured memory-hierarchy entries from measured ratios.
*Bucketing* averages known energies per micro-architectural bucket and uses
the average for any class without a direct or scaled entry.

Since the calibration refactor all three run on the array-backed table:
known energies are read as dense vectors over ``isa.CLASS_INDEX`` and the
per-bucket means are two ``np.bincount`` calls over the index's bucket
codes instead of a per-class ``bucket_of`` walk.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import isa
from repro.core.table import SCALED, EnergyTable
from repro.hw.spec import ChipSpec


def apply_scaling(table: EnergyTable, chip: Optional[ChipSpec] = None) -> None:
    """Scaling rules (paper: e(LDG@L2) = e(LDG@L1) * e(STG@L2)/e(STG@L1)).

    - ``vmem.write`` from the measured read/write ratio at the HBM level.
    - ``dcn.transfer`` from the ICI energy scaled by the public
      link-bandwidth ratio (no cross-pod microbenchmark in the suite).
    """
    d = table.direct
    if ("vmem.write" not in d and "vmem.read" in d
            and d.get("hbm.read", 0) > 0 and "hbm.write" in d):
        table.set_energy(
            "vmem.write", d["vmem.read"] * d["hbm.write"] / d["hbm.read"],
            SCALED)
    if "dcn.transfer" not in d and "ici.all_to_all" in d and chip is not None:
        ratio = chip.ici_link_bandwidth / max(chip.dcn_bandwidth, 1.0)
        table.set_energy("dcn.transfer", d["ici.all_to_all"] * ratio, SCALED)


def compute_bucket_means(table: EnergyTable) -> None:
    """Per-bucket averages over *known* (direct + scaled) positive energies.

    One pass over the class index: gather the known-energy vector, mask to
    positive entries, and reduce per bucket with ``bincount`` over the
    index's bucket codes.
    """
    n = len(isa.CLASS_INDEX)
    known, mask = table.known_energies(n)
    sel = mask & (known > 0)
    codes = isa.CLASS_INDEX.bucket_codes(n)[sel]
    n_buckets = len(isa.BUCKET_ORDER)
    sums = np.bincount(codes, weights=known[sel], minlength=n_buckets)
    counts = np.bincount(codes, minlength=n_buckets)
    unknown = isa.BUCKET_CODE[isa.UNKNOWN_BUCKET]
    table.bucket_means = {
        isa.BUCKET_ORDER[b]: float(sums[b] / counts[b])
        for b in np.nonzero(counts)[0] if b != unknown}


def extend_table(table: EnergyTable, chip: Optional[ChipSpec] = None) -> None:
    apply_scaling(table, chip)
    compute_bucket_means(table)
