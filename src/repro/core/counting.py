"""The shared op-count accumulation core used by both counter front-ends.

``repro.core.opcount`` (jaxpr walk) and ``repro.hlo.opcount`` (optimized-HLO
walk) are *front-ends*: they know how to read their representation, but every
accounting decision — how a dot prices onto an MMA generation, how a convert
picks its class, what a collective puts on the wire, how a loop body
multiplies through its trip count, how fusion-boundary vs fused traffic is
booked — lives here, once.  The two counters can therefore never drift in
what a unit of work *means*, only in what they can observe.

The currency itself also lives here: ``OpCounts`` keeps its per-class units
as a dense NumPy vector over ``isa.CLASS_INDEX`` (the paper's Eq. 3 as an
actual dot-product axis), with a read-mostly dict view (``units``) kept for
compatibility with existing callers and serialized artifacts.
"""
from __future__ import annotations

import math
import warnings
from typing import Dict, Iterator, List, Mapping, Optional, Sequence

import numpy as np

from repro.core import isa

__all__ = [
    "OpCounts", "UnitsView", "dtype_tag", "mma_head", "add_dot", "add_conv",
    "convert_class", "collective_wire_bytes", "COLLECTIVE_WIRE",
    "add_collective", "merge_loop_body", "merge_best_branch", "scatter_class",
    "sort_units", "add_reduce", "counts_matrix",
]

# ---------------------------------------------------------------------------
# Dtype grouping (§3.4).  One table covering both front-ends' raw spellings:
# NumPy dtype names (jaxpr avals) go through ``isa.group_dtype``; HLO type
# tokens are folded here onto the same grouped tags.
# ---------------------------------------------------------------------------
_HLO_DTYPE_TAG = {
    "f64": "f32", "f32": "f32", "f16": "bf16", "bf16": "bf16",
    "f8e4m3fn": "fp8", "f8e5m2": "fp8", "f8e4m3": "fp8",
    "s64": "int", "s32": "int", "s16": "int", "s8": "int",
    "u64": "int", "u32": "int", "u16": "int", "u8": "int",
    "s4": "int4", "u4": "int4", "pred": "int",
}


def dtype_tag(name: str) -> str:
    """Grouped dtype tag for a NumPy dtype name or an HLO type token."""
    tag = _HLO_DTYPE_TAG.get(name)
    return tag if tag is not None else isa.group_dtype(name)


# ---------------------------------------------------------------------------
# The currency.
# ---------------------------------------------------------------------------
_MUTATION_WARNED = False


def _warn_units_mutation() -> None:
    global _MUTATION_WARNED
    if not _MUTATION_WARNED:
        _MUTATION_WARNED = True
        warnings.warn(
            "mutating OpCounts.units as a dict is deprecated; use "
            "OpCounts.add(cls, n) — writes are redirected through the "
            "class index", DeprecationWarning, stacklevel=3)


class UnitsView(Mapping):
    """Dict-compatible view over an ``OpCounts`` unit vector.

    Reads behave like the old ``defaultdict(float)``: absent (or zeroed)
    classes read as missing, ``[]`` on a missing key returns ``0.0`` rather
    than raising.  Writes still work for out-of-tree callers but warn once
    and are redirected through the class index (the supported write path is
    ``OpCounts.add``).
    """

    __slots__ = ("_counts",)

    def __init__(self, counts: "OpCounts"):
        self._counts = counts

    # -- reads --------------------------------------------------------------
    def _nonzero_ids(self) -> np.ndarray:
        return np.nonzero(self._counts._vec)[0]

    def __getitem__(self, cls: str) -> float:
        i = isa.CLASS_INDEX.id(cls)
        v = self._counts._vec
        return float(v[i]) if i is not None and i < v.size else 0.0

    def get(self, cls: str, default=None):
        i = isa.CLASS_INDEX.id(cls)
        v = self._counts._vec
        if i is None or i >= v.size or v[i] == 0.0:
            return default
        return float(v[i])

    def __contains__(self, cls) -> bool:
        i = isa.CLASS_INDEX.id(cls)
        v = self._counts._vec
        return i is not None and i < v.size and v[i] != 0.0

    def __iter__(self) -> Iterator[str]:
        name = isa.CLASS_INDEX.name
        return (name(int(i)) for i in self._nonzero_ids())

    def __len__(self) -> int:
        return int(self._nonzero_ids().size)

    def items(self):
        v = self._counts._vec
        name = isa.CLASS_INDEX.name
        return [(name(int(i)), float(v[i])) for i in self._nonzero_ids()]

    def keys(self):
        return list(self)

    def values(self):
        v = self._counts._vec
        return [float(v[i]) for i in self._nonzero_ids()]

    def __eq__(self, other) -> bool:
        if isinstance(other, UnitsView):
            return dict(self.items()) == dict(other.items())
        if isinstance(other, Mapping):
            return dict(self.items()) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:
        return f"UnitsView({dict(self.items())!r})"

    # -- deprecated writes --------------------------------------------------
    def __setitem__(self, cls: str, value: float) -> None:
        _warn_units_mutation()
        c = self._counts
        i = isa.CLASS_INDEX.intern(cls)
        c._ensure(i + 1)
        c._vec[i] = float(value)

    def __delitem__(self, cls: str) -> None:
        _warn_units_mutation()
        i = isa.CLASS_INDEX.id(cls)
        if i is not None and i < self._counts._vec.size:
            self._counts._vec[i] = 0.0


class OpCounts:
    """Work-unit counts per canonical op class + traffic/FLOP aggregates.

    ``units`` is stored as a dense float64 vector over ``isa.CLASS_INDEX``
    (``add``/``merge``/``scaled`` are vector ops; ``vector(n)`` exposes a
    zero-padded copy for matrix assembly).  The ``units`` property is a
    dict-compatible view for existing callers.
    """

    __slots__ = ("_vec", "naive_bytes", "boundary_read_bytes",
                 "boundary_write_bytes", "fused_bytes", "flops", "exec_count",
                 "dispatch_count", "max_buffer_bytes", "mxu_macs_total",
                 "mxu_macs_aligned")

    def __init__(self, units: Optional[Mapping[str, float]] = None):
        self._vec = np.zeros(len(isa.CLASS_INDEX))
        self.naive_bytes = 0.0          # all operand+result traffic
        self.boundary_read_bytes = 0.0  # fusion-boundary reads
        self.boundary_write_bytes = 0.0  # fusion-boundary writes
        self.fused_bytes = 0.0          # traffic that stays inside fusions
        self.flops = 0.0            # arithmetic FLOPs (2*MACs for dots/convs)
        self.exec_count = 0.0       # total dynamic eqn executions
        self.dispatch_count = 0.0   # fusion roots ≈ kernel dispatches
        self.max_buffer_bytes = 0.0  # largest single tensor (working-set hint)
        self.mxu_macs_total = 0.0
        self.mxu_macs_aligned = 0.0
        if units:
            for cls, n in units.items():
                self.add(cls, float(n))

    # -- vector plumbing ----------------------------------------------------
    def _ensure(self, n: int) -> None:
        if self._vec.size < n:
            grown = np.zeros(max(n, len(isa.CLASS_INDEX)))
            grown[:self._vec.size] = self._vec
            self._vec = grown

    def vector(self, n: Optional[int] = None) -> np.ndarray:
        """Zero-padded copy of the unit vector, length ``n`` (default: the
        current ``CLASS_INDEX`` size)."""
        want = len(isa.CLASS_INDEX) if n is None else int(n)
        out = np.zeros(want)
        m = min(want, self._vec.size)
        out[:m] = self._vec[:m]
        return out

    @property
    def units(self) -> UnitsView:
        return UnitsView(self)

    @units.setter
    def units(self, value: Mapping[str, float]) -> None:
        _warn_units_mutation()
        self._vec = np.zeros(len(isa.CLASS_INDEX))
        for cls, n in value.items():
            self.add(cls, float(n))

    @property
    def boundary_bytes(self) -> float:
        return self.boundary_read_bytes + self.boundary_write_bytes

    # -- accumulation -------------------------------------------------------
    def add(self, cls: str, n: float) -> None:
        if n:
            i = isa.CLASS_INDEX.intern(cls)
            self._ensure(i + 1)
            self._vec[i] += float(n)

    def add_io(self, b_read: float, b_write: float, fused: float,
               mult: float = 1.0) -> None:
        """Book fusion-boundary reads/writes and fused (resident) traffic."""
        self.naive_bytes += (b_read + b_write + fused) * mult
        self.boundary_read_bytes += b_read * mult
        self.boundary_write_bytes += b_write * mult
        self.fused_bytes += fused * mult

    def add_fused_io(self, b: float, mult: float = 1.0) -> None:
        """Book traffic that never leaves the fusion (VMEM/VREG resident)."""
        self.naive_bytes += b * mult
        self.fused_bytes += b * mult

    def note_buffer(self, b: float) -> None:
        self.max_buffer_bytes = max(self.max_buffer_bytes, b)

    def merge(self, other: "OpCounts", mult: float = 1.0) -> None:
        ov = other._vec
        self._ensure(ov.size)
        if mult == 1.0:
            self._vec[:ov.size] += ov
        else:
            self._vec[:ov.size] += ov * mult
        self.naive_bytes += other.naive_bytes * mult
        self.boundary_read_bytes += other.boundary_read_bytes * mult
        self.boundary_write_bytes += other.boundary_write_bytes * mult
        self.fused_bytes += other.fused_bytes * mult
        self.flops += other.flops * mult
        self.exec_count += other.exec_count * mult
        self.dispatch_count += other.dispatch_count * mult
        self.max_buffer_bytes = max(self.max_buffer_bytes,
                                    other.max_buffer_bytes)
        self.mxu_macs_total += other.mxu_macs_total * mult
        self.mxu_macs_aligned += other.mxu_macs_aligned * mult

    def scaled(self, mult: float) -> "OpCounts":
        out = OpCounts()
        out.merge(self, mult)
        return out

    def total_units(self) -> float:
        return float(self._vec.sum())

    def as_dict(self) -> Dict[str, float]:
        d = dict(self.units.items())
        d["__naive_bytes__"] = self.naive_bytes
        d["__flops__"] = self.flops
        return d

    def __repr__(self) -> str:
        return (f"OpCounts(classes={int(np.count_nonzero(self._vec))}, "
                f"units={self.total_units():.3e}, flops={self.flops:.3e})")


# ---------------------------------------------------------------------------
# MXU accounting: MMA-generation selection + dot/conv pricing.
# ---------------------------------------------------------------------------
def mma_head(isa_gen: int, batch: float, m: float, n: float, k: float) -> str:
    """Arch-aware MMA opcode form for a dot (NSight reports HGMMA on H100
    where V100 reports HMMA — the profiler reports what the generation
    issues): gen>=2 batched dots lower to the warp-group form, gen>=1
    narrow dots to the narrow-issue form."""
    if isa_gen >= 2 and batch > 1:
        return "dot_group"
    if isa_gen >= 1 and min(m, n, k) < 128:
        return "dot_small"
    return "dot"


def add_dot(out: OpCounts, *, isa_gen: int, dt: str, batch: float, m: float,
            n: float, k: float, macs: Optional[float] = None,
            mult: float = 1.0) -> None:
    """Price one dot: MMA form, MACs, FLOPs, 128-alignment bookkeeping."""
    macs = float(batch * m * n * k) if macs is None else float(macs)
    head = mma_head(isa_gen, batch, m, n, k)
    out.add(isa.group_class(f"{head}.{dt}"), mult * macs)
    out.flops += 2.0 * macs * mult
    out.mxu_macs_total += macs * mult
    if m % 128 == 0 and n % 128 == 0 and k % 128 == 0:
        out.mxu_macs_aligned += macs * mult


def add_conv(out: OpCounts, *, dt: str, macs: float, mult: float = 1.0) -> None:
    """Price one convolution (convs are rarely 128-aligned)."""
    out.add(isa.group_class(f"conv.{dt}"), mult * macs)
    out.flops += 2.0 * macs * mult
    out.mxu_macs_total += macs * mult


# ---------------------------------------------------------------------------
# Convert-class selection (the paper's F2F family, §5.3.1).
# ---------------------------------------------------------------------------
_FLOAT_TAGS = ("f32", "bf16", "fp8")


def convert_class(src: str, dst: str) -> Optional[str]:
    """Canonical class for a dtype conversion; ``None`` when free."""
    if src == dst:
        return None
    if src in _FLOAT_TAGS and dst in _FLOAT_TAGS:
        return f"convert.{src}.{dst}"
    if src in ("int", "int4"):
        return "convert.int.float"
    return "convert.float.int"


# ---------------------------------------------------------------------------
# Collectives: wire bytes per chip as a function of the *local shard* bytes.
# The jaxpr front-end observes per-chip (shard_map) operands; the HLO
# front-end observes result shapes — ``from_result`` converts.
# ---------------------------------------------------------------------------
COLLECTIVE_WIRE = {
    "ici.all_reduce": lambda b, n: 2.0 * b * (n - 1) / max(n, 1),
    "ici.all_gather": lambda b, n: b * (n - 1),
    "ici.reduce_scatter": lambda b, n: b * (n - 1) / max(n, 1),
    "ici.all_to_all": lambda b, n: b * (n - 1) / max(n, 1),
    "ici.permute": lambda b, n: b,
}

# result bytes -> the local reference size each formula is written against
_RESULT_TO_LOCAL = {
    "ici.all_gather": lambda r, n: r / max(n, 1),   # result is n x shard
    "ici.reduce_scatter": lambda r, n: r * n,       # result is input / n
}


def collective_wire_bytes(cls: str, bytes_: float, n: int, *,
                          from_result: bool = False) -> float:
    """Per-chip wire bytes of a collective over ``n`` participants."""
    if from_result:
        bytes_ = _RESULT_TO_LOCAL.get(cls, lambda r, _n: r)(bytes_, n)
    return COLLECTIVE_WIRE[cls](bytes_, n)


def add_collective(out: OpCounts, cls: str, bytes_: float, n: int,
                   mult: float = 1.0, *, from_result: bool = False) -> None:
    if n > 1:
        out.add(cls, mult * collective_wire_bytes(cls, bytes_, n,
                                                  from_result=from_result))


# ---------------------------------------------------------------------------
# Control flow: trip-count multiplication and worst-branch pricing.
# ---------------------------------------------------------------------------
def merge_loop_body(out: OpCounts, body: OpCounts, trips: float,
                    mult: float = 1.0) -> None:
    """Fold a loop body through its trip count; book the loop control."""
    out.merge(body, mult * trips)
    out.add("ctl.loop", mult * trips)


def merge_best_branch(out: OpCounts, branches: Sequence[OpCounts],
                      mult: float = 1.0) -> None:
    """Price a conditional at its most expensive branch (both counters walk
    every branch; only the worst is charged)."""
    if branches:
        best = max(branches, key=lambda c: c.flops + c.total_units())
        out.merge(best, mult)
    out.add("ctl.cond", mult)


# ---------------------------------------------------------------------------
# Smaller shared pricing rules.
# ---------------------------------------------------------------------------
def scatter_class(isa_gen: int) -> str:
    """gen>=1 hardware issues scatter through the DMA engine."""
    return "scatter_dma" if isa_gen >= 1 else "scatter"


def sort_units(n_in: float, last_dim: float) -> float:
    """Comparison-sort work: n * log2(sorted-axis extent)."""
    return n_in * max(1.0, math.log2(max(last_dim, 2.0)))


def add_reduce(out: OpCounts, is_max: bool, n_in: float,
               mult: float = 1.0) -> None:
    """Reductions: add-style ones are FLOPs, max-style ones are not."""
    if is_max:
        out.add("reduce.max.f32", mult * n_in)
    else:
        out.add("reduce.add.f32", mult * n_in)
        out.flops += mult * n_in


# ---------------------------------------------------------------------------
# Matrix assembly over the index (solver, batched prediction).
# ---------------------------------------------------------------------------
def counts_matrix(counts: Sequence[OpCounts],
                  n: Optional[int] = None) -> np.ndarray:
    """Stack unit vectors into a ``(len(counts), n)`` matrix in one shot."""
    want = len(isa.CLASS_INDEX) if n is None else int(n)
    out = np.zeros((len(counts), want))
    for i, c in enumerate(counts):
        m = min(want, c._vec.size)
        out[i, :m] = c._vec[:m]
    return out
