"""The TPU "virtual ISA" used by the energy model.

The paper (§2.2, §3.1) models energy per SASS *instruction instance*.  TPU ops
are orders of magnitude coarser (a single ``dot`` can be 10^12 FLOPs), so the
TPU-native analogue is an **op class × work unit**: ``dot.bf16`` is priced per
MAC, ``exp.f32`` per element, ``hbm.read`` per byte, ``ici.all_reduce`` per
byte.  This keeps the paper's linear model (Eq. 3)::

    E_dynamic = sum_i  units_i * energy_i

Grouping (§3.4) maps raw (primitive, dtype, modifier) observations onto these
canonical classes exactly as the paper folds ``ISETP.GE.OR`` into
``ISETP.GE.AND`` and multi-step ``HMMA`` sequences into one instruction.

Bucketing (§3.4) assigns every class to a micro-architectural bucket (MXU,
VPU-transcendental, VPU-simple, memory, collective, control); unknown classes
inherit their bucket's mean energy.

The op-class space is indexed by the module-level ``CLASS_INDEX``, a
``ClassIndex`` assigning a stable integer id to every class name (canonical
classes first, observed-but-unknown classes interned append-only).  The id
space is the *currency axis*: ``OpCounts.units`` is a dense vector over it,
the energy table resolves to energy vectors over it, and Eq. 3 becomes the
dot product it always was.  Names remain the serialization format — ids are
process-lifetime stable, not on-disk stable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Buckets (micro-architectural components; AccelWattch-style categorisation).
# ---------------------------------------------------------------------------
BUCKET_MXU = "mxu"                # systolic array
BUCKET_VPU_SIMPLE = "vpu_simple"  # vector add/mul/cmp/select ...
BUCKET_VPU_TRANS = "vpu_trans"    # transcendental unit
BUCKET_VPU_INT = "vpu_int"        # integer/logical lane ops
BUCKET_MOVE = "move"              # on-chip data movement / layout
BUCKET_MEM = "mem"                # HBM <-> VMEM traffic
BUCKET_ICI = "ici"                # intra-pod interconnect
BUCKET_DCN = "dcn"                # cross-pod interconnect
BUCKET_CTL = "ctl"                # sequencer / loop / branch analogue

ALL_BUCKETS = (
    BUCKET_MXU, BUCKET_VPU_SIMPLE, BUCKET_VPU_TRANS, BUCKET_VPU_INT,
    BUCKET_MOVE, BUCKET_MEM, BUCKET_ICI, BUCKET_DCN, BUCKET_CTL,
)


@dataclasses.dataclass(frozen=True)
class OpClass:
    """One row of the per-instruction energy table."""

    name: str          # canonical class name, e.g. "dot.bf16"
    bucket: str        # micro-architectural bucket
    unit: str          # what one "count" means: mac | elem | byte
    isa_gen: int = 0   # first hardware generation providing this class


def _mk(name: str, bucket: str, unit: str, gen: int = 0) -> OpClass:
    return OpClass(name=name, bucket=bucket, unit=unit, isa_gen=gen)


# ---------------------------------------------------------------------------
# Canonical op classes.  ~70 classes; the square-system property (one
# microbenchmark introduced per class, paper §3.1) is enforced in the trainer.
# ---------------------------------------------------------------------------
_F = ("f32", "bf16")

OP_CLASSES: List[OpClass] = []

# MXU.
OP_CLASSES += [
    _mk("dot.bf16", BUCKET_MXU, "mac"),
    _mk("dot.f32", BUCKET_MXU, "mac"),
    _mk("dot.int8", BUCKET_MXU, "mac"),
    _mk("conv.bf16", BUCKET_MXU, "mac"),
    _mk("conv.f32", BUCKET_MXU, "mac"),
    # Newer-generation classes (paper §5.2.3: H100's HGMMA has no V100
    # microbenchmark -> bucketing must cover them).  ``dot_small`` is the
    # gen-1 narrow-issue form; ``dot_group`` is the gen-2 warp-group-MMA
    # analogue that batched application dots lower to — the microbenchmark
    # suite (designed on gen 0) never emits either, so Direct-mode coverage
    # drops on newer systems exactly as in the paper's A100/H100 studies.
    _mk("dot.fp8", BUCKET_MXU, "mac", gen=2),
    _mk("sparse_dot.bf16", BUCKET_MXU, "mac", gen=2),
    _mk("dot.int4", BUCKET_MXU, "mac", gen=1),
    _mk("dot_small.bf16", BUCKET_MXU, "mac", gen=1),
    _mk("dot_small.f32", BUCKET_MXU, "mac", gen=1),
    _mk("dot_group.bf16", BUCKET_MXU, "mac", gen=2),
    _mk("dot_group.f32", BUCKET_MXU, "mac", gen=2),
    _mk("scatter_dma", BUCKET_MOVE, "elem", gen=1),
]

# VPU transcendental.
for op in ("exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "erf", "sin",
           "cos", "pow"):
    for dt in _F:
        OP_CLASSES.append(_mk(f"{op}.{dt}", BUCKET_VPU_TRANS, "elem"))

# VPU simple arithmetic.
for op in ("add", "mul", "sub", "div", "max", "min"):
    for dt in _F:
        OP_CLASSES.append(_mk(f"{op}.{dt}", BUCKET_VPU_SIMPLE, "elem"))
OP_CLASSES += [
    _mk("cmp.f32", BUCKET_VPU_SIMPLE, "elem"),
    _mk("cmp.bf16", BUCKET_VPU_SIMPLE, "elem"),
    _mk("select.f32", BUCKET_VPU_SIMPLE, "elem"),
    _mk("select.bf16", BUCKET_VPU_SIMPLE, "elem"),
    _mk("reduce.add.f32", BUCKET_VPU_SIMPLE, "elem"),
    _mk("reduce.max.f32", BUCKET_VPU_SIMPLE, "elem"),
    _mk("cumsum.f32", BUCKET_VPU_SIMPLE, "elem"),
]

# VPU integer / logical.
for op in ("add", "mul", "and", "or", "xor", "shift"):
    OP_CLASSES.append(_mk(f"{op}.int", BUCKET_VPU_INT, "elem"))
OP_CLASSES += [
    _mk("cmp.int", BUCKET_VPU_INT, "elem"),
    _mk("select.int", BUCKET_VPU_INT, "elem"),
    _mk("rng.bits", BUCKET_VPU_INT, "elem"),
]

# Conversions — the paper's F2F case-study family (§5.3.1).
OP_CLASSES += [
    _mk("convert.f32.bf16", BUCKET_MOVE, "elem"),
    _mk("convert.bf16.f32", BUCKET_MOVE, "elem"),
    _mk("convert.int.float", BUCKET_MOVE, "elem"),
    _mk("convert.float.int", BUCKET_MOVE, "elem"),
]

# Data movement / layout.
OP_CLASSES += [
    _mk("bcast", BUCKET_MOVE, "elem"),
    _mk("transpose", BUCKET_MOVE, "elem"),
    _mk("concat", BUCKET_MOVE, "elem"),
    _mk("slice", BUCKET_MOVE, "elem"),
    _mk("dus", BUCKET_MOVE, "elem"),      # dynamic_update_slice
    _mk("gather", BUCKET_MOVE, "elem"),
    _mk("scatter", BUCKET_MOVE, "elem"),
    _mk("iota", BUCKET_MOVE, "elem"),
    _mk("pad", BUCKET_MOVE, "elem"),
    _mk("sort", BUCKET_MOVE, "elem"),
]

# Memory hierarchy traffic (the paper's L1/L2/DRAM family; on TPU the levels
# are VMEM-resident (fused) vs HBM).  Unit: bytes.  ``vmem.write`` has no
# direct microbenchmark — it is recovered by *scaling* (§3.4):
#   e(vmem.write) = e(vmem.read) * e(hbm.write) / e(hbm.read)
OP_CLASSES += [
    _mk("hbm.read", BUCKET_MEM, "byte"),
    _mk("hbm.write", BUCKET_MEM, "byte"),
    _mk("vmem.read", BUCKET_MEM, "byte"),
    _mk("vmem.write", BUCKET_MEM, "byte"),
]

# Collectives (paper §6 lists inter-GPU communication as future work; we model
# it as first-class classes — a beyond-paper extension).  Unit: bytes on the
# wire per chip.
OP_CLASSES += [
    _mk("ici.all_reduce", BUCKET_ICI, "byte"),
    _mk("ici.all_gather", BUCKET_ICI, "byte"),
    _mk("ici.reduce_scatter", BUCKET_ICI, "byte"),
    _mk("ici.all_to_all", BUCKET_ICI, "byte"),
    _mk("ici.permute", BUCKET_ICI, "byte"),
    _mk("dcn.transfer", BUCKET_DCN, "byte"),
]

# Control overhead (BRA/loop analogue): priced per executed loop iteration.
OP_CLASSES += [
    _mk("ctl.loop", BUCKET_CTL, "elem"),
    _mk("ctl.cond", BUCKET_CTL, "elem"),
]

CLASS_BY_NAME: Dict[str, OpClass] = {c.name: c for c in OP_CLASSES}


def classes_for_gen(isa_gen: int) -> List[OpClass]:
    """Classes that exist on a given hardware generation."""
    return [c for c in OP_CLASSES if c.isa_gen <= isa_gen]


# ---------------------------------------------------------------------------
# Grouping (§3.4): raw observation -> canonical class.
# ---------------------------------------------------------------------------
# dtype folding: f64 is emulated on TPU but grouped with f32 energy; f16
# behaves like bf16; every int width shares the int lane class.
_DTYPE_GROUP = {
    "float64": "f32", "float32": "f32", "float16": "bf16", "bfloat16": "bf16",
    "float8_e4m3fn": "fp8", "float8_e5m2": "fp8",
    "int64": "int", "int32": "int", "int16": "int", "int8": "int",
    "uint64": "int", "uint32": "int", "uint16": "int", "uint8": "int",
    "int4": "int4", "uint4": "int4",
    "bool": "int",
}

# primitive-name folding (modifier folding, HMMA-sequence analogue).
_PRIM_GROUP = {
    "log1p": "log", "expm1": "exp", "exp2": "exp", "log2": "log",
    "cbrt": "rsqrt", "atan2": "pow", "tan": "sin", "asin": "sin",
    "acos": "cos", "atan": "sin", "sinh": "sin", "cosh": "cos",
    "erfc": "erf", "erf_inv": "erf", "logistic": "logistic",
    "integer_pow": "pow",
    "shift_left": "shift", "shift_right_logical": "shift",
    "shift_right_arithmetic": "shift",
    "rem": "div", "nextafter": "add",
    "neg": "sub", "abs": "max", "sign": "cmp", "floor": "max",
    "ceil": "max", "round": "max", "clamp": "max", "not": "xor",
    "is_finite": "cmp", "square": "mul",
}


def group_dtype(dtype_name: str) -> str:
    return _DTYPE_GROUP.get(dtype_name, "f32")


def group_class(raw_name: str) -> str:
    """Fold a raw ``{prim}.{dtype}`` observation onto a canonical class name.

    Returns the canonical name even if it is not in the table — coverage
    machinery (bucketing) handles unknown-but-bucketable classes.
    """
    if raw_name in CLASS_BY_NAME:
        return raw_name
    if "." in raw_name:
        prim, _, rest = raw_name.partition(".")
        folded = _PRIM_GROUP.get(prim, prim)
        cand = f"{folded}.{rest}"
        if cand in CLASS_BY_NAME:
            return cand
        # int ops all share the integer lane classes.
        if rest == "int" and f"{folded}.int" in CLASS_BY_NAME:
            return f"{folded}.int"
        return cand
    return raw_name


def bucket_of(class_name: str) -> Optional[str]:
    """Bucket for a (possibly unknown) class name.

    Known classes use their table bucket; unknown classes are bucketed by
    structural rules — the paper's "categorize the unknown instruction into a
    micro-architectural bucket" step.
    """
    c = CLASS_BY_NAME.get(class_name)
    if c is not None:
        return c.bucket
    head = class_name.split(".", 1)[0]
    if head in ("dot", "conv", "sparse_dot"):
        return BUCKET_MXU
    if head in ("exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "erf",
                "sin", "cos", "pow"):
        return BUCKET_VPU_TRANS
    if class_name.endswith(".int") or head in ("and", "or", "xor", "shift",
                                               "rng"):
        return BUCKET_VPU_INT
    if head in ("add", "mul", "sub", "div", "max", "min", "fma", "cmp",
                "select", "reduce", "cumsum"):
        return BUCKET_VPU_SIMPLE
    if head in ("convert", "bcast", "transpose", "concat", "slice", "dus",
                "gather", "scatter", "iota", "pad", "sort", "topk", "rev"):
        return BUCKET_MOVE
    if head in ("hbm", "vmem"):
        return BUCKET_MEM
    if head == "ici":
        return BUCKET_ICI
    if head == "dcn":
        return BUCKET_DCN
    if head == "ctl":
        return BUCKET_CTL
    return None


# ---------------------------------------------------------------------------
# The canonical class index: stable int id per op class.
# ---------------------------------------------------------------------------
UNKNOWN_BUCKET = "unknown"
BUCKET_ORDER = ALL_BUCKETS + (UNKNOWN_BUCKET,)
BUCKET_CODE: Dict[str, int] = {b: i for i, b in enumerate(BUCKET_ORDER)}


class ClassIndex:
    """Append-only ``class name -> int id`` map over the op-class space.

    Canonical classes (``OP_CLASSES``) occupy the leading ids in table
    order; any raw class observed by a counter (unknown primitives kept for
    the bucketing machinery) is interned on first sight and keeps its id for
    the process lifetime.  Because the index only ever grows, a vector of
    length ``n`` taken at any earlier time stays valid — longer vectors are
    zero-padded extensions, never re-orderings.

    Bucket membership is exposed as an int-code array (``bucket_codes``)
    aligned with the id space, so per-bucket reductions are ``np.bincount``
    calls instead of per-key ``bucket_of`` walks.
    """

    def __init__(self, names: Iterable[str] = ()):
        self._id: Dict[str, int] = {}
        self._names: List[str] = []
        self._bucket_code_list: List[int] = []
        self._bucket_codes_arr = np.empty(0, dtype=np.intp)
        for n in names:
            self.intern(n)

    def intern(self, name: str) -> int:
        """Id for ``name``, assigning the next id on first sight."""
        i = self._id.get(name)
        if i is None:
            i = len(self._names)
            self._id[name] = i
            self._names.append(name)
            self._bucket_code_list.append(
                BUCKET_CODE.get(bucket_of(name), BUCKET_CODE[UNKNOWN_BUCKET]))
        return i

    def id(self, name: str) -> Optional[int]:
        """Id for ``name`` if already interned, else ``None``."""
        return self._id.get(name)

    def __len__(self) -> int:
        return len(self._names)

    def __contains__(self, name: str) -> bool:
        return name in self._id

    def name(self, i: int) -> str:
        return self._names[i]

    def names(self, n: Optional[int] = None) -> List[str]:
        """The first ``n`` (default: all) class names, id order."""
        return self._names[:len(self._names) if n is None else n]

    def bucket_codes(self, n: Optional[int] = None) -> np.ndarray:
        """``BUCKET_ORDER`` code per class id, as an array of length ``n``."""
        want = len(self._names) if n is None else n
        if self._bucket_codes_arr.size < want:
            self._bucket_codes_arr = np.asarray(self._bucket_code_list,
                                                dtype=np.intp)
        return self._bucket_codes_arr[:want]

    def bucket_ids(self, bucket: str, n: Optional[int] = None) -> np.ndarray:
        """Ids (ascending) of the classes in ``bucket``."""
        codes = self.bucket_codes(n)
        return np.nonzero(codes == BUCKET_CODE[bucket])[0]


#: The process-wide index.  Canonical classes first (stable leading ids),
#: observed raw classes interned append-only by the counters.
CLASS_INDEX = ClassIndex(c.name for c in OP_CLASSES)
