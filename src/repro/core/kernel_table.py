"""The second calibration tier: measured J/op per kernel launch config.

The class-level ``EnergyTable`` prices *op classes*; this table prices
*whole kernel launches* — (kernel, variant, block config, operating point)
→ measured joules per call and per logical op.  It is the persistence
layer behind the block-size autotuner (``repro.kernels.autotune``): staged
micro-calibration fills it, ``block_config="auto"`` reads the winner back,
and the ``TableStore`` ships it alongside the class table as
``<system>__kernels__v1.json``.

Pure stdlib + dataclasses on purpose: telemetry shard workers and the
``TableStore`` import this module, and neither may pay for (or depend on)
jax at startup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

KERNEL_SCHEMA_VERSION = 1


class KernelTableError(ValueError):
    """A serialized kernel table has an alien or stale schema."""


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One measured launch configuration."""

    kernel: str                    # e.g. "flash_attention"
    variant: str                   # "pallas" | "ref"
    config: Tuple[int, ...]        # block sizes ((), for ref)
    point: Optional[str]           # operating-point tag ("f940c170") | None
    j_per_op: float                # the autotuner's objective
    j_per_call: float
    latency_s: float               # wall-clock per call (ceiling constraint)
    ops_per_call: float            # fixed logical ops (config-independent)
    energy_j: float                # median measured run total
    duration_s: float              # measured run duration
    iters: int                     # calls folded into the run
    spec_id: str                   # measurement record / noise-substream id

    @property
    def key(self) -> Tuple[str, str, Tuple[int, ...], Optional[str]]:
        return (self.kernel, self.variant, tuple(self.config), self.point)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["config"] = list(self.config)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "KernelEntry":
        d = dict(d)
        d["config"] = tuple(int(c) for c in d.get("config", ()))
        return cls(**d)


class KernelEnergyTable:
    """All measured kernel entries for one system."""

    def __init__(self, system: str,
                 entries: Optional[List[KernelEntry]] = None):
        self.system = system
        self._entries: Dict[tuple, KernelEntry] = {}
        for e in entries or []:
            self.put(e)

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, entry: KernelEntry) -> None:
        self._entries[entry.key] = entry

    def get(self, kernel: str, variant: str, config,
            point: Optional[str] = None) -> Optional[KernelEntry]:
        return self._entries.get((kernel, variant, tuple(config), point))

    def entries(self, kernel: Optional[str] = None,
                point: Optional[str] = "__any__",
                variant: Optional[str] = None) -> List[KernelEntry]:
        """Entries filtered by kernel/point/variant (point="__any__": all)."""
        out = []
        for e in self._entries.values():
            if kernel is not None and e.kernel != kernel:
                continue
            if point != "__any__" and e.point != point:
                continue
            if variant is not None and e.variant != variant:
                continue
            out.append(e)
        return sorted(out, key=lambda e: (e.kernel, e.variant, e.config,
                                          e.point or ""))

    def best(self, kernel: str, *, point: Optional[str] = None,
             latency_ceiling_s: Optional[float] = None,
             variant: Optional[str] = None) -> Optional[KernelEntry]:
        """Minimum-J/op entry under the latency ceiling.

        Entries measured at the requested operating point are preferred;
        when the point has no entries at all, the nominal (``point=None``)
        entries answer instead — a tuned block is a better default than an
        untuned one even off its calibration point.
        """
        cands = self.entries(kernel, point=point, variant=variant)
        if not cands and point is not None:
            cands = self.entries(kernel, point=None, variant=variant)
        if latency_ceiling_s is not None:
            cands = [e for e in cands if e.latency_s <= latency_ceiling_s]
        if not cands:
            return None
        return min(cands, key=lambda e: (e.j_per_op, e.latency_s))

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": KERNEL_SCHEMA_VERSION,
            "system": self.system,
            "entries": [e.to_dict() for e in self.entries()],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "KernelEnergyTable":
        version = d.get("schema")
        if version != KERNEL_SCHEMA_VERSION:
            raise KernelTableError(
                f"kernel table schema {version!r} != "
                f"{KERNEL_SCHEMA_VERSION}")
        return cls(d["system"],
                   [KernelEntry.from_dict(e) for e in d.get("entries", [])])
