"""Quickstart: the paper in one page.

Train a per-op energy table on the simulated v5e (microbenchmarks +
steady-state measurement + non-negative solve), then predict and attribute
the energy of a workload it has never seen.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import opcount, predict
from repro.core.trainer import train_table
from repro.hw import Program, get_device

# --- training phase (paper Fig. 2 top): ~76 microbenchmarks, solved jointly
table = train_table("sim-v5e-air")
print(f"table: {len(table.direct)} direct classes, "
      f"P_const={table.p_const:.1f}W P_static={table.p_static:.1f}W "
      f"residual={table.meta['residual_rel']:.4f}")

# --- an application the table has never seen
def my_app(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    return jnp.sum(jax.nn.softmax(h @ w2, axis=-1))

args = (jax.ShapeDtypeStruct((8192, 1024), jnp.bfloat16),
        jax.ShapeDtypeStruct((1024, 4096), jnp.bfloat16),
        jax.ShapeDtypeStruct((4096, 1024), jnp.bfloat16))
counts = opcount.count_fn(my_app, *args)

# --- ground truth from the device (NVML analogue) vs Wattchmen prediction
dev = get_device("sim-v5e-air")
rec = dev.run(Program("my_app", counts,
                      iters=dev.iters_for_duration(counts, 30.0)))
pred = predict.predict(table, counts.scaled(rec.iters), rec.duration_s,
                       counters=rec.counters)

print(f"\nmeasured : {rec.energy_counter_j:10.1f} J")
print(f"predicted: {pred.total_j:10.1f} J "
      f"({100 * (pred.total_j / rec.energy_counter_j - 1):+.1f}%)")
print(f"coverage : {pred.coverage:.1%} of dynamic energy from direct entries")
print("\ntop energy consumers:")
for cls, e in pred.top_classes(6):
    print(f"  {cls:20s} {e:10.2f} J")
print("\nby bucket:")
for b, e in sorted(pred.by_bucket.items(), key=lambda kv: -kv[1]):
    print(f"  {b:12s} {e:10.2f} J")
