"""Quickstart: the paper in three lines.

``EnergyModel`` is the whole surface: ``from_store`` loads the trained
per-op energy table from the persistent table store (training it once — the
~76-microbenchmark suite + non-negative solve — if this is the first run on
this machine), ``compare`` measures a workload on the device and predicts
its energy from the same profile, and ``attribute`` breaks the energy down
per op class and per micro-architectural bucket.

    PYTHONPATH=src python examples/quickstart.py

Run it twice: the second invocation loads the table from the store
(``~/.cache/repro/tables`` or ``$REPRO_TABLE_STORE``) in milliseconds
instead of re-training.
"""
import time

import jax
import jax.numpy as jnp

from repro import EnergyModel, default_store

# --- training phase (paper Fig. 2 top) — or a store hit on the second run
t0 = time.time()
cold = not default_store().path_for("sim-v5e-air").exists()
model = EnergyModel.from_store("sim-v5e-air")
print(f"{model} [{'trained' if cold else 'loaded from store'} "
      f"in {time.time() - t0:.2f}s]")


# --- an application the table has never seen
def my_app(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    return jnp.sum(jax.nn.softmax(h @ w2, axis=-1))


args = (jax.ShapeDtypeStruct((8192, 1024), jnp.bfloat16),
        jax.ShapeDtypeStruct((1024, 4096), jnp.bfloat16),
        jax.ShapeDtypeStruct((4096, 1024), jnp.bfloat16))

# --- ground truth from the device (NVML analogue) vs Wattchmen prediction
cmp = model.compare(my_app, *args, target_seconds=30.0)
pred = cmp.prediction

print(f"\nmeasured : {cmp.measured_j:10.1f} J")
print(f"predicted: {cmp.predicted_j:10.1f} J ({cmp.error_pct:+.1f}%)")
print(f"coverage : {pred.coverage:.1%} of dynamic energy from direct entries")
print("\ntop energy consumers:")
for cls, e in pred.top_classes(6):
    print(f"  {cls:20s} {e:10.2f} J")
print("\nby bucket:")
for b, e in sorted(pred.by_bucket.items(), key=lambda kv: -kv[1]):
    print(f"  {b:12s} {e:10.2f} J")
