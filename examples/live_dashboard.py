"""Live monitoring dashboard: streaming telemetry on a serving fleet.

Two simulated devices run workloads under the full streaming pipeline —
background-style power sampling, MTSM-style per-step marker alignment,
measured-vs-predicted attribution with drift detection — aggregated by one
``TelemetryService`` (the JSON snapshot a real dashboard would poll).

One device is healthy; the other has drifted silicon (its true per-op
energies run 40% hot against the trained table — an aged part or a
firmware DVFS change).  Watch the drift detector flag it and the
recalibration trigger repair the table live.

Ingestion is chunked: the monitor loop calls ``service.poll_all`` to drain
every session's sampler a few array-chunks at a time (sub-µs per sample
through the whole pipeline), rendering a fleet snapshot between passes —
exactly the cadence of a real dashboard refreshing while collectors pour
telemetry in.

    PYTHONPATH=src python examples/live_dashboard.py
"""
import jax
import jax.numpy as jnp

from repro import EnergyModel, TelemetryService
from repro.hw.device import SimDevice
from repro.hw.systems import SYSTEMS


def decode_like(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    return jnp.sum(jax.nn.softmax(h @ w2, axis=-1))


ARGS = (jax.ShapeDtypeStruct((2048, 1024), jnp.bfloat16),
        jax.ShapeDtypeStruct((1024, 2048), jnp.bfloat16),
        jax.ShapeDtypeStruct((2048, 1024), jnp.bfloat16))

service = TelemetryService()

# -- node 0: healthy -------------------------------------------------------
CHUNK = 64        # small chunks so the poll cadence is visible in a demo

model = EnergyModel.from_store("sim-v5e-air")
prof = model.profile(decode_like, *ARGS)
healthy = model.monitor(live=True, step_counts=prof.counts,
                        telemetry_chunk=CHUNK)
service.register(healthy.live, key="node0/decode")

# -- node 1: drifted silicon (same table, coefficients 40% hot) ------------
cfg = SYSTEMS["sim-v5e-air"]
drifted_model = EnergyModel.from_store("sim-v5e-air")
drifted_model._device = SimDevice(cfg.chip, cfg.cooling, cfg.seed,
                                  name="sim-v5e-air-aged", coeff_scale=1.4)
aged = drifted_model.monitor(live=True, step_counts=prof.counts,
                             telemetry_chunk=CHUNK)
service.register(aged.live, key="node1/decode")

# -- the "serving loops": each decode step is an MTSM sync point -----------
STEPS = 32
for i in range(STEPS):
    healthy.live.step(i, work_units=2048)
    aged.live.step(i, work_units=2048)

# anchor node1's drift baseline on a healthy shakedown run of the same
# workload (in production this is the burn-in history of the part)
aged.live.attributor.detector.baseline = 1.0

# -- chunked consume loop: one poll_all pass drains the whole fleet --------
healthy.live.start()
aged.live.start()
passes = 0
while service.poll_all(max_chunks=4):
    passes += 1
    snap = service.snapshot()["fleet"]
    print(f"[poll {passes:2d}] {snap['samples']:5d} samples in  "
          f"{snap['measured_j']:9.1f} J measured  "
          f"drifting={snap['drifting'] or '-'}")

for mon, label in ((healthy, "node0"), (aged, "node1")):
    s = mon.live.finish()        # already drained: just the summary
    flag = " ** DRIFT -> recalibrated **" if s.recalibrations else ""
    print(f"[{label}] {s.steps} steps  measured {s.measured_total_j:9.1f} J  "
          f"predicted {s.predicted_total_j:9.1f} J  "
          f"MAPE {s.mape_pct:5.1f}%{flag}")
    for rec in mon.records[:3]:
        print(f"    step {rec.step}: measured {rec.measured_j:8.2f} J, "
              f"predicted {rec.prediction.total_j:8.2f} J "
              f"({rec.error_pct:+.1f}%)")

print("\ntop measured consumers (node0):")
for cls, e in healthy.live.attributor.top_measured_classes(5):
    print(f"  {cls:20s} {e:10.2f} J")

print("\nfleet snapshot (what a dashboard polls):")
print(service.to_json(indent=1))
