"""Sweet-spot governor dashboard: convergence, then a workload shift.

The closed loop on the frequency axis, end to end: a (freq, power-cap)
family is calibrated on the simulated v5e, a ``SweetSpotGovernor`` explores
the candidate grid on a decode-heavy workload and settles on the measured
J/token argmin under a tokens/s SLA — then the workload mix shifts under
it (the decode batch turns MXU-heavy) and the staleness check notices the
measured J/work no longer matches what it converged on, forcing a
re-exploration and a *new* sweet spot.

Every proposal/hold/switch/re-explore decision is printed as it happens,
and the final ``TelemetryService``-style governor snapshot is dumped at
the end (the JSON a real dashboard would poll).

    PYTHONPATH=src python examples/sweet_spot_dashboard.py
"""
import json

from repro import EnergyModel
from repro.core.opcount import OpCounts
from repro.dvfs import GovernorConfig, SweetSpotGovernor, default_sweep_points


def decode_counts() -> OpCounts:
    """Boundary-traffic-heavy: the memory-bound decode regime."""
    c = OpCounts()
    c.add("dot.bf16", 2e8)
    c.mxu_macs_total = c.mxu_macs_aligned = 2e8
    c.add("exp.f32", 1e6)
    c.add("add.f32", 5e6)
    c.boundary_read_bytes = 4e6
    c.boundary_write_bytes = 2e6
    c.naive_bytes = 8e6
    c.fused_bytes = 2e6
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


def prefill_counts() -> OpCounts:
    """MXU-heavy: the compute-bound prefill regime (the shifted mix)."""
    c = OpCounts()
    c.add("dot.bf16", 6e9)
    c.mxu_macs_total = c.mxu_macs_aligned = 6e9
    c.add("exp.f32", 2e7)
    c.add("add.f32", 4e7)
    c.boundary_read_bytes = 1e7
    c.boundary_write_bytes = 5e6
    c.naive_bytes = 2e7
    c.fused_bytes = 6e6
    c.max_buffer_bytes = 8e6
    c.dispatch_count = 3
    return c


TOKENS_PER_STEP = 64.0

model = EnergyModel.from_store("sim-v5e-air")
points = default_sweep_points(model.device, n=3)
fam = {(f, c) for f, c, _ in model.table.family() if f is not None}
if any(p not in fam for p in points):
    print(f"[calib] sweeping {len(points)} operating points "
          f"({', '.join(f'{f:g}' for f, _ in points)} MHz) ...")
    model.calibrate_points(points=points, duration_s=3.0, repeats=2)

gov = SweetSpotGovernor(points, GovernorConfig(sla_work_per_s=None))


def show(run, label):
    for r in run.rounds:
        print(f"  [{label} round {r.round}] f={r.freq_mhz:g} MHz "
              f"({r.reason:10s}) {r.j_per_work:.3e} J/token  "
              f"{r.work_per_s:,.0f} tokens/s")
    pt = run.final_point
    print(f"  -> holding f={pt[0]:g} MHz "
          f"({'converged' if run.converged else 'still exploring'})\n")


# -- phase 1: converge on the decode mix -----------------------------------
print("phase 1: decode-heavy workload — explore the grid, find the knee")
run1 = model.govern(decode_counts(), gov, rounds=8, steps=3,
                    work_units=TOKENS_PER_STEP, min_duration_s=6.0,
                    name="dash-decode")
show(run1, "decode")
settled = run1.final_point

# -- phase 2: the mix shifts under the governor ----------------------------
print("phase 2: workload shifts MXU-heavy under the governor — the J/work "
      "it converged on\nis stale, the deviation check trips, and it "
      "re-explores")
run2 = model.govern(prefill_counts(), gov, rounds=8, steps=3,
                    work_units=TOKENS_PER_STEP, min_duration_s=6.0,
                    name="dash-prefill")
show(run2, "prefill")

re_explored = any(r.reason in ("re-explore", "explore") for r in run2.rounds)
moved = run2.final_point != settled
print(f"workload shift {'re-triggered exploration' if re_explored else 'was absorbed'}"
      + (f"; sweet spot moved {settled[0]:g} -> {run2.final_point[0]:g} MHz"
         if moved else f"; sweet spot stayed at {settled[0]:g} MHz"))

print("\ngovernor snapshot (what a dashboard polls):")
print(json.dumps(gov.snapshot(history=8), indent=1))
