"""End-to-end LM training with checkpoint/restart, straggler monitoring and
per-step energy attribution — the production loop of ``repro.launch.train``.

Default is a reduced qwen2-family config for CPU speed; ``--d-model 512
--layers 12 --steps 300`` trains a ~100M-param model for a few hundred
steps (the full-scale exercise; budget ~30 min on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses

from repro import configs as cfgs
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.d_model or args.layers:
        base = cfgs.get_smoke_config(args.arch)
        cfg = dataclasses.replace(
            base, d_model=args.d_model or base.d_model,
            n_layers=args.layers or base.n_layers,
            d_ff=4 * (args.d_model or base.d_model))
        cfgs._MODULES[args.arch].SMOKE = cfg   # run with the resized config

    state, losses, monitor = run(
        args.arch, smoke=True, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
        ckpt_every=20)
    print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    if monitor is not None:
        print("energy top consumers over the run:")
        for cls, e in monitor.top_consumers(5):
            print(f"  {cls:20s} {e:9.3f} J")


if __name__ == "__main__":
    main()
