"""Energy audit — the paper's Backprop case study (§5.3.1) as a workflow.

A training kernel accidentally runs in f32 because one constant was created
with the "system default" dtype (the paper's ``#define``-double bug, TPU
edition: a strong-typed f32 scalar upcasts the whole bf16 graph).
Wattchmen's per-class breakdown points straight at ``dot.f32`` +
``convert.bf16.f32``; one line later the kernel is ~30% cheaper.

    PYTHONPATH=src python examples/energy_audit.py
"""
import jax
import jax.numpy as jnp

from repro import EnergyModel

SCALE_BUGGY = jnp.float32(0.125)      # strong f32: silently upcasts bf16!
SCALE_FIXED = 0.125                   # weak python float: stays bf16

MODEL = EnergyModel.from_store("sim-v5e-air")


def make_backprop(scale):
    def backprop_k2(x, w1, w2, y):
        def loss(w1, w2):
            h = jnp.tanh((x @ w1) * scale)
            o = jax.nn.sigmoid(h @ w2)
            return jnp.mean((o - y) ** 2)
        g1, g2 = jax.grad(loss, argnums=(0, 1))(w1, w2)
        return g1.sum() + g2.sum()
    return backprop_k2


def audit(fn, iters=None):
    """Profile + measure + predict one variant.  Both variants are the same
    application on the same inputs, so they share the Program name and run
    the same iteration count (energy for equal work, as in the paper)."""
    args = (jax.ShapeDtypeStruct((65536, 512), jnp.bfloat16),
            jax.ShapeDtypeStruct((512, 2048), jnp.bfloat16),
            jax.ShapeDtypeStruct((2048, 64), jnp.bfloat16),
            jax.ShapeDtypeStruct((65536, 64), jnp.bfloat16))
    cmp = MODEL.compare(fn, *args, target_seconds=30.0, iters=iters,
                        name="backprop_k2")
    return cmp.record, cmp.prediction, cmp.record.iters


rec_bug, pred_bug, n_iters = audit(make_backprop(SCALE_BUGGY))
print("=== buggy kernel: Wattchmen breakdown ===")
for cls, e in pred_bug.top_classes(6):
    print(f"  {cls:22s} {e:10.2f} J")
flagged = [c for c, _ in pred_bug.top_classes(6)
           if c.endswith(".f32") and c.startswith(("dot", "convert"))]
print(f"\n-> f32 compute in a bf16 model: {flagged} — precision bug!\n")

rec_fix, pred_fix, _ = audit(make_backprop(SCALE_FIXED), iters=n_iters)
saved_meas = 1 - rec_fix.energy_counter_j / rec_bug.energy_counter_j
saved_pred = 1 - pred_fix.total_j / pred_bug.total_j
print(f"measured  energy: {rec_bug.energy_counter_j:9.0f} J -> "
      f"{rec_fix.energy_counter_j:9.0f} J  ({saved_meas:+.1%} saved)")
print(f"predicted energy: {pred_bug.total_j:9.0f} J -> "
      f"{pred_fix.total_j:9.0f} J  ({saved_pred:+.1%} saved)")
