"""Kernel energy microscopy + J/op autotuning, end to end.

Two instruments on the same workload:

1. ``EnergyModel.microscope`` — per-launch marker windows subdivide each
   step's aligned energy into one window per kernel launch (plus the
   ``__unattributed__`` remainder), tiling the step's measured joules
   *bitwise*.  Where the class table answers "which op classes cost what",
   the microscope answers "which launches cost what" — on measured energy,
   not model output.
2. ``EnergyModel.tune_kernel`` — staged J/op search over block configs.
   The winner persists in the kernel tier of the table store, and any
   ``block_config="auto"`` call site silently picks it up.

The script tunes ``flash_attention``, then microscopes a decode-style
step before and after, showing the tuned launch getting cheaper while the
tiling invariant holds in both worlds.

    PYTHONPATH=src python examples/kernel_microscope.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro import EnergyModel
from repro.core.store import TableStore
from repro.kernels import autotune, ops

MODEL = EnergyModel.from_store("sim-v5e-air")

B, S, H, D = 1, 1024, 4, 64


def flash_launch(block_config=None):
    shape = jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)

    def fa(q, k, v):
        return ops.flash_attention(q, k, v, causal=True, interpret=True,
                                   block_config=block_config)
    return MODEL.profile(fa, shape, shape, shape)


def mlp_launch():
    x = jax.ShapeDtypeStruct((B * S, 512), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((512, 2048), jnp.bfloat16)
    return MODEL.profile(lambda x, w: jnp.tanh(x @ w), x, w)


def microscope(tag, flash_cfg):
    rep = MODEL.microscope(
        [("flash_attention", flash_launch(flash_cfg), "pallas",
          flash_cfg or ()),
         ("mlp", mlp_launch())],
        steps=6, name=f"microscope-{tag}", recalibrate=None)
    print(f"\n== {tag}: per-launch energy over "
          f"{rep.summary.steps} steps ==")
    for name, d in sorted(rep.kernels.items(),
                          key=lambda kv: -kv[1]["energy_j"]):
        cfg = "x".join(map(str, d["config"])) or "-"
        print(f"  {name:<22} {d['variant'] or '-':<7} cfg={cfg:<9} "
              f"{d['energy_j']:10.2f} J   {d['j_per_launch']:.3e} J/launch")
    tiled = sum(d["energy_j"] for d in rep.kernels.values())
    print(f"  {'sum of kernel windows':<41} {tiled:10.2f} J")
    print(f"  {'attributed step energy':<41} {rep.attributed_j:10.2f} J")
    # per-step tiling is bitwise; the per-kernel regrouping across steps
    # reorders the sum, so the aggregate recomposes to float tolerance
    assert rep.tiling_exact, "kernel windows must tile steps bitwise"
    assert abs(tiled - rep.attributed_j) <= 1e-9 * rep.attributed_j, \
        "tiled energies must sum to the attributed total"
    print("  tiling: exact (bitwise per step)")
    return rep


def main():
    before = microscope("default blocks", None)

    with tempfile.TemporaryDirectory() as tmp:
        print("\n== tuning flash_attention (staged J/op search) ==")
        res = MODEL.tune_kernel("flash_attention", store=TableStore(tmp),
                                shape={"b": B, "s": S, "h": H, "d": D},
                                durations=(2.0, 4.0), repeats=(1, 2))
        for e in res.entries:
            cfg = "x".join(map(str, e.config)) or e.variant
            mark = " <- winner" if e.key == res.winner.key else \
                   (" (shipped default)" if e.key == res.default.key else "")
            print(f"  {cfg:<10} {e.j_per_op:.3e} J/op  "
                  f"{e.latency_s * 1e6:8.1f} us/call{mark}")
        print(f"  improvement vs default: {res.improvement * 100.0:+.1f}%"
              + ("  (the shipped default is already optimal here)"
                 if res.winner.key == res.default.key else ""))

        # the tuned table is now active: "auto" call sites pick the winner
        cfg = autotune.best_config("flash_attention")
        after = microscope(f"tuned blocks {cfg}", cfg)

    # the honest before/after: winner vs default under the tuner's shared
    # protocol (microscope runs are separate measurements with their own
    # sensor noise, so their deltas are not a matched comparison)
    print(f"\nflash_attention J/call, matched protocol: "
          f"{res.default.j_per_call:.3e} (default) -> "
          f"{res.winner.j_per_call:.3e} (tuned), "
          f"{res.improvement * 100.0:+.1f}%")
    for tag, rep in (("before", before), ("after", after)):
        d = rep.kernels["flash_attention"]
        print(f"  microscope {tag}: {d['j_per_launch']:.3e} J/launch "
              f"over {d['windows']} step windows")
    autotune.set_active(None)


if __name__ == "__main__":
    main()
