"""Tenant billing walkthrough: energy-aware batching with a J/token cap.

Two identical multi-tenant workloads run against the simulated v5e
device; the second adds a J/token budget sitting just above the predicted
cost of a 2-wide decode batch.  The workload's op counts include
cross-request cache interference (superlinear per-batch work), so packing
the batch wider *raises* predicted J/token — exactly the regime where the
budget bites: the capped run refuses to pack past 2, defers the rest, and
its per-tenant bills land at a lower J/token.

    PYTHONPATH=src python examples/tenant_billing.py
"""
from repro import EnergyModel
from repro.serve import EnergyPolicy, Request, synthetic_counts_fn


def workload():
    return [
        Request("alpha-0", "alpha", prompt_len=16, max_new=12,
                arrival_step=0),
        Request("alpha-1", "alpha", prompt_len=8, max_new=10,
                arrival_step=0),
        Request("beta-0", "beta", prompt_len=12, max_new=12, arrival_step=0),
        Request("beta-1", "beta", prompt_len=8, max_new=8, arrival_step=1),
        Request("gamma-0", "gamma", prompt_len=24, max_new=16,
                arrival_step=3),
    ]


def main():
    counts = synthetic_counts_fn(interference=0.5)
    base = EnergyModel.from_store("sim-v5e-air")

    # price the decode batch at each width: interference makes J/token rise
    probe = base.serve(counts, min_phase_seconds=2.0)
    print("predicted decode J/token by batch width:")
    for b in (1, 2, 3, 4):
        print(f"  batch {b}: {probe.predict_j_per_token(b):.3e} J/token")
    budget = probe.predict_j_per_token(2) * 1.05
    print(f"budget: {budget:.3e} J/token (5% above the 2-wide cost)\n")

    reports = {}
    for label, policy in [
        ("uncapped", EnergyPolicy(max_batch=4)),
        ("capped", EnergyPolicy(max_batch=4, budget_j_per_token=budget)),
    ]:
        # fork the model per run (copy-on-repair): drift repair rescales
        # the bound table in place, and one run's repair must not re-price
        # the other's budget — the fork shares the device but owns its table
        model = base.fork()
        server = model.serve(counts, policy=policy, min_phase_seconds=2.0,
                             name=f"billing/{label}")
        report = server.run(workload())
        reports[label] = report
        widest = max(p.batch for p in report.phases if p.kind == "decode")
        defers = [e for e in report.events if e.event == "defer"]
        print(f"== {label}: widest decode batch {widest}, "
              f"{len(defers)} deferrals ==")
        for e in defers[:3]:
            print(f"  step {e.step}: defer {e.request_id} ({e.detail})")
        print(report.table())
        for t, bill in report.billing.bills.items():
            print(f"[bill] {t}: {bill.measured_j:.4e} J over "
                  f"{bill.requests} requests, "
                  f"{bill.j_per_token:.3e} J/token "
                  f"(residual {bill.residual_j:+.3e} J)")
        print()

    for label, report in reports.items():
        jpt = report.measured_total_j / sum(
            b.scaled_tokens for b in report.billing.bills.values())
        print(f"{label}: {report.measured_total_j:.4e} J total, "
              f"{jpt:.3e} J/token fleet-wide")
    capped_widest = max(p.batch for p in reports["capped"].phases
                        if p.kind == "decode")
    assert capped_widest <= 2, "budget failed to cap the decode batch"
    print("\nthe J/token budget held the decode batch at "
          f"{capped_widest} wide; every joule above it was deferred, "
          "not spent")


if __name__ == "__main__":
    main()
