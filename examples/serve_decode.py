"""Batched serving with per-token energy attribution.

Serves the attention-free mamba2 family by default (O(1) decode state), and
prints joules/token from the Wattchmen table next to the throughput.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
"""
import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    out, monitor = run(args.arch, smoke=True, batch=args.batch,
                       prompt_len=args.prompt_len, max_new=args.max_new)
    if monitor is not None and monitor.records:
        per_tok = monitor.records[-1].joules_per_unit_work
        print(f"predicted {per_tok:.3e} J/token at this batch size")


if __name__ == "__main__":
    main()
