"""Batched serving with per-request energy attribution.

Serves the attention-free mamba2 family by default (O(1) decode state) and
prints the per-request energy ledger: measured and predicted joules per
request from the Wattchmen table + simulated telemetry.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
"""
import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    report, _ = run(args.arch, smoke=True, tenants=args.tenants,
                    requests=args.requests, prompt_len=args.prompt_len,
                    max_new=args.max_new)
    busiest = max(report.requests, key=lambda r: r.measured_j)
    print(f"most expensive request: {busiest.request.id} "
          f"({busiest.measured_j:.3e} J, {busiest.j_per_token:.3e} J/token)")


if __name__ == "__main__":
    main()
