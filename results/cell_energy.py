import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-cell energy predictions: the Wattchmen table applied to every
(arch x shape) step program — pod-level J/step and J/token on 256 chips.

    PYTHONPATH=src python results/cell_energy.py > results/cell_energy.md
"""
import json       # noqa: E402
import pathlib    # noqa: E402

import jax        # noqa: E402

from repro import configs as cfgs                     # noqa: E402
from repro.api import EnergyModel, PredictJob         # noqa: E402
from repro.configs.base import SHAPES, shape_applicable  # noqa: E402
from repro.core.opcount import count_fn               # noqa: E402
from repro.launch.dryrun import build_cell            # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402

N_CHIPS = 256


def main():
    rows = json.loads((pathlib.Path(__file__).parent
                       / "dryrun_final.json").read_text())
    step_lb = {(r["arch"], r["shape"]): max(r["compute_s"], r["memory_s"],
                                            r["collective_s"])
               for r in rows if r["status"] == "ok" and r["mesh"] == "16x16"}
    model = EnergyModel.from_store("sim-v5e-air")
    mesh = make_production_mesh()
    # profile every (arch x shape) cell, then predict the whole batch at
    # once — the facade amortizes table lookups across all cells
    cells, jobs = [], []
    for arch in cfgs.ARCHS:
        for shape_name in SHAPES:
            cfg = cfgs.get_config(arch)
            shape = SHAPES[shape_name]
            if not shape_applicable(cfg, shape)[0]:
                continue
            fn, args, _ = build_cell(arch, shape_name, mesh)
            counts = count_fn(fn, *args)
            t = step_lb.get((arch, shape_name), 1.0)
            # per-chip share of the program + per-chip static/const x time
            jobs.append(PredictJob(counts.scaled(1.0 / N_CHIPS), t,
                                   name=f"{arch}/{shape_name}"))
            cells.append((arch, shape_name, shape, t))
    print("| arch | shape | step LB (s) | pod energy/step (J) | "
          "J/token | dominant bucket |")
    print("|---|---|---|---|---|---|")
    for (arch, shape_name, shape, t), pred in zip(cells,
                                                  model.predict_many(jobs)):
        pod_j = pred.total_j * N_CHIPS
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind != "decode" else shape.global_batch)
        dom = max(((b, e) for b, e in pred.by_bucket.items()),
                  key=lambda kv: kv[1])[0]
        print(f"| {arch} | {shape_name} | {t:.3e} | {pod_j:.3e} "
              f"| {pod_j / tokens:.3e} | {dom} |")


if __name__ == "__main__":
    main()
