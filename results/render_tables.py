"""Render the EXPERIMENTS.md roofline table from dryrun_final.json."""
import json
import pathlib
import sys

rows = json.loads((pathlib.Path(__file__).parent / "dryrun_final.json")
                  .read_text())


def fmt(mesh):
    out = []
    out.append("| arch | shape | bound | compute (s) | memory (s) | "
               "collective (s) | wire GiB/chip | bytes/dev GiB | "
               "useful FLOPs | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — |"
                       f" — | — | skipped (long-context inapplicable) |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['bound']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} "
            f"| {r['wire_bytes_per_device']/2**30:.2f} "
            f"| {r['total_bytes_per_device']/2**30:.2f} "
            f"| {min(r['useful_flops_ratio'], 1.0):.2f} "
            f"| {r['roofline_fraction']:.4f} |")
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(fmt(mesh))
