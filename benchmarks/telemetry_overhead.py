"""Telemetry ingestion overhead — the streaming hot path must stay cheap.

A production collector polls every device at NVML-ish rates; the per-sample
cost of ring write + incremental integration + plateau update + marker
alignment bounds how many devices one monitor process can watch.  This
benchmark times the **per-sample reference path** against **chunked ndarray
ingestion** (several chunk sizes), end-to-end through the full pipeline and
through the integrator alone, and checks the two agree bitwise.

Emits JSON (``--out``, default ``results/BENCH_telemetry_overhead.json``)
recording ns/sample for both paths plus the devices-per-monitor headroom
each implies, and the repo's CSV line format on stdout.  ``--min-speedup``
turns it into a CI gate.

The **shard sweep** (``--shards-out``, default
``results/BENCH_telemetry_shards.json``) measures the sharded telemetry
plane: real ``EnergyModel`` sessions partitioned across 1/2/4/8 shards,
each shard's drain timed separately.  Modeled wall-clock per plane is the
*max* per-shard drain time — the per-core capacity model for one worker
per shard (this container pins the suite to one core, so shards are timed
sequentially; on a multi-core collector the shards genuinely overlap).
The sweep also re-checks the tiling guarantee end-to-end: a 4-shard
plane's snapshot must be bitwise-identical to the unsharded service's.
``--min-shard-speedup`` gates the modeled speedup at 4 shards.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from benchmarks.common import record
from repro.telemetry.align import StreamAligner, contiguous_markers
from repro.telemetry.sampler import PowerSample, SampleRing
from repro.telemetry.stream import OnlineSteadyState, StreamingIntegrator

N_SAMPLES = 200_000
SAMPLES_PER_STEP = 100          # marker cadence
CHUNK_SIZES = (64, 512, 4096)
SENSOR_HZ = 10.0                # NVML-ish poll rate, for the headroom math

SHARD_COUNTS = (1, 2, 4, 8)
SHARD_SESSIONS = 16             # sessions per plane (divisible by all counts)
SHARD_STEPS = 8                 # steps per session
SHARD_REPEATS = 3               # take the min modeled wall over repeats


def _synthetic(n: int):
    ts = np.arange(n) * 0.1
    ps = 180.0 + 10.0 * np.sin(ts / 7.0) + np.random.default_rng(0).normal(
        0.0, 1.5, n)
    return ts, ps


def _pipeline(ts, ps, bounds, chunk: int | None):
    """Run the full stack; returns (ns_per_sample, total_energy, windows)."""
    n = len(ts)
    ring = SampleRing(4096)
    integ = StreamingIntegrator()
    plateau = OnlineSteadyState()
    aligner = StreamAligner()
    for m in contiguous_markers(bounds):
        aligner.add_marker(m)
    t0 = time.perf_counter()
    if chunk is None:
        for i in range(n):
            s = PowerSample(ts[i], ps[i])
            ring.append(s)
            integ.add(s.t_s, s.power_w)
            plateau.update(s.t_s, s.power_w)
            aligner.add_sample(s)
    else:
        for lo in range(0, n, chunk):
            t, p = ts[lo:lo + chunk], ps[lo:lo + chunk]
            ring.extend(t, p)
            integ.extend(t, p)
            plateau.update_chunk(t, p)
            aligner.add_samples(t, p)
    ns = (time.perf_counter() - t0) / n * 1e9
    aligner.close()
    return ns, integ.energy_j, [w.measured_j for w in aligner.windows]


def _integrator_only(ts, ps, chunk: int | None):
    n = len(ts)
    integ = StreamingIntegrator()
    t0 = time.perf_counter()
    if chunk is None:
        for i in range(n):
            integ.add(ts[i], ps[i])
    else:
        for lo in range(0, n, chunk):
            integ.extend(ts[lo:lo + chunk], ps[lo:lo + chunk])
    return (time.perf_counter() - t0) / n * 1e9, integ.energy_j


# ---------------------------------------------------------------------------
# Shard sweep: the sharded plane's per-core capacity model + tiling check
# ---------------------------------------------------------------------------
def _shard_counts_vec(i: int):
    from repro.core.counting import OpCounts
    c = OpCounts()
    c.add("dot", 1e9 * (i % 7 + 1))
    c.add("add", 5e8)
    c.naive_bytes = 1e8
    c.boundary_read_bytes = 4e7
    c.boundary_write_bytes = 2e7
    c.flops = 2e9
    return c


def _build_plane(n_shards: int, sessions: int, steps: int):
    """A fresh plane with ``sessions`` started streaming sessions.

    A fresh ``EnergyModel.from_store`` per plane: the sim device's
    sensor-noise RNG is a device-lifetime stream, so identical build
    order on a fresh device reproduces the exact same traces — that is
    what lets every configuration drain the same samples and the 4-shard
    snapshot compare bitwise against the unsharded service.
    """
    from repro.api import EnergyModel
    from repro.telemetry import TelemetryPlane
    model = EnergyModel.from_store("sim-v5e-air")
    plane = TelemetryPlane(n_shards, runner="serial")
    for i in range(sessions):
        s = model.stream(_shard_counts_vec(i), name=f"w{i}",
                         recalibrate=None, chunk_size=512)
        plane.register(s, f"dev{i}/w{i}")
        for _ in range(steps):
            s.step()
        s.start()
    return plane


def _shard_sweep(sessions: int, steps: int, repeats: int):
    """Time each shard's drain separately across SHARD_COUNTS planes."""
    rows = {}
    for n in SHARD_COUNTS:
        best_wall, shard_s, total = None, None, 0
        for _ in range(repeats):
            plane = _build_plane(n, sessions, steps)
            times = []
            for sh in plane.shards:
                t0 = time.perf_counter()
                sh.drain()
                times.append(time.perf_counter() - t0)
            plane.finish_all()
            total = sum(s.samples_drained
                        for s in plane._sessions.values())
            if best_wall is None or max(times) < best_wall:
                best_wall, shard_s = max(times), times
        rows[str(n)] = {
            "n_shards": n,
            "total_samples": total,
            "shard_drain_s": shard_s,
            "modeled_wall_s": best_wall,
            "per_core_ns_per_sample": best_wall / total * n * 1e9,
            "devices_per_plane_at_10hz": int(total / best_wall / SENSOR_HZ),
        }
    base = rows[str(SHARD_COUNTS[0])]["modeled_wall_s"]
    for row in rows.values():
        row["speedup_vs_1_shard"] = base / row["modeled_wall_s"]
        row["scaling_efficiency"] = (row["speedup_vs_1_shard"]
                                     / row["n_shards"])
    return rows


def _shard_bitwise_check(sessions: int, steps: int) -> bool:
    """End-to-end tiling guarantee: 4-shard plane == unsharded service."""
    from repro.api import EnergyModel
    from repro.telemetry import TelemetryService
    ref = TelemetryService()
    model = EnergyModel.from_store("sim-v5e-air")
    for i in range(sessions):
        s = model.stream(_shard_counts_vec(i), name=f"w{i}",
                         recalibrate=None, chunk_size=512)
        ref.register(s, f"dev{i}/w{i}")
        for _ in range(steps):
            s.step()
        s.start()
    while ref.poll_all(4):
        pass
    ref.finish_all()
    plane = _build_plane(4, sessions, steps)
    plane.finish_all()
    return plane.to_json() == ref.to_json()


def run_shard_sweep(args) -> dict:
    bitwise = _shard_bitwise_check(args.shard_sessions, args.shard_steps)
    rows = _shard_sweep(args.shard_sessions, args.shard_steps,
                        args.shard_repeats)
    at4 = rows["4"]["speedup_vs_1_shard"] if "4" in rows else None
    result = {
        "benchmark": "telemetry_shards",
        "sessions_per_plane": args.shard_sessions,
        "steps_per_session": args.shard_steps,
        "runner": "serial (per-shard sequential timing; modeled wall = "
                  "max per-shard drain, one core per shard)",
        "shards": rows,
        "speedup_at_4_shards": at4,
        "plane_bitwise_identical_to_service": bitwise,
    }
    out = pathlib.Path(args.shards_out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    for n, row in rows.items():
        record(f"telemetry_plane_{n}_shards", row["modeled_wall_s"] * 1e3,
               f"speedup=x{row['speedup_vs_1_shard']:.2f} "
               f"eff={row['scaling_efficiency']:.2f} "
               f"devices@10Hz={row['devices_per_plane_at_10hz']}")
    print(f"shard sweep: x{at4:.2f} modeled speedup at 4 shards "
          f"({rows['4']['devices_per_plane_at_10hz']} devices/plane @10Hz), "
          f"bitwise={bitwise}")
    print(f"wrote {out}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_telemetry_overhead.json")
    ap.add_argument("--samples", type=int, default=N_SAMPLES)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless the best chunked full pipeline beats "
                         "the per-sample path by this factor")
    ap.add_argument("--shards-out",
                    default="results/BENCH_telemetry_shards.json")
    ap.add_argument("--shard-sessions", type=int, default=SHARD_SESSIONS)
    ap.add_argument("--shard-steps", type=int, default=SHARD_STEPS)
    ap.add_argument("--shard-repeats", type=int, default=SHARD_REPEATS)
    ap.add_argument("--min-shard-speedup", type=float, default=0.0,
                    help="fail unless the modeled 4-shard plane beats one "
                         "shard by this factor")
    ap.add_argument("--no-shards", action="store_true",
                    help="skip the shard sweep (chunked-ingestion part only)")
    ap.add_argument("--shards-only", action="store_true",
                    help="run only the shard sweep")
    args = ap.parse_args(argv)

    if args.shards_only:
        shards = run_shard_sweep(args)
        if not shards["plane_bitwise_identical_to_service"]:
            print("FAIL: sharded plane snapshot differs from the unsharded "
                  "service", file=sys.stderr)
            return 1
        if shards["speedup_at_4_shards"] < args.min_shard_speedup:
            print(f"FAIL: shard speedup x{shards['speedup_at_4_shards']:.2f}"
                  f" < required x{args.min_shard_speedup:.2f}",
                  file=sys.stderr)
            return 1
        return 0

    ts, ps = _synthetic(args.samples)
    bounds = ts[::SAMPLES_PER_STEP]

    # warm numpy / allocator paths once
    _pipeline(ts[:2048], ps[:2048], ts[:2048:SAMPLES_PER_STEP], 512)

    scalar_ns, scalar_e, scalar_w = _pipeline(ts, ps, bounds, None)
    scalar_integ_ns, scalar_integ_e = _integrator_only(ts, ps, None)

    chunked = {}
    identical = True
    for cs in CHUNK_SIZES:
        full_ns, e, w = _pipeline(ts, ps, bounds, cs)
        integ_ns, ie = _integrator_only(ts, ps, cs)
        identical &= (e == scalar_e and ie == scalar_integ_e
                      and w == scalar_w)
        chunked[str(cs)] = {"full_ns_per_sample": full_ns,
                            "integrator_ns_per_sample": integ_ns}

    best_cs, best = min(chunked.items(),
                        key=lambda kv: kv[1]["full_ns_per_sample"])
    speedup = scalar_ns / max(best["full_ns_per_sample"], 1e-12)

    def devices(ns_per_sample: float) -> int:
        # one monitor process, SENSOR_HZ polls per device per second
        return int(1e9 / (ns_per_sample * SENSOR_HZ))

    result = {
        "benchmark": "telemetry_overhead",
        "n_samples": args.samples,
        "samples_per_step": SAMPLES_PER_STEP,
        "scalar": {"full_ns_per_sample": scalar_ns,
                   "integrator_ns_per_sample": scalar_integ_ns,
                   "devices_per_monitor_at_10hz": devices(scalar_ns)},
        "chunked": chunked,
        "best_chunk_size": int(best_cs),
        "best_full_ns_per_sample": best["full_ns_per_sample"],
        "devices_per_monitor_at_10hz": devices(best["full_ns_per_sample"]),
        "speedup_chunked_vs_scalar": speedup,
        "outputs_bitwise_identical": identical,
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    record("telemetry_scalar_pipeline", scalar_ns / 1e3,
           f"ns_per_sample={scalar_ns:.0f}")
    for cs, row in chunked.items():
        record(f"telemetry_chunked_{cs}", row["full_ns_per_sample"] / 1e3,
               f"ns_per_sample={row['full_ns_per_sample']:.0f}")
    record("telemetry_integrator_chunked",
           chunked[str(CHUNK_SIZES[-1])]["integrator_ns_per_sample"] / 1e3,
           f"scalar_ns={scalar_integ_ns:.0f}")
    print(f"speedup x{speedup:.1f} at chunk={best_cs} "
          f"({best['full_ns_per_sample']:.0f} ns/sample, "
          f"{result['devices_per_monitor_at_10hz']} devices/monitor @10Hz) "
          f"identical={identical}")
    print(f"wrote {out}")

    if not identical:
        print("FAIL: chunked outputs are not bitwise-identical to the "
              "per-sample path", file=sys.stderr)
        return 1
    if speedup < args.min_speedup:
        print(f"FAIL: speedup x{speedup:.1f} < required "
              f"x{args.min_speedup:.1f}", file=sys.stderr)
        return 1

    if not args.no_shards:
        shards = run_shard_sweep(args)
        if not shards["plane_bitwise_identical_to_service"]:
            print("FAIL: sharded plane snapshot differs from the unsharded "
                  "service", file=sys.stderr)
            return 1
        if shards["speedup_at_4_shards"] < args.min_shard_speedup:
            print(f"FAIL: shard speedup x{shards['speedup_at_4_shards']:.2f}"
                  f" < required x{args.min_shard_speedup:.2f}",
                  file=sys.stderr)
            return 1
    return 0


def bench_telemetry_overhead():
    """Harness entry (benchmarks.run): the full canonical configuration,
    so the JSON under results/ is never overwritten with a reduced run."""
    main([])


ALL = [bench_telemetry_overhead]

if __name__ == "__main__":
    sys.exit(main())
