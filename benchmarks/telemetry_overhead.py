"""Telemetry ingestion overhead — the streaming hot path must stay cheap.

A production collector polls every device at NVML-ish rates; the per-sample
cost of ring append + incremental integration + plateau update + marker
alignment bounds how many devices one monitor process can watch.  Reports
nanoseconds per sample through the full pipeline and through the integrator
alone.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import timed
from repro.telemetry.align import StreamAligner, contiguous_markers
from repro.telemetry.sampler import PowerSample, SampleRing
from repro.telemetry.stream import OnlineSteadyState, StreamingIntegrator

N_SAMPLES = 200_000
SAMPLES_PER_STEP = 100          # marker cadence


def _synthetic(n: int):
    ts = np.arange(n) * 0.1
    ps = 180.0 + 10.0 * np.sin(ts / 7.0) + np.random.default_rng(0).normal(
        0.0, 1.5, n)
    return ts, ps


@timed("telemetry_integrator_only")
def bench_integrator() -> str:
    ts, ps = _synthetic(N_SAMPLES)
    integ = StreamingIntegrator()
    t0 = time.perf_counter()
    for i in range(N_SAMPLES):
        integ.add(ts[i], ps[i])
    ns = (time.perf_counter() - t0) / N_SAMPLES * 1e9
    return f"ns_per_sample={ns:.0f} energy_j={integ.energy_j:.0f}"


@timed("telemetry_full_pipeline")
def bench_pipeline() -> str:
    ts, ps = _synthetic(N_SAMPLES)
    bounds = ts[::SAMPLES_PER_STEP]
    ring = SampleRing(4096)
    integ = StreamingIntegrator()
    plateau = OnlineSteadyState()
    aligner = StreamAligner()
    for m in contiguous_markers(bounds):
        aligner.add_marker(m)
    t0 = time.perf_counter()
    for i in range(N_SAMPLES):
        s = PowerSample(ts[i], ps[i])
        ring.append(s)
        integ.add(s.t_s, s.power_w)
        plateau.update(s.t_s, s.power_w)
        aligner.add_sample(s)
    ns = (time.perf_counter() - t0) / N_SAMPLES * 1e9
    aligner.close()
    return (f"ns_per_sample={ns:.0f} windows={len(aligner.windows)} "
            f"dropped={ring.dropped}")


ALL = [bench_integrator, bench_pipeline]

if __name__ == "__main__":
    for b in ALL:
        b()
