"""Telemetry ingestion overhead — the streaming hot path must stay cheap.

A production collector polls every device at NVML-ish rates; the per-sample
cost of ring write + incremental integration + plateau update + marker
alignment bounds how many devices one monitor process can watch.  This
benchmark times the **per-sample reference path** against **chunked ndarray
ingestion** (several chunk sizes), end-to-end through the full pipeline and
through the integrator alone, and checks the two agree bitwise.

Emits JSON (``--out``, default ``results/BENCH_telemetry_overhead.json``)
recording ns/sample for both paths plus the devices-per-monitor headroom
each implies, and the repo's CSV line format on stdout.  ``--min-speedup``
turns it into a CI gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from benchmarks.common import record
from repro.telemetry.align import StreamAligner, contiguous_markers
from repro.telemetry.sampler import PowerSample, SampleRing
from repro.telemetry.stream import OnlineSteadyState, StreamingIntegrator

N_SAMPLES = 200_000
SAMPLES_PER_STEP = 100          # marker cadence
CHUNK_SIZES = (64, 512, 4096)
SENSOR_HZ = 10.0                # NVML-ish poll rate, for the headroom math


def _synthetic(n: int):
    ts = np.arange(n) * 0.1
    ps = 180.0 + 10.0 * np.sin(ts / 7.0) + np.random.default_rng(0).normal(
        0.0, 1.5, n)
    return ts, ps


def _pipeline(ts, ps, bounds, chunk: int | None):
    """Run the full stack; returns (ns_per_sample, total_energy, windows)."""
    n = len(ts)
    ring = SampleRing(4096)
    integ = StreamingIntegrator()
    plateau = OnlineSteadyState()
    aligner = StreamAligner()
    for m in contiguous_markers(bounds):
        aligner.add_marker(m)
    t0 = time.perf_counter()
    if chunk is None:
        for i in range(n):
            s = PowerSample(ts[i], ps[i])
            ring.append(s)
            integ.add(s.t_s, s.power_w)
            plateau.update(s.t_s, s.power_w)
            aligner.add_sample(s)
    else:
        for lo in range(0, n, chunk):
            t, p = ts[lo:lo + chunk], ps[lo:lo + chunk]
            ring.extend(t, p)
            integ.extend(t, p)
            plateau.update_chunk(t, p)
            aligner.add_samples(t, p)
    ns = (time.perf_counter() - t0) / n * 1e9
    aligner.close()
    return ns, integ.energy_j, [w.measured_j for w in aligner.windows]


def _integrator_only(ts, ps, chunk: int | None):
    n = len(ts)
    integ = StreamingIntegrator()
    t0 = time.perf_counter()
    if chunk is None:
        for i in range(n):
            integ.add(ts[i], ps[i])
    else:
        for lo in range(0, n, chunk):
            integ.extend(ts[lo:lo + chunk], ps[lo:lo + chunk])
    return (time.perf_counter() - t0) / n * 1e9, integ.energy_j


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_telemetry_overhead.json")
    ap.add_argument("--samples", type=int, default=N_SAMPLES)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless the best chunked full pipeline beats "
                         "the per-sample path by this factor")
    args = ap.parse_args(argv)

    ts, ps = _synthetic(args.samples)
    bounds = ts[::SAMPLES_PER_STEP]

    # warm numpy / allocator paths once
    _pipeline(ts[:2048], ps[:2048], ts[:2048:SAMPLES_PER_STEP], 512)

    scalar_ns, scalar_e, scalar_w = _pipeline(ts, ps, bounds, None)
    scalar_integ_ns, scalar_integ_e = _integrator_only(ts, ps, None)

    chunked = {}
    identical = True
    for cs in CHUNK_SIZES:
        full_ns, e, w = _pipeline(ts, ps, bounds, cs)
        integ_ns, ie = _integrator_only(ts, ps, cs)
        identical &= (e == scalar_e and ie == scalar_integ_e
                      and w == scalar_w)
        chunked[str(cs)] = {"full_ns_per_sample": full_ns,
                            "integrator_ns_per_sample": integ_ns}

    best_cs, best = min(chunked.items(),
                        key=lambda kv: kv[1]["full_ns_per_sample"])
    speedup = scalar_ns / max(best["full_ns_per_sample"], 1e-12)

    def devices(ns_per_sample: float) -> int:
        # one monitor process, SENSOR_HZ polls per device per second
        return int(1e9 / (ns_per_sample * SENSOR_HZ))

    result = {
        "benchmark": "telemetry_overhead",
        "n_samples": args.samples,
        "samples_per_step": SAMPLES_PER_STEP,
        "scalar": {"full_ns_per_sample": scalar_ns,
                   "integrator_ns_per_sample": scalar_integ_ns,
                   "devices_per_monitor_at_10hz": devices(scalar_ns)},
        "chunked": chunked,
        "best_chunk_size": int(best_cs),
        "best_full_ns_per_sample": best["full_ns_per_sample"],
        "devices_per_monitor_at_10hz": devices(best["full_ns_per_sample"]),
        "speedup_chunked_vs_scalar": speedup,
        "outputs_bitwise_identical": identical,
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    record("telemetry_scalar_pipeline", scalar_ns / 1e3,
           f"ns_per_sample={scalar_ns:.0f}")
    for cs, row in chunked.items():
        record(f"telemetry_chunked_{cs}", row["full_ns_per_sample"] / 1e3,
               f"ns_per_sample={row['full_ns_per_sample']:.0f}")
    record("telemetry_integrator_chunked",
           chunked[str(CHUNK_SIZES[-1])]["integrator_ns_per_sample"] / 1e3,
           f"scalar_ns={scalar_integ_ns:.0f}")
    print(f"speedup x{speedup:.1f} at chunk={best_cs} "
          f"({best['full_ns_per_sample']:.0f} ns/sample, "
          f"{result['devices_per_monitor_at_10hz']} devices/monitor @10Hz) "
          f"identical={identical}")
    print(f"wrote {out}")

    if not identical:
        print("FAIL: chunked outputs are not bitwise-identical to the "
              "per-sample path", file=sys.stderr)
        return 1
    if speedup < args.min_speedup:
        print(f"FAIL: speedup x{speedup:.1f} < required "
              f"x{args.min_speedup:.1f}", file=sys.stderr)
        return 1
    return 0


def bench_telemetry_overhead():
    """Harness entry (benchmarks.run): the full canonical configuration,
    so the JSON under results/ is never overwritten with a reduced run."""
    main([])


ALL = [bench_telemetry_overhead]

if __name__ == "__main__":
    sys.exit(main())
