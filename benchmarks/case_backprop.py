"""§5.3.1 Backprop case study: the attribution finds an accidental-precision
bug (strong-typed f32 scalar upcasting a bf16 model — the TPU edition of the
paper's #define-double bug); fixing it saves double-digit % energy, and
Wattchmen predicts the saving within ~1 point of the measurement."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.api import EnergyModel


def _make(scale):
    # the bug hits the second (output) projection + its backward — a partial
    # upcast like the paper's two #define'd values (one kernel affected)
    def backprop_k2(x, w1, w2, y):
        def loss(w1, w2):
            h = jnp.tanh(x @ w1)
            o = jax.nn.sigmoid((h * scale) @ w2)
            return jnp.mean((o - y) ** 2)
        g1, g2 = jax.grad(loss, argnums=(0, 1))(w1, w2)
        return g1.sum() + g2.sum()
    return backprop_k2


def _audit(fn, iters=None):
    args = (jax.ShapeDtypeStruct((65536, 512), jnp.bfloat16),
            jax.ShapeDtypeStruct((512, 2048), jnp.bfloat16),
            jax.ShapeDtypeStruct((2048, 64), jnp.bfloat16),
            jax.ShapeDtypeStruct((65536, 64), jnp.bfloat16))
    model = EnergyModel.from_store("sim-v5e-air")
    cmp = model.compare(fn, *args, target_seconds=30.0, iters=iters,
                        name="backprop_k2")
    return cmp.record, cmp.prediction, cmp.record.iters


@timed("case_backprop_precision_bug")
def case_backprop():
    rec_bug, pred_bug, n = _audit(_make(jnp.float32(0.125)))
    rec_fix, pred_fix, _ = _audit(_make(0.125), iters=n)
    top = [c for c, _ in pred_bug.top_classes(6)]
    flagged = any(c.endswith(".f32") and c.startswith(("dot", "convert"))
                  for c in top)
    meas = 1 - rec_fix.energy_counter_j / rec_bug.energy_counter_j
    prd = 1 - pred_fix.total_j / pred_bug.total_j
    return (f"flagged_f32={flagged}|saved_measured={meas:.1%}"
            f"|saved_predicted={prd:.1%}")


ALL = [case_backprop]
