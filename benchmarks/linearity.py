"""Figure 5: dynamic energy is linear in instruction count
(base / +mul / 2x-base microbenchmark triple)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import measure, microbench, opcount
from repro.hw.device import Program
from repro.hw.systems import get_device


def _variant(n_mul: int, n_add: int):
    # register-resident working set: the energy delta is purely the added
    # instructions (Fig. 5's loop executes on register values)
    def fn(c0):
        def body(c, _):
            for _ in range(n_mul):
                c = c * 1.0001
            for _ in range(n_add):
                c = c + 0.5
            return c, ()
        c, _ = jax.lax.scan(body, c0, None, length=64)
        return c
    return opcount.count_fn(fn, jax.ShapeDtypeStruct((128, 1024),
                                                     jnp.float32))


@timed("fig5_linearity")
def linearity():
    dev = get_device("sim-v5e-air")
    p_const = measure.constant_power(dev.idle(30.0))
    ns = microbench._nanosleep_counts()
    p_static = measure.static_power(
        dev.run(Program("ns", ns, iters=dev.iters_for_duration(ns, 60.0),
                        is_nanosleep=True)), p_const)
    iters = dev.iters_for_duration(_variant(16, 16), 60.0)
    e = {}
    for name, (m, a) in {"base": (16, 16), "add_mul": (32, 16),
                         "x2": (32, 32)}.items():
        rec = dev.run(Program("lin", _variant(m, a), iters=iters))
        e[name] = measure.dynamic_energy(rec, p_const, p_static) / rec.iters
    ratio = e["x2"] / e["base"]
    return (f"Edyn base={e['base']:.3e}J|+mul={e['add_mul']:.3e}J"
            f"|2x={e['x2']:.3e}J|2x/base={ratio:.3f}")


ALL = [linearity]
