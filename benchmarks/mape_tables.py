"""Tables 4-7 + Figures 6-9: MAPE of every model on every system, with
Direct/Pred coverage, plus the AccelWattch self-consistency check (Fig. 1:
accurate on its own reference environment, brittle on the deployment)."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core.evaluate import evaluate_system


@timed("table4_air_v5e_mape")
def table4():
    rep = evaluate_system("sim-v5e-air")
    t = rep.mape_table()
    return ("AW={accelwattch:.1f}%|Guser={guser:.1f}%"
            "|Direct={wattchmen_direct:.1f}%|Pred={wattchmen_pred:.1f}%"
            .format(**t))


@timed("table5_liquid_v5e_mape")
def table5():
    rep = evaluate_system("sim-v5e-liquid", with_guser=False)
    t = rep.mape_table()
    return ("AW={accelwattch:.1f}%|Direct={wattchmen_direct:.1f}%"
            "|Pred={wattchmen_pred:.1f}%".format(**t))


@timed("table6_v5p_mape_coverage")
def table6():
    rep = evaluate_system("sim-v5p-air", with_accelwattch=False,
                          with_guser=False)
    t = rep.mape_table()
    return (f"Direct={t['wattchmen_direct']:.1f}%"
            f"|Pred={t['wattchmen_pred']:.1f}%"
            f"|covDirect={rep.mean_coverage('direct'):.0%}"
            f"|covPred={rep.mean_coverage('pred'):.0%}")


@timed("table7_v6e_mape_coverage")
def table7():
    rep = evaluate_system("sim-v6e-air", with_accelwattch=False,
                          with_guser=False)
    t = rep.mape_table()
    return (f"Direct={t['wattchmen_direct']:.1f}%"
            f"|Pred={t['wattchmen_pred']:.1f}%"
            f"|covDirect={rep.mean_coverage('direct'):.0%}"
            f"|covPred={rep.mean_coverage('pred'):.0%}")


@timed("fig1_accelwattch_selfcheck")
def fig1():
    """AccelWattch on its own calibration environment vs the deployment."""
    own = evaluate_system("sim-v5e-ref", with_guser=False)
    dep = evaluate_system("sim-v5e-air", with_guser=False)
    return (f"own_env={own.mape_table()['accelwattch']:.1f}%"
            f"|deployment={dep.mape_table()['accelwattch']:.1f}%")


ALL = [table4, table5, table6, table7, fig1]
