"""Serving-energy overhead — scheduling and attribution must stay off the
critical path.

A serving runtime makes admission/eviction decisions and splits joules
across the batch at every step boundary; if that costs more than a few
microseconds it competes with the decode step it is metering.  This
benchmark times (1) the continuous-batching scheduler draining a large
staggered workload (pure policy logic, injected pricing/drift) and (2)
the ledger's bitwise-conserving per-request attribution at several batch
sizes, checking conservation on every recorded step.

Emits JSON (``--out``, default ``results/BENCH_serve_energy.json``) with
us/step for both layers plus the steps-per-second headroom, and the
repo's CSV line format on stdout.  ``--max-us-per-step`` turns it into a
CI gate; conservation is always a gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from benchmarks.common import record
from repro.serve.ledger import ActiveShare, RequestLedger
from repro.serve.scheduler import (ContinuousBatchingScheduler, EnergyPolicy,
                                   Request)

N_REQUESTS = 512
LEDGER_STEPS = 20_000
BATCH_SIZES = (2, 8, 32)


def _workload(n: int):
    rng = np.random.default_rng(0)
    reqs, step = [], 0
    for i in range(n):
        reqs.append(Request(id=f"r{i}", tenant=f"t{i % 8}",
                            prompt_len=int(rng.integers(4, 64)),
                            max_new=int(rng.integers(4, 64)),
                            arrival_step=step))
        step += int(rng.integers(0, 3))
    return reqs


def _bench_scheduler(n_requests: int):
    """Drain a full workload; returns (us/boundary-step, steps, phases)."""
    reqs = _workload(n_requests)
    sched = ContinuousBatchingScheduler(
        reqs, EnergyPolicy(max_batch=16, budget_j_per_token=1.4),
        j_per_token=lambda b: 1.0 + 0.02 * b, drift_flag=lambda: False)
    t0 = time.perf_counter()
    steps = phases = 0
    while (ph := sched.next_phase()) is not None:
        steps += ph.n_steps
        phases += 1
    dt = time.perf_counter() - t0
    return dt / max(steps, 1) * 1e6, steps, phases


def _bench_ledger(n_steps: int, batch: int):
    """Attribute ``n_steps`` steps at ``batch``; conservation is asserted
    bitwise on every step.  Returns (us/step, entries/s)."""
    rng = np.random.default_rng(batch)
    measured = rng.uniform(50.0, 500.0, n_steps)
    predicted = measured * rng.uniform(0.9, 1.1, n_steps)
    dyn = rng.uniform(0.3, 1.0, n_steps)
    active = [ActiveShare(request_id=f"r{i}", tenant=f"t{i % 4}",
                          tokens=float(1 + i % 3),
                          kv_bytes=float((i + 1) << 12))
              for i in range(batch)]
    ledger = RequestLedger()
    t0 = time.perf_counter()
    for s in range(n_steps):
        ledger.record_step(step=s, kind="decode", duration_s=0.1,
                           measured_j=float(measured[s]),
                           predicted_j=float(predicted[s]),
                           dynamic_frac=float(dyn[s]), active=active,
                           work_scale=2.0)
    dt = time.perf_counter() - t0
    for s in ledger.steps:
        acc = 0.0
        for e in s.entries:
            acc += e.measured_j
        if acc != s.measured_j:
            raise AssertionError(
                f"conservation violated at step {s.step}: "
                f"{acc!r} != {s.measured_j!r}")
    return dt / n_steps * 1e6, n_steps * batch / dt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_serve_energy.json")
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--ledger-steps", type=int, default=LEDGER_STEPS)
    ap.add_argument("--max-us-per-step", type=float, default=0.0,
                    help="fail if scheduler or ledger exceeds this per-step "
                         "cost (0 = no gate; conservation always gates)")
    args = ap.parse_args(argv)

    # warm allocator / numpy paths
    _bench_scheduler(16)
    _bench_ledger(256, 4)

    sched_us, steps, phases = _bench_scheduler(args.requests)

    ledger_rows = {}
    for b in BATCH_SIZES:
        us, eps = _bench_ledger(args.ledger_steps, b)
        ledger_rows[str(b)] = {"us_per_step": us, "entries_per_s": eps}

    worst_ledger_us = max(r["us_per_step"] for r in ledger_rows.values())
    result = {
        "benchmark": "serve_energy",
        "n_requests": args.requests,
        "scheduler": {"us_per_step": sched_us, "steps": steps,
                      "phases": phases,
                      "steps_per_s": 1e6 / max(sched_us, 1e-12)},
        "ledger": ledger_rows,
        "ledger_steps": args.ledger_steps,
        "worst_us_per_step": max(sched_us, worst_ledger_us),
        "conservation_bitwise": True,      # asserted per step above
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    record("serve_scheduler_step", sched_us,
           f"steps={steps} phases={phases}")
    for b, row in ledger_rows.items():
        record(f"serve_ledger_batch{b}", row["us_per_step"],
               f"entries_per_s={row['entries_per_s']:.0f}")
    print(f"scheduler {sched_us:.2f} us/step over {steps} steps; ledger "
          f"worst {worst_ledger_us:.2f} us/step (batch {BATCH_SIZES[-1]}); "
          f"conservation bitwise on every step")
    print(f"wrote {out}")

    if args.max_us_per_step > 0 and \
            result["worst_us_per_step"] > args.max_us_per_step:
        print(f"FAIL: {result['worst_us_per_step']:.1f} us/step > gate "
              f"{args.max_us_per_step:.1f}", file=sys.stderr)
        return 1
    return 0


def bench_serve_energy():
    """Harness entry (benchmarks.run): the full canonical configuration,
    so the JSON under results/ is never overwritten with a reduced run."""
    main([])


ALL = [bench_serve_energy]

if __name__ == "__main__":
    sys.exit(main())
