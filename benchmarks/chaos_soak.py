"""Chaos soak — telemetry must degrade gracefully, never wrongly.

Runs the full monitoring and serving paths behind the deterministic
fault-injection layer at the ``heavy`` profile (>= 5% sample drops, NaN
bursts, power spikes, duplicated/reordered timestamps, and one shard
crash on the plane) and gates CI on the degradation contract:

  1. every run completes without an unhandled exception;
  2. per-step energies plus the startup span still tile the measured run
     total (the gap estimate is folded in, never double-counted);
  3. zero fault-induced recalibrations — low-coverage windows are flagged
     low-confidence instead of steering the drift detector;
  4. the shard supervisor restarts the crashed worker within its budget
     and the merged fleet snapshot matches the crash-free run bitwise
     (modulo the ``supervisor`` incident block);
  5. with the fault layer *disabled* the wrapped run is bitwise-identical
     to a bare one — the chaos path costs nothing when off.

Emits JSON (``--out``, default ``results/BENCH_chaos_soak.json``) plus
the repo's CSV line format on stdout.  All five gates always gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

from benchmarks.common import record
from repro.api import EnergyModel
from repro.core.counting import OpCounts
from repro.serve.scheduler import Request
from repro.telemetry import ChaosPlan, SupervisorConfig

SYSTEM = "sim-v5e-air"


def _counts(i: int = 0) -> OpCounts:
    c = OpCounts()
    c.add("dot.bf16", 1e7 * (i + 1))
    c.mxu_macs_total = c.mxu_macs_aligned = 1e7 * (i + 1)
    c.add("add.f32", 2e5)
    c.boundary_read_bytes = 2e5
    c.boundary_write_bytes = 1e5
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


def _gate(ok: bool, what: str) -> None:
    if not ok:
        raise AssertionError(f"chaos soak gate failed: {what}")


def _monitor_soak(chaos, steps: int):
    """One monitored session under chaos; returns (snapshot, elapsed_s)."""
    model = EnergyModel.from_store(SYSTEM)
    t0 = time.perf_counter()
    s = model.stream(_counts(), name="soak", chaos=chaos,
                     min_duration_s=max(6.0, steps), chunk_size=512)
    for i in range(steps):
        s.step(i)
    summary = s.finish()
    elapsed = time.perf_counter() - t0

    from repro.telemetry import window_tiling
    tiling = window_tiling(s.windows)
    total = tiling["startup_j"]
    for j in tiling["step_j"]:
        total += j
    _gate(abs(total - summary.measured_total_j)
          <= 1e-9 * abs(summary.measured_total_j),
          f"tiling: windows sum {total!r} != measured "
          f"{summary.measured_total_j!r}")
    _gate(summary.recalibrations == [],
          f"{len(summary.recalibrations)} fault-induced recalibrations")
    if chaos is not None and chaos.stream_enabled:
        _gate(summary.quarantined_samples > 0,
              "heavy profile produced no quarantined samples")
        _gate(summary.n_gaps > 0, "heavy profile produced no gaps")
        _gate(0.0 <= summary.gap_j <= summary.measured_total_j,
              f"gap estimate {summary.gap_j!r} outside the run total")
    return s.snapshot(), elapsed


def _serve_soak(chaos, requests: int):
    model = EnergyModel.from_store(SYSTEM)
    t0 = time.perf_counter()
    reqs = [Request(f"r{i}", f"tenant-{i % 2}", 8, 4, arrival_step=i // 2)
            for i in range(requests)]
    report = model.serve(requests=reqs, chaos=chaos, min_phase_seconds=4.0)
    elapsed = time.perf_counter() - t0
    _gate(report.measured_total_j > 0, "serve measured no energy")
    _gate(report.recalibrations == [],
          f"{len(report.recalibrations)} fault-induced recalibrations "
          f"in serve")
    _gate(report.health.get("samples", 0) > 0,
          "serve report carries no health counters")
    return report, elapsed


def _plane_soak(chaos, *, n_sessions: int = 3):
    """Process-runner plane; returns (plane, elapsed_s) or (None, 0.0)
    when the platform has no shared memory."""
    try:
        import multiprocessing.shared_memory  # noqa: F401
    except ImportError:
        return None, 0.0
    model = EnergyModel.from_store(SYSTEM)
    t0 = time.perf_counter()
    plane = model.plane(2, runner="process", chaos=chaos,
                        supervisor=SupervisorConfig(heartbeat_timeout_s=30.0,
                                                    max_restarts=2,
                                                    backoff_s=0.1))
    for i in range(n_sessions):
        s = model.stream(_counts(i), name=f"w{i}", recalibrate=None,
                         chunk_size=512)
        plane.register(s, f"dev{i}/w{i}")
        for _ in range(3):
            s.step()
    plane.finish_all()
    return plane, time.perf_counter() - t0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_chaos_soak.json")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--no-process", action="store_true",
                    help="skip the process-runner shard-crash soak")
    args = ap.parse_args(argv)

    heavy = ChaosPlan.profile("heavy", seed=args.chaos_seed)

    # 1+2+3: monitor under heavy faults
    snap, mon_s = _monitor_soak(heavy, args.steps)
    health = snap["health"]
    record("chaos_monitor_soak", mon_s * 1e6,
           f"quarantined={health['quarantined']} gaps={health['n_gaps']}")

    # 5: disabled layer is free — bitwise identity against a bare run
    bare, _ = _monitor_soak(None, args.steps)
    wrapped, _ = _monitor_soak(ChaosPlan.profile("none"), args.steps)
    _gate(json.dumps(bare, sort_keys=True)
          == json.dumps(wrapped, sort_keys=True),
          "disabled fault layer perturbed the snapshot")

    # 1+3: serve under heavy faults
    report, srv_s = _serve_soak(heavy, args.requests)
    record("chaos_serve_soak", srv_s * 1e6,
           f"requests={len(report.requests)} "
           f"quarantined={report.health['quarantined']:.0f}")

    # 4: shard crash -> supervised restart, bitwise-conserved merge
    supervisor = {"skipped": True}
    if not args.no_process:
        crash = dataclasses.replace(ChaosPlan(), crash_shards=(0,),
                                    crash_attempts=1)
        ref_plane, _ = _plane_soak(None)
        hit_plane, plane_s = _plane_soak(crash)
        if hit_plane is not None:
            _gate(hit_plane.restarts == 1,
                  f"expected 1 supervised restart, saw "
                  f"{hit_plane.restarts}")
            got = hit_plane.snapshot()
            sup = got.pop("supervisor", None)
            _gate(sup is not None and sup["folded_shards"] == [],
                  "crashed shard was folded instead of restarted")
            _gate(json.dumps(ref_plane.snapshot(), sort_keys=True)
                  == json.dumps(got, sort_keys=True),
                  "restarted plane snapshot diverged from the "
                  "crash-free run")
            supervisor = {"skipped": False, "restarts": hit_plane.restarts,
                          "events": sup["events"]}
            record("chaos_plane_crash_soak", plane_s * 1e6,
                   f"restarts={hit_plane.restarts}")

    result = {
        "benchmark": "chaos_soak",
        "profile": "heavy",
        "chaos_seed": args.chaos_seed,
        "steps": args.steps,
        "requests": args.requests,
        "monitor": {"elapsed_s": mon_s, "health": health},
        "serve": {"elapsed_s": srv_s, "health": report.health,
                  "measured_total_j": report.measured_total_j},
        "supervisor": supervisor,
        "gates": {"completed": True, "tiling_exact": True,
                  "zero_fault_recalibrations": True,
                  "disabled_layer_bitwise": True,
                  "supervised_restart_bitwise": not supervisor["skipped"]},
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")
    print(f"chaos soak: monitor {mon_s:.1f}s, serve {srv_s:.1f}s, "
          f"{health['quarantined']} samples quarantined, "
          f"{health['n_gaps']} gaps accounted, all gates green")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
