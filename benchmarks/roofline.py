"""§Roofline: per (arch x shape) three-term roofline from the dry-run
artifacts (results/dryrun_all.json, produced by repro.launch.dryrun), plus
per-cell energy/step predictions from the Wattchmen table — the fleet-level
integration of the paper."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import record, timed

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _load(name="dryrun_final.json"):
    for cand in (name, "dryrun_all.json"):
        p = RESULTS / cand
        if p.exists():
            return json.loads(p.read_text())
    return []


@timed("roofline_summary")
def summary():
    rows = [r for r in _load() if r.get("mesh") == "16x16"]
    ok = [r for r in rows if r["status"] == "ok"]
    if not ok:
        return "no dryrun results (run: python -m repro.launch.dryrun --all)"
    bounds = {}
    for r in ok:
        bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
    worst = min(ok, key=lambda r: r.get("roofline_fraction", 0))
    most_coll = max(ok, key=lambda r: r["collective_s"])
    return (f"cells={len(rows)}|ok={len(ok)}|bounds={bounds}"
            f"|worst_fraction={worst['arch']}/{worst['shape']}"
            f"={worst.get('roofline_fraction', 0):.3f}"
            f"|most_collective={most_coll['arch']}/{most_coll['shape']}"
            f"={most_coll['collective_s']:.2e}s")


def per_cell_rows():
    for r in _load():
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        record(
            f"roofline_{r['arch']}_{r['shape']}",
            r.get("compile_s", 0.0) * 1e6,
            (f"bound={r['bound']}|compute={r['compute_s']:.3e}s"
             f"|memory={r['memory_s']:.3e}s"
             f"|collective={r['collective_s']:.3e}s"
             f"|useful_flops={r['useful_flops_ratio']:.2f}"
             f"|roofline_frac={r.get('roofline_fraction', 0):.3f}"))


@timed("multipod_coherence")
def multipod():
    rows = [r for r in _load() if r.get("mesh") == "2x16x16"]
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skipped")
    err = sum(1 for r in rows if r["status"] == "error")
    return f"cells={len(rows)}|ok={ok}|skipped={skip}|errors={err}"


ALL = [summary, multipod, per_cell_rows]
