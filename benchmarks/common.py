"""Shared benchmark plumbing: CSV emission per the harness contract."""
from __future__ import annotations

import sys
import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(name: str):
    """Decorator: time the benchmark body; it returns the derived string."""
    def deco(fn: Callable[[], str]):
        def run():
            t0 = time.time()
            derived = fn()
            record(name, (time.time() - t0) * 1e6, derived)
        run.__name__ = f"bench_{name}"
        return run
    return deco
