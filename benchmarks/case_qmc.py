"""§5.3.2 QMCPACK case study: the fleet monitor catches mixed-precision DMC
spikes — a function called at a higher frequency than intended.  The
energy-share anomaly on the update's op classes points at the bug; removing
the redundant calls saves ~35% with prediction within ~1 point."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.api import EnergyModel
from repro.core import opcount


def _qmc_step(update_every: int):
    """One DMC block of 16 drift-diffusion steps; the wavefunction rebuild
    is *structurally* scheduled every ``update_every`` steps (the fix moved
    it out of the inner loop — exactly the QMCPACK patch)."""
    def drift(p, vec):
        ratio = jnp.einsum("wij,wj->wi", p, vec)
        return p + 1e-3 * jnp.einsum("wi,wj->wij", ratio, vec)

    def update(p, vec):
        # expensive mixed-precision wavefunction rebuild
        w = jnp.exp(jnp.clip(jnp.einsum("wij,wj->wi", p, vec) * 1e-3, -5, 5))
        corr = jnp.einsum("wi,wij->wj", w, p)
        return p * (1 + 1e-6 * jnp.tanh(corr)[:, None, :])

    n_blocks, inner = 16 // update_every, update_every

    def fn(psi, vec):
        def block(p, _):
            def step(p2, _):
                return drift(p2, vec), ()
            p, _ = jax.lax.scan(step, p, None, length=inner)
            return update(p, vec), ()
        p, _ = jax.lax.scan(block, psi, None, length=n_blocks)
        return p

    args = (jax.ShapeDtypeStruct((128, 512, 512), jnp.float32),
            jax.ShapeDtypeStruct((128, 512), jnp.float32))
    return opcount.count_fn(fn, *args)


@timed("case_qmc_redundant_update")
def case_qmc():
    model = EnergyModel.from_store("sim-v5e-air")
    buggy = _qmc_step(update_every=1)     # every step (unintended)
    fixed = _qmc_step(update_every=8)     # intended frequency

    # fleet monitor over a run that regresses at step 12
    mon = model.monitor(window=8, spike_ratio=1.4, min_share=0.03)
    for step in range(24):
        counts = buggy if step >= 12 else fixed
        t_step = 0.085 if step >= 12 else 0.05   # profiled step times
        mon.observe(step, counts, t_step)
    spiked = sorted({a.cls for a in mon.anomalies if a.step == 12})

    iters = model.device.iters_for_duration(buggy, 30.0)
    cb = model.compare(buggy, iters=iters, name="qmc_dmc")
    cf = model.compare(fixed, iters=iters, name="qmc_dmc")
    rb, rf = cb.record, cf.record
    meas = 1 - rf.energy_counter_j / rb.energy_counter_j
    prd = 1 - cf.predicted_j / cb.predicted_j
    return (f"anomaly_at_regression={bool(spiked)}|classes={spiked[:2]}"
            f"|saved_measured={meas:.1%}|saved_predicted={prd:.1%}")


ALL = [case_qmc]
