"""Kernel J/op regression gate — the autotuner vs the shipped defaults.

The block-size autotuner (``repro.kernels.autotune``) exists to make the
shipped pallas kernels measurably cheaper per logical op; this benchmark
holds that claim to account on the simulated device.  For each tunable
kernel it runs the staged micro-calibration search (grid + successive
halving, default config pinned into the final round) and reports the full
measured J/op landscape: every surviving candidate, the winner, the
shipped default, and the ref (non-pallas) baseline.

Emits JSON (``--out``, default ``results/BENCH_kernel_energy.json``) plus
the repo's CSV line format on stdout.  The gate — winner J/op <= default
J/op for every kernel — always applies; ``--no-gate`` downgrades it to a
report for exploratory runs.  (The tuner pins the default into the final
round precisely so this inequality is measurable, not vacuous.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from benchmarks.common import record
from repro.hw.systems import get_device
from repro.kernels import autotune

SYSTEM = "sim-v5e-air"
KERNELS = ("flash_attention", "decode_attention", "ssd_chunked")


def _entry_dict(e) -> dict:
    return {"variant": e.variant, "config": list(e.config),
            "j_per_op": e.j_per_op, "j_per_call": e.j_per_call,
            "latency_s": e.latency_s, "ops_per_call": e.ops_per_call}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_kernel_energy.json")
    ap.add_argument("--kernels", default=",".join(KERNELS),
                    help="comma-separated subset of tunable kernels")
    ap.add_argument("--durations", default=None,
                    help="comma-separated per-round probe durations "
                         "(seconds), e.g. '2,4'; default = the tuner's "
                         "staged schedule")
    ap.add_argument("--repeats", default=None,
                    help="comma-separated per-round repeat counts, "
                         "e.g. '1,2'")
    ap.add_argument("--latency-ceiling-us", type=float, default=None,
                    help="per-call latency ceiling for the search")
    ap.add_argument("--exhaustive", action="store_true",
                    help="measure every candidate in every round")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; do not fail on a regression")
    args = ap.parse_args(argv)

    kwargs = {}
    if args.durations:
        kwargs["durations"] = tuple(
            float(d) for d in args.durations.split(","))
    if args.repeats:
        kwargs["repeats"] = tuple(int(r) for r in args.repeats.split(","))
    if args.latency_ceiling_us is not None:
        kwargs["latency_ceiling_s"] = args.latency_ceiling_us * 1e-6
    if args.exhaustive:
        kwargs["exhaustive"] = True

    device = get_device(SYSTEM)
    kernels = [k.strip() for k in args.kernels.split(",") if k.strip()]
    results, failures = {}, []
    for kernel in kernels:
        res = autotune.tune(kernel, device, **kwargs)
        w, d = res.winner, res.default
        results[kernel] = {
            "winner": _entry_dict(w),
            "default": _entry_dict(d),
            "improvement_pct": res.improvement * 100.0,
            "entries": [_entry_dict(e) for e in res.entries],
            "rounds": res.rounds,
        }
        record(f"kernel_energy_{kernel}", w.latency_s * 1e6,
               f"j_per_op={w.j_per_op:.3e} default={d.j_per_op:.3e} "
               f"config={'x'.join(map(str, w.config)) or w.variant} "
               f"improvement={res.improvement * 100.0:+.1f}%")
        if w.j_per_op > d.j_per_op:
            failures.append(
                f"{kernel}: tuned {w.j_per_op:.3e} J/op > default "
                f"{d.j_per_op:.3e} J/op")

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "benchmark": "kernel_energy",
        "system": SYSTEM,
        "gate": "winner j_per_op <= default j_per_op per kernel",
        "kernels": results,
    }, indent=1) + "\n")
    print(f"wrote {out}")

    if failures and not args.no_gate:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


def bench_kernel_energy():
    """Harness entry (benchmarks.run): the full canonical configuration,
    so the JSON under results/ is never overwritten with a reduced run."""
    main([])


ALL = [bench_kernel_energy]

if __name__ == "__main__":
    sys.exit(main())
