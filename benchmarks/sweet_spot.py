"""Sweet-spot frequency benchmark — the governor vs the exhaustive sweep.

The frequency axis buys energy only if the closed loop actually lands on
the sweet spot: dynamic energy falls with V(f)^2 at low clocks while the
constant+static floor is paid for longer, so measured J/token bottoms out
at a workload-dependent frequency.  This benchmark calibrates a (freq,
cap) family on the simulated device, measures the exhaustive J/token and
tokens/s curve over the candidate grid, then lets the ``SweetSpotGovernor``
run the same workload closed-loop under a throughput SLA — both sides use
the *same* candidate grid, so "within one grid step of the exhaustive
optimum" is a meaningful gate.

Emits JSON (``--out``, default ``results/BENCH_sweet_spot.json``) with
J/step, J/token and tokens/s per operating point, the governor's decision
trace, and the chosen-vs-optimal verdict, plus the repo's CSV line format
on stdout.  The gate (governor within one grid step of the SLA-constrained
optimum, SLA held at the chosen point) always applies; ``--no-gate``
downgrades it to a report for exploratory runs.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from benchmarks.common import record
from repro.api import EnergyModel
from repro.core.opcount import OpCounts
from repro.dvfs import GovernorConfig, SweetSpotGovernor, default_sweep_points

SYSTEM = "sim-v5e-air"
TOKENS_PER_STEP = 64.0


def decode_counts() -> OpCounts:
    """A decode-like step: MXU-light, boundary-traffic-heavy."""
    c = OpCounts()
    c.add("dot.bf16", 2e8)
    c.mxu_macs_total = c.mxu_macs_aligned = 2e8
    c.add("exp.f32", 1e6)
    c.add("add.f32", 5e6)
    c.boundary_read_bytes = 4e6
    c.boundary_write_bytes = 2e6
    c.naive_bytes = 8e6
    c.fused_bytes = 2e6
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


def grid_distance(points, a_freq: float, b_freq: float) -> int:
    """Distance in grid steps between two candidate frequencies."""
    freqs = sorted({p[0] for p in points})
    return abs(freqs.index(a_freq) - freqs.index(b_freq))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_sweet_spot.json")
    ap.add_argument("--grid", type=int, default=4,
                    help="candidate frequencies across the V/f span")
    ap.add_argument("--duration-s", type=float, default=6.0,
                    help="per-microbenchmark calibration duration")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--steps", type=int, default=4,
                    help="workload steps per measured phase")
    ap.add_argument("--rounds", type=int, default=10,
                    help="closed-loop rounds for the governor")
    ap.add_argument("--min-phase-s", type=float, default=8.0)
    ap.add_argument("--sla-frac", type=float, default=0.6,
                    help="SLA = this fraction of the fastest point's "
                         "measured tokens/s")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; do not fail on a missed sweet spot")
    args = ap.parse_args(argv)

    model = EnergyModel.from_store(SYSTEM)
    points = default_sweep_points(model.device, n=args.grid)
    fam = {(f, c) for f, c, _ in model.table.family() if f is not None}
    missing = [p for p in points if p not in fam]
    if missing:
        model.calibrate_points(points=points, duration_s=args.duration_s,
                               repeats=args.repeats, seed=args.seed)
    counts = decode_counts()

    # 1. ground truth: the exhaustive J/token curve over the grid
    sweep = model.sweep(counts, points=points, steps=args.steps,
                        work_units=TOKENS_PER_STEP,
                        min_duration_s=args.min_phase_s, name="bench-sweep")
    sla = args.sla_frac * max(r.work_per_s for r in sweep.rows)
    best = sweep.best(sla_work_per_s=sla)
    assert best is not None, "SLA excluded every operating point"

    # 2. closed loop: same grid, same workload, SLA enforced by the governor
    gov = SweetSpotGovernor(points, GovernorConfig(sla_work_per_s=sla))
    run = model.govern(counts, gov, rounds=args.rounds, steps=args.steps,
                       work_units=TOKENS_PER_STEP,
                       min_duration_s=args.min_phase_s, name="bench-govern")
    chosen = run.final_point
    assert chosen is not None, "governor never settled on a point"

    by_freq = {r.freq_mhz: r for r in sweep.rows}
    chosen_row = by_freq[chosen[0]]
    dist = grid_distance(points, chosen[0], best.freq_mhz)
    sla_held = chosen_row.work_per_s >= sla
    nominal = by_freq.get(float(model.device.vf.f_nom_mhz))
    saved_pct = 0.0 if nominal is None else \
        (1.0 - chosen_row.j_per_work / nominal.j_per_work) * 100.0

    result = {
        "benchmark": "sweet_spot",
        "system": SYSTEM,
        "grid": [list(p) for p in points],
        "sla_tokens_per_s": sla,
        "tokens_per_step": TOKENS_PER_STEP,
        "sweep": [dict(r.snapshot(),
                       j_per_step=r.measured_j * TOKENS_PER_STEP
                       / max(r.work_units, 1e-12),
                       j_per_token=r.j_per_work,
                       tokens_per_s=r.work_per_s)
                  for r in sweep.rows],
        "exhaustive_best": best.snapshot(),
        "governor": run.snapshot(),
        "chosen_freq_mhz": chosen[0],
        "optimal_freq_mhz": best.freq_mhz,
        "grid_step_distance": dist,
        "sla_held_at_chosen": sla_held,
        "converged": run.converged,
        "saved_vs_nominal_pct": saved_pct,
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    for r in sweep.rows:
        record(f"sweet_spot_f{r.freq_mhz:g}", r.duration_s * 1e6,
               f"j_per_token={r.j_per_work:.3e} tokens_per_s="
               f"{r.work_per_s:.1f}")
    record("sweet_spot_governor", sum(r.duration_s for r in run.rounds) * 1e6,
           f"chosen_f={chosen[0]:g} optimal_f={best.freq_mhz:g} dist={dist}")
    print(f"sweet spot: exhaustive optimum f={best.freq_mhz:g} MHz "
          f"({best.j_per_work:.3e} J/token), governor chose "
          f"f={chosen[0]:g} MHz ({chosen_row.j_per_work:.3e} J/token, "
          f"{dist} grid step(s) away), SLA {sla:.1f} tokens/s "
          f"{'held' if sla_held else 'MISSED'}; "
          f"{saved_pct:+.1f}% J/token vs nominal")
    print(f"wrote {out}")

    if not args.no_gate and (dist > 1 or not sla_held):
        print(f"FAIL: governor at f={chosen[0]:g} is {dist} grid steps from "
              f"the optimum f={best.freq_mhz:g}"
              + ("" if sla_held else " and misses the SLA"), file=sys.stderr)
        return 1
    return 0


def bench_sweet_spot():
    """Harness entry (benchmarks.run): the full canonical configuration,
    so the JSON under results/ is never overwritten with a reduced run."""
    main([])


ALL = [bench_sweet_spot]

if __name__ == "__main__":
    sys.exit(main())
