"""Figure 14: cross-system bootstrap of the energy table.

Calibrate the liquid-cooled system through the unified pipeline while
*measuring* only a random 10% / 50% of its microbenchmark suite
(``EnergyModel.train(profile_fraction=..., donor=...)``), affine-mapping
every other class from the air-cooled donor table, and show workload MAPE
stays at the fully-profiled level (plus the R² of the underlying linear
relationship, paper: 0.988)."""
from __future__ import annotations

from benchmarks.common import timed
from repro.api import EnergyModel
from repro.core import transfer
from repro.core.evaluate import evaluate_system


@timed("fig14_transfer")
def fig14():
    air = EnergyModel.from_store("sim-v5e-air").table
    liq_model = EnergyModel.from_store("sim-v5e-liquid")
    r2 = transfer.r2_between(air, liq_model.table)
    out = [f"R2={r2:.3f}"]
    for frac in (0.1, 0.5):
        hybrid = EnergyModel.train("sim-v5e-liquid", profile_fraction=frac,
                                   donor=air, seed=3).table
        rep = evaluate_system("sim-v5e-liquid", table=hybrid,
                              with_accelwattch=False, with_guser=False)
        out.append(f"{int(frac*100)}%={rep.mape_table()['wattchmen_pred']:.1f}%"
                   f"(n={int(hybrid.provenance['n_measured'])})")
    rep_full = evaluate_system("sim-v5e-liquid", model=liq_model,
                               with_accelwattch=False, with_guser=False)
    out.append(f"100%={rep_full.mape_table()['wattchmen_pred']:.1f}%")
    return "|".join(out)


ALL = [fig14]
