"""Prediction throughput — the fleet-scale hot path (Eq. 3 as matrix algebra).

Times three layers of the vectorized currency:

* ``single_predict`` — one ``TablePredictor.predict`` call (µs/call);
* ``predict_loop`` vs ``predict_many`` — ≥1000 synthetic programs priced one
  at a time vs as one stacked counts matrix (``predict_batch``), asserting
  the batched ``Prediction`` totals are **bitwise identical** to the loop's;
* ``fused_predict`` — the jitted fused path (``TablePredictor(fused=True)``)
  vs the plain batch, both for predict-only and for predict+attribute
  (``by_bucket`` materialized per program — the bincount the jit fuses),
  asserting fused totals stay bitwise-identical to the plain path;
* ``solver_assembly`` — ``solver.build_system`` over the real microbenchmark
  suite (the training-phase matrix assembled in one shot).

Emits JSON (``--out``, default ``results/BENCH_predict_throughput.json``) so
the perf trajectory populates run over run, plus the repo's CSV line format
on stdout.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

import numpy as np

from benchmarks.common import record
from repro.core import coverage, isa, microbench, solver
from repro.core.opcount import OpCounts
from repro.core.predict import TablePredictor
from repro.core.table import EnergyTable
from repro.hw.device import RunRecord, SensorTrace

N_PROGRAMS = 4000       # fleet-scale: where the batched/fused paths live
SEED = 7


def synthetic_table() -> EnergyTable:
    """Deterministic stand-in table (throughput doesn't care about values)."""
    rng = np.random.default_rng(SEED)
    direct = {c.name: float(e) for c, e in
              zip(isa.OP_CLASSES, rng.uniform(1e-12, 6e-11, len(isa.OP_CLASSES)))}
    table = EnergyTable(system="bench", p_const=40.0, p_static=55.0,
                        direct=direct)
    coverage.compute_bucket_means(table)
    return table


def synthetic_programs(n: int):
    """Random-but-plausible op-count profiles over the canonical classes."""
    rng = np.random.default_rng(SEED + 1)
    names = [c.name for c in isa.OP_CLASSES]
    programs, durations = [], []
    for _ in range(n):
        c = OpCounts()
        for cls in rng.choice(names, size=rng.integers(8, 28), replace=False):
            c.add(str(cls), float(rng.uniform(1e3, 1e9)))
        c.boundary_read_bytes = float(rng.uniform(1e6, 1e10))
        c.boundary_write_bytes = float(rng.uniform(1e6, 1e10))
        c.fused_bytes = float(rng.uniform(1e6, 1e10))
        c.naive_bytes = c.boundary_bytes + c.fused_bytes
        programs.append(c)
        durations.append(float(rng.uniform(0.5, 30.0)))
    return programs, durations


def _fake_record(bench, iters: int) -> RunRecord:
    t = np.array([0.0, 1.0])
    trace = SensorTrace(t, np.array([100.0, 100.0]), np.ones(2),
                        np.full(2, 50.0))
    return RunRecord(name=bench.name, duration_s=60.0, iters=iters,
                     trace=trace, energy_counter_j=6000.0,
                     counters={"hbm_read_bytes": 1e9, "hbm_write_bytes": 1e9,
                               "vmem_read_bytes": 1e8, "vmem_write_bytes": 1e8})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_predict_throughput.json")
    ap.add_argument("--n", type=int, default=N_PROGRAMS)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail unless predict_many beats the loop by this")
    args = ap.parse_args(argv)

    predictor = TablePredictor(synthetic_table())
    predictor.warm()
    programs, durations = synthetic_programs(args.n)

    # warm the kernel path once so neither contender pays first-call costs
    predictor.predict(programs[0], durations[0])

    t0 = time.perf_counter()
    loop_preds = [predictor.predict(c, d)
                  for c, d in zip(programs, durations)]
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    batch_preds = predictor.predict_batch(programs, durations)
    t_batch = time.perf_counter() - t0

    identical = all(
        a.total_j == b.total_j and a.dynamic_j == b.dynamic_j
        and a.coverage == b.coverage
        for a, b in zip(loop_preds, batch_preds))
    speedup = t_loop / max(t_batch, 1e-12)

    n_single = 200
    t0 = time.perf_counter()
    for c, d in zip(programs[:n_single], durations[:n_single]):
        predictor.predict(c, d)
    us_single = (time.perf_counter() - t0) / n_single * 1e6

    # -- fused (jitted) path vs the plain batch -----------------------------
    fused = TablePredictor(synthetic_table(), fused=True)
    fused.warm()
    fused_on = fused.enable_fused()
    fused_bitwise = fused_predict_speedup = fused_attr_speedup = None
    if fused_on:
        fused_preds = fused.predict_batch(programs, durations)
        fused_bitwise = all(
            a.total_j == b.total_j and a.dynamic_j == b.dynamic_j
            and a.coverage == b.coverage
            for a, b in zip(batch_preds, fused_preds))

        def _time(pr, attribute, reps=7):
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                preds = pr.predict_batch(programs, durations)
                if attribute:
                    for p in preds:
                        p.by_bucket
                ts.append(time.perf_counter() - t0)
            return statistics.median(ts)

        # interleave-free warmup, then medians; attribute = by_bucket
        # materialized per program (the bincount the fused kernel absorbs)
        _time(fused, False, reps=1)
        fused_predict_speedup = _time(predictor, False) / _time(fused, False)
        fused_attr_speedup = _time(predictor, True) / _time(fused, True)

    suite = microbench.build_suite(isa_gen=0)
    targets = microbench.benched_classes(suite)
    records = [_fake_record(b, 1000) for b in suite]
    energies = [1.0] * len(suite)
    n_asm = 20
    t0 = time.perf_counter()
    for _ in range(n_asm):
        system = solver.build_system(suite, records, energies, targets)
    us_assembly = (time.perf_counter() - t0) / n_asm * 1e6

    result = {
        "benchmark": "predict_throughput",
        "n_programs": args.n,
        "predict_loop_us_total": t_loop * 1e6,
        "predict_many_us_total": t_batch * 1e6,
        "predict_many_us_per_program": t_batch / args.n * 1e6,
        "speedup_many_vs_loop": speedup,
        "totals_bitwise_identical": identical,
        "fused_available": fused_on,
        "fused_totals_bitwise_identical": fused_bitwise,
        "speedup_fused_vs_batch_predict": fused_predict_speedup,
        "speedup_fused_vs_batch_attribute": fused_attr_speedup,
        "single_predict_us": us_single,
        "solver_assembly_us": us_assembly,
        "solver_matrix_shape": list(system.matrix.shape),
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    record("predict_single", us_single, f"us_per_call={us_single:.1f}")
    record("predict_many", t_batch / args.n * 1e6,
           f"speedup_vs_loop=x{speedup:.1f} identical={identical}")
    if fused_on:
        record("predict_fused", fused_attr_speedup,
               f"attr=x{fused_attr_speedup:.2f} "
               f"predict=x{fused_predict_speedup:.2f} "
               f"identical={fused_bitwise}")
    record("solver_assembly", us_assembly,
           f"shape={system.matrix.shape[0]}x{system.matrix.shape[1]}")
    print(f"wrote {out}")

    if not identical:
        print("FAIL: batched totals are not bitwise-identical to the loop",
              file=sys.stderr)
        return 1
    if fused_on and not fused_bitwise:
        print("FAIL: fused totals are not bitwise-identical to the plain "
              "batch", file=sys.stderr)
        return 1
    if speedup < args.min_speedup:
        print(f"FAIL: speedup x{speedup:.1f} < required "
              f"x{args.min_speedup:.1f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
