"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  - table4..table7 / fig1: MAPE reproductions (paper Tables 4-7, Fig. 1)
  - fig5: dynamic-energy linearity
  - fig14: cross-system table transfer
  - case_*: the two §5.3 case studies
  - roofline_*: §Roofline terms per (arch x shape) from the dry-run
"""
from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (case_backprop, case_qmc, kernel_energy, linearity,
                            mape_tables, roofline, serve_energy,
                            telemetry_overhead, transfer_fig14)
    for mod in (mape_tables, linearity, transfer_fig14, case_backprop,
                case_qmc, roofline, telemetry_overhead, serve_energy,
                kernel_energy):
        for bench in mod.ALL:
            try:
                bench()
            except Exception as e:   # noqa: BLE001 — report, keep going
                from benchmarks.common import record
                record(getattr(bench, "__name__", "bench"), 0.0,
                       f"ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
