"""Calibration-pipeline throughput — the training half's hot path.

Times three calibration protocols against one system (short steady-state
durations; throughput, not table quality):

* ``full``       — the complete plan/measure/solve/extend pipeline into a
                   fresh run directory;
* ``fractional`` — ``profile_fraction=0.25`` with a donor table: only the
                   sampled quarter of the suite is measured, everything
                   else is affine-mapped (the Fig. 14 bring-up path);
* ``resumed``    — the full campaign re-run against its completed run
                   directory: every record is loaded instead of re-measured,
                   leaving only plan + solve + extend (the
                   interrupted-calibration recovery cost).

Emits JSON (``--out``, default ``results/BENCH_calibrate_throughput.json``)
so the perf trajectory populates run over run, plus the repo's CSV line
format on stdout.  Run as a CI smoke step with artifact upload, same shape
as ``predict_throughput``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import time

from benchmarks.common import record
from repro.core import calibrate as cal

SYSTEM = "sim-v5e-air"
DONOR_SYSTEM = "sim-v5e-liquid"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_calibrate_throughput.json")
    ap.add_argument("--duration", type=float, default=8.0,
                    help="steady-state seconds per benchmark")
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--fraction", type=float, default=0.25)
    ap.add_argument("--min-resume-speedup", type=float, default=0.0,
                    help="fail unless the resumed pass beats full by this")
    args = ap.parse_args(argv)

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="bench_calibrate_"))
    kw = dict(duration_s=args.duration, repeats=args.repeats)

    t0 = time.perf_counter()
    table_full = cal.calibrate(SYSTEM, run_dir=tmp / "full", **kw)
    t_full = time.perf_counter() - t0

    # donor for the fractional pass: reuse the freshly calibrated table as
    # an affine source for the *other* system (throughput only)
    t0 = time.perf_counter()
    table_frac = cal.calibrate(DONOR_SYSTEM, run_dir=tmp / "frac",
                               profile_fraction=args.fraction,
                               donor=table_full, **kw)
    t_frac = time.perf_counter() - t0

    # resume against the completed full run: records load, nothing re-runs
    t0 = time.perf_counter()
    table_resumed = cal.calibrate(SYSTEM, run_dir=tmp / "full", **kw)
    t_resume = time.perf_counter() - t0

    identical = table_resumed == table_full
    n_specs = len(cal.plan(SYSTEM, **kw).specs)
    resume_speedup = t_full / max(t_resume, 1e-12)

    result = {
        "benchmark": "calibrate_throughput",
        "duration_s_per_bench": args.duration,
        "repeats": args.repeats,
        "n_specs": n_specs,
        "full_s": t_full,
        "fractional_s": t_frac,
        "fractional_fraction": args.fraction,
        "fractional_n_measured": int(table_frac.provenance["n_measured"]),
        "resumed_s": t_resume,
        "resume_speedup_vs_full": resume_speedup,
        "resumed_bitwise_identical": identical,
        "full_residual_rel": table_full.meta["residual_rel"],
        "fractional_r2_fit": table_frac.meta["r2_fit"],
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1) + "\n")

    record("calibrate_full", t_full * 1e6, f"n_specs={n_specs}")
    record("calibrate_fractional", t_frac * 1e6,
           f"measured={result['fractional_n_measured']}/{n_specs - 2}")
    record("calibrate_resumed", t_resume * 1e6,
           f"speedup_vs_full=x{resume_speedup:.1f} identical={identical}")
    print(f"wrote {out}")

    if not identical:
        print("FAIL: resumed table is not bitwise-identical to the full run",
              file=sys.stderr)
        return 1
    if resume_speedup < args.min_resume_speedup:
        print(f"FAIL: resume speedup x{resume_speedup:.1f} < required "
              f"x{args.min_resume_speedup:.1f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
