"""Measurement pipeline (§3.3) and the non-negative solve (§3.1)."""
import numpy as np
import pytest

from repro.core import measure, microbench, solver
from repro.core.opcount import OpCounts
from repro.hw.device import Program, SensorTrace
from repro.hw.systems import get_device


def _trace(power, hz=10.0):
    n = len(power)
    t = np.arange(n) / hz
    return SensorTrace(t, np.asarray(power, float), np.ones(n), np.full(n, 50.0))


def test_steady_state_detection_skips_startup():
    power = np.concatenate([np.linspace(60, 150, 50),
                            150 + np.random.default_rng(0).normal(0, 1, 550)])
    ss = measure.detect_steady_state(_trace(power))
    assert 148 < ss.power_w < 152
    assert ss.start_s >= 4.0


def test_constant_power_median_rejects_noise():
    rng = np.random.default_rng(1)
    p = 42 + rng.normal(0, 1.5, 300)
    p[10] = 400.0     # glitch sample
    assert abs(measure.constant_power(_trace(p)) - 42) < 1.0


def test_dynamic_energy_equation2():
    dev = get_device("sim-v5e-air")
    c = OpCounts()
    c.add("add.f32", 5e8)
    c.boundary_read_bytes = 1e6
    c.boundary_write_bytes = 1e6
    c.naive_bytes = 2e6
    c.max_buffer_bytes = 1e5
    c.dispatch_count = 1
    rec = dev.run(Program("t", c, iters=dev.iters_for_duration(c, 60.0)))
    p_const = measure.constant_power(dev.idle(30.0))
    ns = microbench._nanosleep_counts()
    p_static = measure.static_power(
        dev.run(Program("ns", ns, iters=dev.iters_for_duration(ns, 60.0),
                        is_nanosleep=True)), p_const)
    e_dyn = measure.dynamic_energy(rec, p_const, p_static)
    # Eq. 2: total = (const+static)*T + dynamic
    total = measure.total_energy(rec)
    assert abs(total - ((p_const + p_static) * rec.duration_s + e_dyn)) \
        < 0.02 * total


def test_trace_integration_matches_energy_counter():
    """Paper §3.3: trace integration within ~1% of the NVML counter."""
    dev = get_device("sim-v5e-air")
    c = OpCounts()
    c.add("mul.f32", 2e9)
    c.boundary_read_bytes = c.boundary_write_bytes = 5e5
    c.naive_bytes = 1e6
    c.max_buffer_bytes = 1e5
    c.dispatch_count = 1
    rec = dev.run(Program("t2", c, iters=dev.iters_for_duration(c, 120.0)))
    integ = measure.integrate_trace(rec.trace)
    assert abs(integ - rec.energy_counter_j) / rec.energy_counter_j < 0.015


def test_nnls_recovers_synthetic_system():
    rng = np.random.default_rng(7)
    n = 12
    a = rng.uniform(0, 1e9, (n, n)) * (rng.random((n, n)) < 0.4)
    np.fill_diagonal(a, rng.uniform(1e9, 2e9, n))
    x_true = rng.uniform(1e-12, 5e-11, n)
    b = a @ x_true
    sys_eq = solver.EnergySystem(classes=[f"c{i}" for i in range(n)],
                                 matrix=a, rhs=b,
                                 bench_names=[f"b{i}" for i in range(n)])
    sol = solver.solve_nonnegative(sys_eq)
    assert sol.residual_rel < 1e-6
    got = np.array([sol.energies[f"c{i}"] for i in range(n)])
    np.testing.assert_allclose(got, x_true, rtol=1e-4)


def test_square_system_property():
    """One microbenchmark per benched class (§3.1)."""
    suite = microbench.build_suite(0)
    targets = microbench.benched_classes(suite)
    assert len(targets) == len(set(targets)) == len(suite)


def test_solver_residual_near_zero_on_device():
    """Paper: 'we monitor the residual ... it remains zero'."""
    from repro.api import EnergyModel
    tab = EnergyModel.from_store("sim-v5e-air").table
    assert tab.meta["residual_rel"] < 0.02
