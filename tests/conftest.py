import os
import sys

# Tests run single-device (the dry-run owns the 512-device flag).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
