"""Chaos-hardened telemetry: fault injection, degradation, supervision.

Acceptance criteria covered here:
  (a) a disabled fault layer is a bitwise identity: a session run behind
      ``FaultySampler(plan=none)`` snapshots byte-identically to a bare
      sampler session;
  (b) injected fault counts are *exact*: the sanitizer's quarantine
      counters equal the ``ChaosReport``'s ``expected_quarantine`` and a
      drops-only plan's ``drop_events`` equals the aligner's gap count;
  (c) the same chaos seed reproduces a byte-identical ``ChaosReport``;
      the faulted stream is chunk-layout invariant (scalar vs chunked
      ingestion see the same faults and agree bitwise);
  (d) graceful degradation: under a heavy fault profile a monitored run
      and a serving run complete without exception, per-step energies
      plus the reported gap estimate still tile the run total, and no
      fault-induced recalibration fires;
  (e) the telemetry plane's shard supervisor restarts a crashed or hung
      worker (result bitwise-identical to the crash-free run) and folds a
      permanently failed shard without losing a joule;
  (f) corrupt store/calibration artifacts are quarantined aside with a
      clear error, and a calibrate resume re-measures only the bad
      record.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import EnergyModel
from repro.core.counting import OpCounts
from repro.hw.device import SensorTrace
from repro.telemetry import (ChaosPlan, ChaosReport, FaultySampler,
                             StreamSanitizer, SupervisorConfig,
                             TelemetryPlane, window_tiling)
from repro.telemetry.align import StreamAligner
from repro.telemetry.sampler import TraceReplaySampler

SYSTEM = "sim-v5e-air"


def _counts() -> OpCounts:
    c = OpCounts()
    c.add("dot.bf16", 1e7)
    c.mxu_macs_total = c.mxu_macs_aligned = 1e7
    c.add("add.f32", 2e5)
    c.boundary_read_bytes = 2e5
    c.boundary_write_bytes = 1e5
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


def _regular_trace(n: int = 5000, dt: float = 0.01) -> SensorTrace:
    """Strictly increasing t and p: any repeat/reorder is injected."""
    t = dt * np.arange(1, n + 1)
    p = 100.0 + 1e-4 * np.arange(n)
    return SensorTrace(t, p, np.full(n, 0.5), np.full(n, 40.0))


def _session_snapshot(chaos, *, chunk_size=512, steps=6):
    """One monitored run on a fresh model (fresh device noise stream)."""
    model = EnergyModel.from_store(SYSTEM)
    s = model.stream(_counts(), name="chaos", chaos=chaos,
                     min_duration_s=6.0, chunk_size=chunk_size)
    for i in range(steps):
        s.step(i)
    s.finish()
    return s.snapshot(), s


# ---------------------------------------------------------------------------
# (a) identity when disabled
# ---------------------------------------------------------------------------
def test_disabled_fault_layer_is_bitwise_identity():
    bare, _ = _session_snapshot(None)
    wrapped, _ = _session_snapshot(ChaosPlan.profile("none", seed=123))
    assert json.dumps(bare, sort_keys=True) == \
        json.dumps(wrapped, sort_keys=True)


def test_disabled_plan_chunks_are_the_inner_chunks():
    sampler = TraceReplaySampler(_regular_trace(100))
    fs = FaultySampler(sampler, ChaosPlan())
    ref = TraceReplaySampler(_regular_trace(100))
    for (t, p, u, c), (rt, rp, ru, rc) in zip(fs.chunks(32), ref.chunks(32)):
        np.testing.assert_array_equal(t, rt)
        np.testing.assert_array_equal(p, rp)


# ---------------------------------------------------------------------------
# (b) exact counters
# ---------------------------------------------------------------------------
def test_quarantine_counters_match_injected_exactly():
    plan = ChaosPlan(seed=11, nan_fraction=0.01, nan_burst=3,
                     spike_fraction=0.005, stale_fraction=0.004,
                     stale_run=2, dup_fraction=0.003, swap_fraction=0.003,
                     granularity=1000)
    fs = FaultySampler(TraceReplaySampler(_regular_trace()), plan)
    san = StreamSanitizer()
    kept = 0
    for t, p, u, c in fs.chunks(256):
        t2, *_ = san.chunk(t, p, u, c)
        kept += int(np.asarray(t2).size)
    rep = fs.report
    assert rep.samples_in == 5000 and rep.granules == 5
    want = rep.expected_quarantine
    assert san.quarantined_nonfinite == want["nonfinite"] > 0
    assert san.quarantined_spike == want["spikes"] > 0
    assert san.quarantined_out_of_order == want["out_of_order"] > 0
    assert san.quarantined == sum(want.values())
    assert kept == rep.samples_out - san.quarantined
    # trace power is strictly increasing, so every repeat is injected
    assert san.stale_suspects == rep.stale_samples > 0


def test_drop_events_match_aligner_gap_count_exactly():
    dt = 0.01
    plan = ChaosPlan(seed=5, drop_fraction=0.05, granularity=1000)
    fs = FaultySampler(TraceReplaySampler(_regular_trace(dt=dt)), plan)
    aligner = StreamAligner(gap_threshold_s=1.5 * dt)
    for t, p, u, c in fs.chunks(512):
        aligner.add_samples(t, p)
    aligner.close()
    rep = fs.report
    assert rep.dropped > 0
    assert aligner.gap_events == rep.drop_events > 0
    # every gap spans exactly (run length + 1) regular steps
    assert aligner.gap_seconds == pytest.approx(
        dt * (rep.dropped + rep.drop_events), rel=1e-9)


# ---------------------------------------------------------------------------
# (c) determinism + chunk-layout invariance
# ---------------------------------------------------------------------------
def test_same_seed_byte_identical_report():
    plan = ChaosPlan.profile("heavy", seed=42)

    def run(chunk):
        fs = FaultySampler(TraceReplaySampler(_regular_trace()), plan)
        for _ in fs.chunks(chunk):
            pass
        return fs.report.to_json()

    assert run(256) == run(256)
    assert run(256) == run(64)          # granule layout, not consumer chunk
    other = FaultySampler(TraceReplaySampler(_regular_trace()),
                          dataclasses.replace(plan, seed=43))
    for _ in other.chunks(256):
        pass
    assert other.report.to_json() != run(256)


def test_faulty_sampler_is_single_pass():
    fs = FaultySampler(TraceReplaySampler(_regular_trace(100)),
                       ChaosPlan(seed=0, drop_fraction=0.1))
    for _ in fs.chunks(64):
        pass
    with pytest.raises(RuntimeError, match="single-pass"):
        for _ in fs.chunks(64):
            pass


def test_scalar_and_chunked_ingestion_agree_under_chaos():
    plan = ChaosPlan(seed=9, drop_fraction=0.03, nan_fraction=0.01,
                     spike_fraction=0.005, dup_fraction=0.002,
                     swap_fraction=0.002, granularity=1000)
    chunked, _ = _session_snapshot(plan, chunk_size=512)
    scalar, _ = _session_snapshot(plan, chunk_size=None)
    assert json.dumps(chunked, sort_keys=True) == \
        json.dumps(scalar, sort_keys=True)


def test_sanitizer_scalar_chunk_same_decisions():
    t = np.array([1.0, 2.0, np.nan, 3.0, 2.5, 4.0, 4.0, 5.0])
    p = np.array([100.0, 1e7, 101.0, 102.0, 103.0, 104.0, 104.0, 104.0])
    a = StreamSanitizer()
    ta, *_ = a.chunk(t, p, np.full(8, np.nan), np.full(8, np.nan))
    b = StreamSanitizer()
    kept = [s for i, s in enumerate(t)
            if b.sample(type("S", (), {"t_s": t[i], "power_w": p[i],
                                       "util": np.nan, "temp_c": np.nan})())]
    assert list(ta) == kept == [1.0, 3.0, 4.0, 5.0]
    assert a.state_dict() == b.state_dict()


# ---------------------------------------------------------------------------
# (d) graceful degradation
# ---------------------------------------------------------------------------
def test_heavy_chaos_monitor_completes_and_tiles():
    plan = ChaosPlan.profile("heavy", seed=7)
    snap, s = _session_snapshot(plan, steps=8)
    summary = s.summary
    # conservation: windows (including the gap estimate folded into them)
    # still tile the stream total
    tiling = window_tiling(s.windows)
    assert tiling["startup_j"] + sum(tiling["step_j"]) == pytest.approx(
        summary.measured_total_j, rel=1e-9)
    # the gap portion is accounted, never double-counted
    assert sum(w.gap_j for w in s.windows) <= summary.gap_j + 1e-9
    h = snap["health"]
    assert h["quarantined"] > 0
    assert h["quarantined"] == s.sanitizer.quarantined
    assert 0.0 <= h["gap_j"] <= summary.measured_total_j
    # faults must degrade confidence, never trigger a table rewrite
    assert summary.recalibrations == []


def test_heavy_chaos_serve_completes_with_health():
    model = EnergyModel.from_store(SYSTEM)
    from repro.serve.scheduler import Request
    report = model.serve(
        requests=[Request("r0", "a", 8, 4), Request("r1", "b", 8, 4)],
        chaos=ChaosPlan.profile("heavy", seed=1),
        min_phase_seconds=4.0)
    assert report.measured_total_j > 0
    h = report.health
    assert h["samples"] > 0
    assert set(h) >= {"quarantined", "gap_j", "gap_s", "n_gaps",
                      "low_confidence_windows"}
    assert report.recalibrations == []
    assert report.snapshot()["health"] == h


def test_low_coverage_windows_skip_drift():
    from repro.telemetry.align import AlignedWindow
    from repro.telemetry.attrib import OnlineAttributor
    model = EnergyModel.from_store(SYSTEM)
    att = OnlineAttributor(model.predictor)
    w = AlignedWindow(step=0, name="w", t_start_s=0.0, t_end_s=1.0,
                      measured_j=100.0, n_samples=3, covered_s=1.0,
                      clipped=False, gap_j=80.0, gap_s=0.8)
    assert w.solid_coverage < att.min_solid_coverage
    out = att.attribute(w, _counts())
    assert out.low_confidence
    assert att.low_confidence_total == 1
    assert att.detector._n == 0         # never fed the drift detector
    solid = AlignedWindow(step=1, name="w", t_start_s=1.0, t_end_s=2.0,
                          measured_j=100.0, n_samples=50, covered_s=1.0,
                          clipped=False)
    out2 = att.attribute(solid, _counts())
    assert not out2.low_confidence
    assert att.detector._n == 1


# ---------------------------------------------------------------------------
# (e) shard supervisor
# ---------------------------------------------------------------------------
def _plane_run(chaos, *, n_shards=2, max_restarts=2,
               heartbeat_timeout_s=15.0):
    """Three sessions on a process-runner plane, workers do the ingest.

    A fresh model per call: bitwise-comparable runs need a fresh sim
    device (its sensor-noise RNG is a device-lifetime stream)."""
    pytest.importorskip("multiprocessing.shared_memory")
    model = EnergyModel.from_store(SYSTEM)
    plane = model.plane(
        n_shards, runner="process", chaos=chaos,
        supervisor=SupervisorConfig(heartbeat_timeout_s=heartbeat_timeout_s,
                                    max_restarts=max_restarts,
                                    backoff_s=0.05))
    for i in range(3):
        s = model.stream(_counts(), name=f"w{i}", recalibrate=None,
                         chunk_size=512)
        plane.register(s, f"dev{i}/w{i}")
        for _ in range(3):
            s.step()
    plane.finish_all()
    return plane


def test_supervisor_restarts_crashed_worker_bitwise():
    crash = dataclasses.replace(ChaosPlan(), crash_shards=(0,),
                                crash_attempts=1)
    ref = _plane_run(None)
    hit = _plane_run(crash)
    assert hit.restarts == 1
    assert [e["cause"] for e in hit._supervisor_events] == ["crashed"]
    snap = hit.snapshot()
    sup = snap.pop("supervisor")
    assert sup["restarts"] == 1 and sup["folded_shards"] == []
    assert json.dumps(ref.snapshot(), sort_keys=True) == \
        json.dumps(snap, sort_keys=True)


def test_supervisor_times_out_hung_worker():
    hang = dataclasses.replace(ChaosPlan(), hang_shards=(1,),
                               crash_attempts=1, hang_s=60.0)
    plane = _plane_run(hang, heartbeat_timeout_s=1.0)
    assert plane.restarts == 1
    assert plane._supervisor_events[0]["cause"] == "heartbeat-timeout"
    assert plane.snapshot()["fleet"]["measured_j"] > 0


def test_permanent_shard_failure_folds_without_losing_joules():
    dead = dataclasses.replace(ChaosPlan(), crash_shards=(0,),
                               crash_attempts=99)
    ref = _plane_run(None, max_restarts=1)
    hit = _plane_run(dead, max_restarts=1)
    assert hit._folded == [0]
    assert [sh.id for sh in hit.shards] == [1]
    snap = hit.snapshot()
    sup = snap.pop("supervisor")
    assert sup["folded_shards"] == [0] and len(sup["events"]) == 2
    # the in-parent fallback drain preserves exact accounting: the fleet
    # block (and every session) matches the crash-free run bitwise
    assert json.dumps(ref.snapshot(), sort_keys=True) == \
        json.dumps(snap, sort_keys=True)


# ---------------------------------------------------------------------------
# (f) store / calibration corruption
# ---------------------------------------------------------------------------
def test_truncated_table_quarantined_and_retrained_path_free(tmp_path):
    from repro.core.store import TableStore
    model = EnergyModel.from_store(SYSTEM)
    store = TableStore(tmp_path)
    path = store.put(model.table)
    raw = path.read_text()
    path.write_text(raw[:len(raw) // 2])        # torn write / truncation
    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert store.get(SYSTEM) is None
    assert not path.exists()                    # publish path freed
    assert path.with_name(path.name + ".corrupt").exists()


def test_value_corruption_caught_by_checksum(tmp_path):
    from repro.core.table import EnergyTable, TableSchemaError
    model = EnergyModel.from_store(SYSTEM)
    path = tmp_path / "t.json"
    model.table.save(path)
    d = json.loads(path.read_text())
    assert "checksum" in d
    d["p_const"] = d["p_const"] + 1.0           # silent value-level rot
    path.write_text(json.dumps(d))
    with pytest.raises(TableSchemaError, match="checksum mismatch"):
        EnergyTable.load(path)
    # a round trip with an intact checksum still loads
    model.table.save(path)
    assert EnergyTable.load(path) == model.table


def test_corrupt_calibration_record_remeasured_alone(tmp_path):
    from repro.core import calibrate as cal
    p = cal.plan(SYSTEM, duration_s=2.0, repeats=1)
    ledger = cal.RunLedger(tmp_path / "run")
    ledger.bind(p)
    cal.run_measurements(p, ledger, limit=3)
    done = sorted(ledger.records)
    assert len(done) == 3
    victim = done[0]
    rec_path = (tmp_path / "run" / "records"
                / cal.RunLedger._fname(victim))
    rec_path.write_text("{ not json")
    fresh = cal.RunLedger(tmp_path / "run")
    with pytest.warns(RuntimeWarning, match="re-measured"):
        fresh.bind(p)
    missing = {s.spec_id for s in fresh.missing(p)}
    assert victim in missing                    # the bad record, and
    for ok in done[1:]:                         # ONLY the bad record,
        assert ok not in missing                # gets re-measured
    assert rec_path.with_name(rec_path.name + ".corrupt").exists()


def test_corrupt_plan_fingerprint_is_loud(tmp_path):
    from repro.core import calibrate as cal
    p = cal.plan(SYSTEM, duration_s=2.0, repeats=1)
    ledger = cal.RunLedger(tmp_path / "run")
    ledger.bind(p)
    (tmp_path / "run" / "plan.json").write_text("xx{")
    fresh = cal.RunLedger(tmp_path / "run")
    with pytest.warns(RuntimeWarning, match="quarantined corrupt"):
        with pytest.raises(cal.CalibrationError, match="corrupted"):
            fresh.bind(p)


def test_chaos_plan_json_round_trip():
    plan = ChaosPlan.profile("heavy", seed=3)
    d = json.loads(plan.to_json())
    d["crash_shards"] = tuple(d["crash_shards"])
    d["hang_shards"] = tuple(d["hang_shards"])
    assert ChaosPlan(**d) == plan
    assert not ChaosPlan.profile("none").enabled
    report = ChaosReport()
    assert json.loads(report.to_json())["dropped"] == 0
