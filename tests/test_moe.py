"""MoE: sort-based dispatch vs a direct per-token reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.models import moe
from repro.models.layers import init_from_specs
import pytest

pytestmark = pytest.mark.slow   # heavy model/distributed tier


def _setup(t=64, d=16, ff=32, e=4, k=2, cap=8.0):
    cfg = dataclasses.replace(
        cfgs.get_smoke_config("arctic-480b"), d_model=d, d_ff=ff,
        n_experts=e, moe_top_k=k, moe_capacity_factor=cap, dtype="float32")
    params = init_from_specs(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d), jnp.float32)
    return cfg, params, x


def _dense_reference(x, p, cfg):
    """Every token through its top-k experts directly (no capacity)."""
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for ei in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["w_gate"][ei]) * (x @ p["w_in"][ei])
        outs.append(h @ p["w_out"][ei])
    expert_out = jnp.stack(outs, 1)                     # [T, E, d]
    sel = jnp.take_along_axis(expert_out, idx[..., None], axis=1)
    return (sel * gate[..., None]).sum(1)


def test_moe_matches_dense_reference_when_no_drops():
    cfg, params, x = _setup(cap=16.0)    # capacity high: nothing dropped
    y, aux = moe.moe_mlp(x, params, cfg)
    want = _dense_reference(x, params, cfg)
    assert float(aux["drop_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_tokens():
    cfg, params, x = _setup(cap=0.25)
    y, aux = moe.moe_mlp(x, params, cfg)
    assert float(aux["drop_fraction"]) > 0.0
    assert np.isfinite(np.asarray(y)).all()


def test_moe_aux_losses_finite_and_balanced_lower_bound():
    cfg, params, x = _setup()
    _, aux = moe.moe_mlp(x, params, cfg)
    # Switch LB loss >= 1 (equality at perfect balance)
    assert float(aux["load_balance"]) >= 0.99
    assert np.isfinite(float(aux["router_z"]))


def test_capacity_rounding():
    cfg, _, _ = _setup()
    c = moe.capacity(cfg, 1000)
    assert c % 8 == 0 and c >= 1000 * cfg.moe_top_k / cfg.n_experts
