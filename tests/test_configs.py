"""Config fidelity: analytic parameter counts match the published sizes."""
import pytest

from repro import configs as cfgs
from repro.configs.base import SHAPES, shape_applicable

# (arch, expected params, rel tolerance).  MoE models use total params.
EXPECTED = {
    "qwen2-0.5b": (0.49e9, 0.30),
    "gemma2-27b": (27e9, 0.25),
    "h2o-danube-3-4b": (4.0e9, 0.30),
    "minicpm3-4b": (4.0e9, 0.35),
    "mamba2-2.7b": (2.7e9, 0.30),
    "zamba2-2.7b": (2.7e9, 0.35),
    "qwen2-vl-7b": (7.6e9, 0.30),
    "whisper-small": (0.24e9, 0.45),
    "arctic-480b": (480e9, 0.25),
    "llama4-scout-17b-a16e": (109e9, 0.35),
}


@pytest.mark.parametrize("arch", cfgs.ARCHS)
def test_param_count_matches_published(arch):
    cfg = cfgs.get_config(arch)
    n = cfg.param_count()
    want, tol = EXPECTED[arch]
    assert abs(n - want) / want < tol, f"{arch}: {n/1e9:.2f}B vs {want/1e9}B"


def test_llama4_active_params_about_17b():
    cfg = cfgs.get_config("llama4-scout-17b-a16e")
    active = cfg.active_param_count()
    assert 10e9 < active < 25e9


def test_arctic_active_much_smaller_than_total():
    cfg = cfgs.get_config("arctic-480b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()


def test_long_context_applicability():
    """DESIGN.md §Arch-applicability: exactly these run long_500k."""
    runs = {a for a in cfgs.ARCHS
            if shape_applicable(cfgs.get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"gemma2-27b", "h2o-danube-3-4b", "mamba2-2.7b",
                    "zamba2-2.7b"}


def test_smoke_configs_are_small():
    for arch in cfgs.ARCHS:
        cfg = cfgs.get_smoke_config(arch)
        assert cfg.param_count() < 5e7, arch
        assert cfg.family == cfgs.get_config(arch).family


def test_exact_published_dims():
    c = cfgs.get_config("gemma2-27b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (46, 4608, 32, 16, 36864, 256000)
    c = cfgs.get_config("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.moe_top_k) == (35, 7168, 56, 8, 4864,
                                                   32000, 128, 2)
    c = cfgs.get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (64, 2560,
                                                             50280, 128)
    c = cfgs.get_config("qwen2-0.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (24, 896, 14, 2, 4864, 151936, True)
