"""The staged calibration pipeline: resume identity, schema migration,
fractional donor bootstrap, and dict-view/vector-path parity."""
import json

import numpy as np
import pytest

from repro.api import EnergyModel
from repro.core import calibrate as cal
from repro.core import coverage, isa
from repro.core.store import TableStore, migrate_table_dict
from repro.core.table import (DIRECT, MISS, SCALED, SCHEMA_VERSION,
                              EnergyTable, TableSchemaError)

SYSTEM = "sim-v5e-air"
FAST = dict(duration_s=3.0, repeats=2)     # throughput settings, not quality


@pytest.fixture(scope="module")
def fast_plan():
    return cal.plan(SYSTEM, **FAST)


# ---------------------------------------------------------------------------
# Plan stage.
# ---------------------------------------------------------------------------
def test_plan_is_square_and_probed(fast_plan):
    p = fast_plan
    assert len(p.targets) == len(set(p.targets)) == len(p.suite)
    assert p.measured == p.targets                 # full calibration
    kinds = [s.kind for s in p.specs]
    assert kinds[:2] == [cal.KIND_IDLE, cal.KIND_NANOSLEEP]
    assert kinds[2:] == [cal.KIND_BENCH] * len(p.suite)


def test_fractional_plan_samples_and_forces(fast_plan):
    donor = EnergyTable(system="donor", p_const=40.0, p_static=50.0,
                        direct={t: 1e-11 for t in fast_plan.targets[:-3]})
    p = cal.plan(SYSTEM, profile_fraction=0.25, donor=donor, seed=1, **FAST)
    assert 0 < len(p.measured) < len(p.targets)
    # classes the donor cannot predict must always be measured
    for t in fast_plan.targets[-3:]:
        assert t in p.measured
    with pytest.raises(cal.CalibrationError, match="donor"):
        cal.plan(SYSTEM, profile_fraction=0.25, **FAST)


# ---------------------------------------------------------------------------
# Measure + resume: the acceptance criterion.
# ---------------------------------------------------------------------------
def test_interrupted_resume_is_bitwise_identical(fast_plan, tmp_path):
    p = fast_plan
    dev = None  # each stage call resolves its own device: order independence

    one_shot = cal.RunLedger(tmp_path / "oneshot")
    one_shot.bind(p)
    cal.run_measurements(p, one_shot)
    table_a = cal.extend(cal.solve(p, one_shot))

    # interrupt after k records, then resume from disk in a "new process"
    k = 9
    first = cal.RunLedger(tmp_path / "resumed")
    first.bind(p)
    cal.run_measurements(p, first, limit=k)
    assert len(first.records) == k
    with pytest.raises(cal.CalibrationError, match="pending"):
        cal.solve(p, first)

    second = cal.RunLedger(tmp_path / "resumed")
    second.bind(p)                      # loads the k completed records
    assert len(second.records) == k
    cal.run_measurements(p, second)
    table_b = cal.extend(cal.solve(p, second))

    assert table_a == table_b           # bitwise: == on every float
    np.testing.assert_array_equal(table_a.energy_vectors()[1],
                                  table_b.energy_vectors()[1])
    assert table_b.meta["residual_rel"] < 0.05


def test_ledger_rejects_mismatched_plan(fast_plan, tmp_path):
    ledger = cal.RunLedger(tmp_path / "run")
    ledger.bind(fast_plan)
    cal.run_measurements(fast_plan, ledger, limit=1)
    other = cal.plan(SYSTEM, duration_s=5.0, repeats=1)
    fresh = cal.RunLedger(tmp_path / "run")
    with pytest.raises(cal.CalibrationError, match="different calibration"):
        fresh.bind(other)
    fresh.bind(other, resume=False)     # explicit discard starts over
    assert fresh.records == {}


def test_calibrate_end_to_end_publishes(tmp_path):
    store = TableStore(tmp_path)
    table = cal.calibrate(SYSTEM, run_dir=store.run_dir(SYSTEM),
                          store=store, **FAST)
    assert store.get(SYSTEM) == table
    assert table.provenance["mode"] == "full"
    assert len(table.direct) == len(cal.plan(SYSTEM, **FAST).targets)


def test_unattended_path_discards_obsolete_records(tmp_path):
    store = TableStore(tmp_path)
    run_dir = store.run_dir(SYSTEM)
    stale = cal.plan(SYSTEM, duration_s=7.0, repeats=1)   # "old version" plan
    ledger = cal.RunLedger(run_dir)
    ledger.bind(stale)
    cal.run_measurements(stale, ledger, limit=2)
    # explicit callers fail loud on the mismatched plan ...
    with pytest.raises(cal.CalibrationError, match="different calibration"):
        cal.calibrate(SYSTEM, run_dir=run_dir, **FAST)
    # ... the unattended store path warns, discards, and recovers
    with pytest.warns(RuntimeWarning, match="obsolete"):
        table = store.get_or_train(
            SYSTEM, lambda s: cal.calibrate(
                s, run_dir=run_dir, on_plan_mismatch="discard", **FAST))
    assert table.provenance["mode"] == "full"


def test_fractional_table_never_shadows_full_profile(tmp_path):
    store = TableStore(tmp_path)
    full = cal.calibrate(SYSTEM, store=store, **FAST)
    donor = cal.calibrate("sim-v5e-liquid", **FAST)
    frac = cal.calibrate(SYSTEM, profile_fraction=0.3, donor=donor,
                         seed=1, **FAST)
    with pytest.warns(RuntimeWarning, match="fully-profiled"):
        assert cal.publish(frac, store) is None
    assert store.get(SYSTEM) == full                 # full table untouched
    assert cal.publish(frac, store, allow_downgrade=True) is not None
    assert store.get(SYSTEM).provenance["mode"] == "fractional"
    # with no full profile in the store, bootstrap publishing just works
    store.evict(SYSTEM)
    assert cal.publish(frac, store) is not None


# ---------------------------------------------------------------------------
# v1 -> v2 schema migration.
# ---------------------------------------------------------------------------
def _v1_payload():
    return {
        "schema": 1,
        "system": SYSTEM,
        "p_const": 41.5,
        "p_static": 48.25,
        "direct": {"add.f32": 1e-11, "dot.bf16": 1.3e-12, "hbm.read": 4.5e-11,
                   "exp.f32": 3.4e-11, "slice": 0.0},
        "scaled": {"vmem.write": 1.7e-12},
        "bucket_means": {"vpu_simple": 1e-11, "mxu": 1.3e-12},
        "meta": {"isa_gen": 0.0, "residual_rel": 0.01},
    }


def test_v1_table_loads_through_store_migration(tmp_path):
    store = TableStore(tmp_path)
    v1_path = tmp_path / f"{SYSTEM}__gen0__v1.json"
    v1_path.write_text(json.dumps(_v1_payload()))

    table = store.get(SYSTEM)
    assert table is not None
    assert table.p_const == 41.5
    assert dict(table.direct.items()) == _v1_payload()["direct"]
    assert dict(table.scaled.items()) == _v1_payload()["scaled"]
    assert table.provenance["migrated_from_schema"] == 1
    # migrated table is republished under the current-version path
    v2_path = store.path_for(SYSTEM)
    assert v2_path.exists()
    assert json.loads(v2_path.read_text())["schema"] == SCHEMA_VERSION
    assert store.get(SYSTEM) == table


def test_migrate_table_dict_paths():
    d = migrate_table_dict(_v1_payload())
    assert d["schema"] == SCHEMA_VERSION
    assert d["provenance"]["migrated_from_schema"] == 1
    with pytest.raises(TableSchemaError, match="no migration path"):
        migrate_table_dict({"schema": -1})


# ---------------------------------------------------------------------------
# Dict-view / vector-path parity on the array-backed table.
# ---------------------------------------------------------------------------
def test_lookup_parity_dict_view_vs_vector_path():
    table = EnergyTable.from_dict(
        {k: v for k, v in _v1_payload().items() if k != "schema"})
    # include an interned-but-unknown class so the bucket path is exercised
    isa.CLASS_INDEX.intern("mystery.f32")
    n = len(isa.CLASS_INDEX)
    e_direct, e_pred = table.energy_vectors(n)
    for i in range(n):
        cls = isa.CLASS_INDEX.name(i)
        v_pred, how = table.lookup(cls, mode="pred")
        v_direct, how_d = table.lookup(cls, mode="direct")
        assert e_pred[i] == v_pred, cls
        assert e_direct[i] == (v_direct if how_d == DIRECT else 0.0), cls
    # explicit zero direct entries are hits, not misses
    assert table.lookup("slice") == (0.0, DIRECT)
    assert table.lookup("vmem.write") == (1.7e-12, SCALED)
    assert table.lookup("does.not.exist")[1] == MISS


def test_view_mutation_invalidates_vectors():
    table = EnergyTable.from_dict(
        {k: v for k, v in _v1_payload().items() if k != "schema"})
    i = isa.CLASS_INDEX.id("add.f32")
    assert table.energy_vectors()[1][i] == 1e-11
    table.direct["add.f32"] *= 2          # write-through dict view
    assert table.energy_vectors()[1][i] == 2e-11
    del table.direct["add.f32"]
    assert "add.f32" not in table.direct
    table.bucket_means["vpu_simple"] = 9e-12
    _, e_pred = table.energy_vectors()
    assert e_pred[i] == 9e-12             # direct gone -> bucket mean
    # inherited dict mutators must invalidate too
    table.bucket_means.setdefault("vpu_trans", 5e-12)
    j = isa.CLASS_INDEX.id("exp.f32")
    assert table.energy_vectors()[1][j] == 3.4e-11    # direct entry
    del table.direct["exp.f32"]
    assert table.energy_vectors()[1][j] == 5e-12      # setdefault'd bucket
    table.direct.setdefault("exp.f32", 1e-12)
    assert table.energy_vectors()[1][j] == 1e-12


def test_bucket_means_bincount_matches_naive():
    table = EnergyTable.from_dict(
        {k: v for k, v in _v1_payload().items() if k != "schema"})
    coverage.compute_bucket_means(table)
    naive = {}
    for cls, e in list(table.direct.items()) + list(table.scaled.items()):
        b = isa.bucket_of(cls)
        if b is not None and e > 0:
            naive.setdefault(b, []).append(e)
    want = {b: float(np.mean(v)) for b, v in naive.items()}
    assert set(table.bucket_means) == set(want)
    for b in want:
        assert table.bucket_means[b] == pytest.approx(want[b], rel=1e-12)


# ---------------------------------------------------------------------------
# Fractional (Fig. 14) mode through the pipeline + facade.
# ---------------------------------------------------------------------------
def test_fractional_calibration_smoke(tmp_path):
    donor = cal.calibrate(SYSTEM, run_dir=tmp_path / "donor", **FAST)
    model = EnergyModel.train("sim-v5e-liquid", profile_fraction=0.3,
                              donor=donor, seed=3, **FAST)
    t = model.table
    assert t.provenance["mode"] == "fractional"
    assert t.provenance["n_measured"] < t.provenance["n_targets"]
    # every donor class is represented: measured or affine-predicted
    assert set(t.direct) >= set(donor.direct)
    assert t.meta["r2_fit"] > 0.8
    # the hybrid prices work sensibly (same order as the donor's energies)
    for cls in ("dot.bf16", "hbm.read"):
        assert 0.1 * donor.direct[cls] < t.direct[cls] < 10 * donor.direct[cls]
