"""Integration: the full Wattchmen pipeline reproduces the paper's claims
(structure-for-structure; absolute MAPEs are cleaner than hardware — see
EXPERIMENTS.md)."""
import numpy as np
import pytest

from repro.api import EnergyModel
from repro.core.evaluate import evaluate_system
from repro.hw.systems import get_device


@pytest.fixture(scope="module")
def v5e_report():
    return evaluate_system("sim-v5e-air")


def test_wattchmen_beats_baselines(v5e_report):
    """Table 4 ordering: Pred <= Direct < Guser/AccelWattch."""
    t = v5e_report.mape_table()
    assert t["wattchmen_pred"] <= t["wattchmen_direct"] + 0.5
    assert t["wattchmen_pred"] < t["accelwattch"]
    assert t["wattchmen_pred"] < t["guser"]


def test_v5e_mape_reasonable(v5e_report):
    assert v5e_report.mape_table()["wattchmen_pred"] < 10.0


def test_cooling_generalization():
    """Table 5: same accuracy on the liquid-cooled system."""
    rep = evaluate_system("sim-v5e-liquid", with_accelwattch=False,
                          with_guser=False)
    assert rep.mape_table()["wattchmen_pred"] < 12.0


@pytest.mark.parametrize("system", ["sim-v5p-air", "sim-v6e-air"])
def test_new_generation_bucketing_recovers_coverage(system):
    """Tables 6/7: Direct coverage drops on newer gens (new MMA forms);
    Pred recovers accuracy via bucketing."""
    rep = evaluate_system(system, with_accelwattch=False, with_guser=False)
    t = rep.mape_table()
    assert rep.mean_coverage("direct") < 0.95
    assert t["wattchmen_pred"] <= t["wattchmen_direct"]
    assert t["wattchmen_pred"] < 18.0


def test_coefficient_recovery_scale():
    """Recovered energies must be the right order of magnitude (the NNLS
    redistributes within collinear groups, but never by orders)."""
    tab = EnergyModel.from_store("sim-v5e-air").table
    hid = get_device("sim-v5e-air")._hidden
    ratios = []
    for cls, est in tab.direct.items():
        true = hid.coeff(cls)
        if true > 0 and est > 0:
            ratios.append(est / true)
    ratios = np.array(ratios)
    assert np.median(np.abs(np.log(ratios))) < np.log(1.6)
    # headline classes tightly recovered
    for cls in ("dot.bf16", "dot.f32", "hbm.read", "ici.all_reduce"):
        r = tab.direct[cls] / hid.coeff(cls)
        assert 0.6 < r < 1.7, (cls, r)


def test_breakdown_sums_to_total(v5e_report):
    for r in v5e_report.results:
        s = sum(r.breakdown.values())
        assert abs(s - r.predictions["wattchmen_pred"]) < 1e-6 * max(s, 1.0)


def test_linearity_of_dynamic_energy():
    """Fig. 5: dynamic energy linear in instruction count (base, +mul, 2x)."""
    import jax, jax.numpy as jnp
    from repro.core import measure, microbench, opcount
    from repro.hw.device import Program

    dev = get_device("sim-v5e-air")
    p_const = measure.constant_power(dev.idle(30.0))
    ns = microbench._nanosleep_counts()
    p_static = measure.static_power(
        dev.run(Program("ns", ns, iters=dev.iters_for_duration(ns, 60.0),
                        is_nanosleep=True)), p_const)

    def make(n_mul, n_add):
        def fn(c0):
            def body(c, _):
                for _ in range(n_mul):
                    c = c * 1.0001
                for _ in range(n_add):
                    c = c + 0.5
                return c, ()
            c, _ = jax.lax.scan(body, c0, None, length=64)
            return c
        return opcount.count_fn(fn, jax.ShapeDtypeStruct((128, 1024),
                                                         jnp.float32))

    iters = dev.iters_for_duration(make(16, 16), 60.0)
    e = {}
    for name, (m, a) in {"base": (16, 16), "add_mul": (32, 16),
                         "x2": (32, 32)}.items():
        rec = dev.run(Program("lin", make(m, a), iters=iters))
        e[name] = measure.dynamic_energy(rec, p_const, p_static) / rec.iters
    # E(2x) - E(base) == E(base); E(add_mul) between them
    assert e["base"] < e["add_mul"] < e["x2"]
    np.testing.assert_allclose(e["x2"], 2 * e["base"], rtol=0.12)
