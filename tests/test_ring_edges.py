"""SampleRing / SharedSampleRing edge cases: bulk-extend accounting.

``extend`` must match ``append`` called per sample *exactly* — same
visible window, same ``total``, same ``dropped`` — for every edge the
chaos layer can produce: empty chunks, chunks that exactly fill the
ring, overflow bursts larger than capacity, and arbitrary interleavings
of the two paths starting from any head position.
"""
import math

import numpy as np
import pytest

from repro.telemetry.sampler import PowerSample, SampleRing

try:
    from repro.telemetry.sampler import SharedSampleRing
except ImportError:                                  # platform without shm
    SharedSampleRing = None


def _chunk(n, start=0.0, dt=0.01):
    t = start + dt * np.arange(1, n + 1)
    return t, 100.0 + t, 0.5 * np.ones(n), 40.0 * np.ones(n)


def _reference(capacity, chunks):
    """Ground truth: the per-sample append path."""
    ring = SampleRing(capacity)
    for t, p, u, c in chunks:
        for i in range(len(t)):
            ring.append(PowerSample(t[i], p[i], u[i], c[i]))
    return ring


def _assert_same(a: SampleRing, b: SampleRing):
    assert a.total == b.total
    assert a.dropped == b.dropped
    assert len(a) == len(b)
    ta, pa = a.arrays()
    tb, pb = b.arrays()
    np.testing.assert_array_equal(ta, tb)
    np.testing.assert_array_equal(pa, pb)


def test_zero_length_extend_is_a_noop():
    ring = SampleRing(8)
    ring.extend(*_chunk(3))
    before = (ring.total, ring.dropped, len(ring))
    assert ring.extend([], []) == 0
    assert ring.extend(np.empty(0), np.empty(0),
                       np.empty(0), np.empty(0)) == 0
    assert (ring.total, ring.dropped, len(ring)) == before
    t, p = ring.arrays()
    assert t.size == 3


def test_mismatched_field_lengths_fail_loud():
    ring = SampleRing(8)
    with pytest.raises(ValueError, match="lengths disagree"):
        ring.extend([1.0, 2.0], [100.0])             # short power
    with pytest.raises(ValueError, match="lengths disagree"):
        ring.extend([1.0, 2.0], [100.0, 101.0], util=[0.5])
    with pytest.raises(ValueError, match="lengths disagree"):
        ring.extend([1.0], [100.0], temp_c=[40.0, 41.0])
    # a scalar power would otherwise broadcast silently
    with pytest.raises(ValueError, match="lengths disagree"):
        ring.extend([1.0, 2.0], 100.0)
    assert ring.total == 0 and ring.dropped == 0     # nothing half-applied


def test_exact_fill_then_single_overflow():
    cap = 16
    ring = SampleRing(cap)
    assert ring.extend(*_chunk(cap)) == cap
    assert ring.total == cap and ring.dropped == 0 and len(ring) == cap
    ring.extend(*_chunk(1, start=1.0))
    assert ring.dropped == 1 and len(ring) == cap
    t, _ = ring.arrays()
    assert t[0] == pytest.approx(0.02)               # oldest rolled off


@pytest.mark.parametrize("burst", [16, 17, 30, 31, 32, 100])
def test_overflow_burst_larger_than_capacity(burst):
    cap = 16
    chunks = [_chunk(5), _chunk(burst, start=10.0)]
    ring = SampleRing(cap)
    for ch in chunks:
        ring.extend(*ch)
    _assert_same(ring, _reference(cap, chunks))
    assert ring.total == 5 + burst
    assert ring.dropped == 5 + burst - cap
    # only the burst's tail is visible, oldest first
    t, _ = ring.arrays()
    np.testing.assert_array_equal(t, chunks[1][0][-cap:])


def test_burst_from_nonzero_head_position():
    cap = 8
    for pre in range(1, cap + 1):                    # every head offset
        chunks = [_chunk(pre), _chunk(3 * cap + 1, start=50.0)]
        ring = SampleRing(cap)
        for ch in chunks:
            ring.extend(*ch)
        _assert_same(ring, _reference(cap, chunks))


def test_randomized_interleavings_match_per_sample_reference():
    rng = np.random.default_rng(7)
    for trial in range(20):
        cap = int(rng.integers(2, 40))
        ring = SampleRing(cap)
        chunks, t0 = [], 0.0
        for _ in range(int(rng.integers(1, 12))):
            n = int(rng.integers(0, 3 * cap))
            ch = _chunk(n, start=t0)
            t0 += 0.01 * (n + 1)
            chunks.append(ch)
            ring.extend(*ch)
        _assert_same(ring, _reference(cap, chunks))


def test_extend_defaults_util_temp_to_nan():
    ring = SampleRing(8)
    ring.extend([1.0, 2.0], [100.0, 101.0])
    tr = ring.to_trace()
    assert np.isnan(tr.util).all() and np.isnan(tr.temp_c).all()
    s = ring.latest()
    assert s.t_s == 2.0 and math.isnan(s.util)


# ---------------------------------------------------------------------------
# shared-memory ring: same accounting through the shm-backed subclass
# ---------------------------------------------------------------------------
def _shared(capacity):
    pytest.importorskip("multiprocessing.shared_memory")
    return SharedSampleRing.create(capacity)


def test_shared_ring_overflow_burst_and_attach_views():
    ring = _shared(8)
    try:
        chunks = [_chunk(3), _chunk(20, start=5.0)]
        for ch in chunks:
            ring.extend(*ch)
        _assert_same(ring, _reference(8, chunks))
        assert ring.dropped == 15
        other = SharedSampleRing.attach(ring.shm.name)
        try:
            # header counters travel through the segment, not pickling
            assert other.total == 23 and other.dropped == 15
            t_mine, _ = ring.arrays()
            t_theirs, _ = other.arrays()
            np.testing.assert_array_equal(t_mine, t_theirs)
        finally:
            other.close()
    finally:
        ring.close()
        ring.unlink()


def test_shared_ring_zero_length_and_mismatch():
    ring = _shared(4)
    try:
        assert ring.extend([], []) == 0
        with pytest.raises(ValueError, match="lengths disagree"):
            ring.extend([1.0, 2.0], [100.0])
        assert ring.total == 0
    finally:
        ring.close()
        ring.unlink()
