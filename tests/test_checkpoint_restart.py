"""Fault tolerance: atomic checkpointing + bitwise restart continuation."""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.launch.train import run as train_run
from repro.train import checkpoint as ck
from repro.train import optimizer as opt_mod
from repro.train.step import init_state

pytestmark = pytest.mark.slow   # heavy model/distributed tier


def _state():
    cfg = cfgs.get_smoke_config("qwen2-0.5b")
    return init_state(cfg, opt_mod.OptConfig(), jax.random.PRNGKey(0))


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    ck.save(tmp_path, 7, state)
    restored, step = ck.restore(tmp_path, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_k(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, state, keep=2)
    assert ck.all_steps(tmp_path) == [4, 5]


def test_no_partial_checkpoints_visible(tmp_path):
    state = _state()
    ck.save(tmp_path, 3, state)
    # only fully-committed step dirs (atomic rename), no temp residue
    names = [p.name for p in pathlib.Path(tmp_path).iterdir()]
    assert names == ["step_0000000003"]


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(tmp_path, _state())


def test_restart_continues_identically(tmp_path):
    """Simulated failure at step 6; restart must replay steps 6..9 to the
    same losses as an uninterrupted run (deterministic data pipeline)."""
    kw = dict(smoke=True, seq_len=32, global_batch=2, energy_system=None,
              verbose=False)
    _, losses_full, _ = train_run("qwen2-0.5b", steps=10, **kw)

    with pytest.raises(RuntimeError, match="simulated node failure"):
        train_run("qwen2-0.5b", steps=10, ckpt_dir=tmp_path, ckpt_every=3,
                  fail_at=6, **kw)
    assert ck.latest_step(tmp_path) == 6
    _, losses_resumed, _ = train_run("qwen2-0.5b", steps=10,
                                     ckpt_dir=tmp_path, ckpt_every=3, **kw)
    np.testing.assert_allclose(losses_resumed, losses_full[6:], rtol=1e-5)
