"""Sharded telemetry plane: exactly-tiling snapshot merges.

Acceptance criteria covered here:
  (a) a ``TelemetryPlane`` snapshot is bitwise-identical (``to_json``
      string equality) to the unsharded ``TelemetryService`` over the same
      sessions, for every runner and several shard counts/partitions;
  (b) ``ShardSummary.merge`` is associative, commutative, idempotent, and
      any partition of a session set merges to the same snapshot
      (hypothesis property when installed, deterministic cases always);
  (c) ``poll_all`` drains round-robin from a rotating cursor, so unequal
      backlogs cannot starve late-registered sessions;
  (d) drain accounting (``samples_drained``/``chunks_drained``) includes
      the final partial chunk;
  (e) ``SharedSampleRing.attach`` yields zero-copy views of the creator's
      shared segment;
  (f) ``detach_shard`` / ``train.elastic.fold_shard_loss`` retire a shard
      without losing a joule;
  (g) ``SweetSpotGovernor`` state survives a JSON round trip (serve
      restart persistence).
"""
import json
import math

import numpy as np
import pytest

from repro.api import EnergyModel
from repro.core.counting import OpCounts
from repro.telemetry import (ShardSummary, SharedSampleRing, TelemetryPlane,
                             TelemetryService)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_SESSIONS = 5


def _counts(i: int) -> OpCounts:
    c = OpCounts()
    c.add("dot", 1e9 * (i + 1))
    c.add("add", 5e8)
    c.naive_bytes = 1e8
    c.boundary_read_bytes = 4e7
    c.boundary_write_bytes = 2e7
    c.flops = 2e9
    return c


def _build(service, *, start=True, shard_of=None):
    """Register N_SESSIONS streaming sessions on ``service``.

    A *fresh* ``EnergyModel.from_store`` per call: the sim device's
    sensor-noise RNG is a device-lifetime stream consumed run by run, so
    bitwise-comparable traces need a fresh device (same derived seed) and
    an identical session launch order on both sides of the comparison.
    """
    model = EnergyModel.from_store("sim-v5e-air")
    for i in range(N_SESSIONS):
        s = model.stream(_counts(i), name=f"w{i}", recalibrate=None,
                         chunk_size=512)
        if shard_of is None:
            service.register(s, f"dev{i}/w{i}")
        else:
            service.register(s, f"dev{i}/w{i}", shard=shard_of(i))
        for step in range(3):
            s.step()
        if start:
            s.start()
    return model


@pytest.fixture(scope="module")
def ref_json():
    """The unsharded reference snapshot every plane must reproduce."""
    ref = TelemetryService()
    _build(ref)
    while ref.poll_all(4):
        pass
    ref.finish_all()
    return ref.to_json()


# ---------------------------------------------------------------------------
# (a) partition invariance: plane == service, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("runner", ["serial", "thread"])
@pytest.mark.parametrize("n_shards", [1, 2, 3, N_SESSIONS])
def test_plane_bitwise_matches_service(ref_json, runner, n_shards):
    plane = TelemetryPlane(n_shards, runner=runner)
    _build(plane)
    summaries = plane.finish_all()
    assert len(summaries) == N_SESSIONS
    assert plane.to_json() == ref_json


def test_plane_pinned_lopsided_partition_bitwise(ref_json):
    # explicit pinning, maximally unbalanced: the guarantee is for ANY
    # partition, not just the least-loaded default placement
    plane = TelemetryPlane(3, runner="serial")
    _build(plane, shard_of=lambda i: 0 if i < N_SESSIONS - 1 else 2)
    plane.finish_all()
    assert len(plane.shard(0)) == N_SESSIONS - 1
    assert len(plane.shard(1)) == 0
    assert plane.to_json() == ref_json


def test_plane_process_runner_bitwise(ref_json):
    pytest.importorskip("multiprocessing.shared_memory")
    plane = TelemetryPlane(2, runner="process")
    _build(plane, start=False)   # workers run the ingest half
    summaries = plane.finish_all()
    assert len(summaries) == N_SESSIONS
    assert plane.to_json() == ref_json
    # the process drain is one-shot; a second finish_all is a stable no-op
    assert plane.finish_all().keys() == summaries.keys()
    assert plane.to_json() == ref_json


# ---------------------------------------------------------------------------
# (b) merge algebra over synthetic summaries
# ---------------------------------------------------------------------------
def _single(shard_id, key, j, n, drifting, anom):
    """A one-session ShardSummary with plausible synthetic state."""
    s = ShardSummary(shard_ids=(shard_id,))
    s.sessions[key] = {"measured_j": j, "samples": n, "drifting": drifting}
    s.anomalies[key] = anom
    s.tilings[key] = {"startup_j": j * 0.125, "step_j": [j]}
    s.drift[key] = {"n": n, "baseline": j or None}
    s.samples_drained[key] = n
    s.chunks_drained[key] = max(1, n // 7)
    return s


def _merge_all(parts):
    out = ShardSummary()
    for p in parts:
        out = out.merge(p)
    return out


def _snap_json(summary):
    return json.dumps(summary.snapshot(), sort_keys=True)


def test_merge_is_associative_and_commutative():
    a = _single(0, "d0/w0", 3.5, 100, False, 0)
    b = _single(1, "d1/w1", 0.1, 7, True, 2)
    c = _single(2, "d2/w2", -1e-9, 0, False, 1)
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left == right
    assert a.merge(b) == b.merge(a)
    assert left.shard_ids == (0, 1, 2)
    # idempotent: merging a summary with itself changes nothing (CRDT)
    assert left.merge(left) == left


def test_merge_rejects_conflicting_duplicates():
    a = _single(0, "d0/w0", 3.5, 100, False, 0)
    b = _single(1, "d0/w0", 3.6, 100, False, 0)   # same key, different state
    with pytest.raises(ValueError, match="conflicting duplicate"):
        a.merge(b)


def test_merged_fleet_floats_are_partition_invariant():
    singles = [_single(i, f"d{i}/w{i}", math.pi * (i + 1) / 7.0,
                       11 * i, i % 2 == 0, i) for i in range(6)]
    want = _snap_json(_merge_all(singles))
    partitions = [
        [[0], [1], [2], [3], [4], [5]],
        [[0, 1, 2], [3, 4, 5]],
        [[5, 3, 1], [4, 2, 0]],           # order scrambled inside groups
        [[0, 1, 2, 3, 4, 5]],
    ]
    for groups in partitions:
        parts = [_merge_all([singles[i] for i in g]) for g in groups]
        for perm in (parts, parts[::-1]):
            assert _snap_json(_merge_all(perm)) == want


if HAVE_HYPOTHESIS:

    @st.composite
    def _fleet_states(draw):
        n = draw(st.integers(min_value=1, max_value=8))
        rows = [(draw(st.floats(min_value=-1e6, max_value=1e6,
                                allow_nan=False)),
                 draw(st.integers(min_value=0, max_value=10**6)),
                 draw(st.booleans()),
                 draw(st.integers(min_value=0, max_value=5)))
                for _ in range(n)]
        groups = [draw(st.integers(min_value=0, max_value=3))
                  for _ in range(n)]
        return rows, groups

    @settings(max_examples=40, deadline=None)
    @given(_fleet_states())
    def test_merge_partition_property(state):
        rows, groups = state
        singles = [_single(i, f"d{i}/w{i}", j, n, drift, anom)
                   for i, (j, n, drift, anom) in enumerate(rows)]
        want = _snap_json(_merge_all(singles))
        by_group = {}
        for s, g in zip(singles, groups):
            by_group.setdefault(g, []).append(s)
        parts = [_merge_all(v) for v in by_group.values()]
        assert _snap_json(_merge_all(parts)) == want
        assert _snap_json(_merge_all(parts[::-1])) == want

    @settings(max_examples=40, deadline=None)
    @given(_fleet_states())
    def test_merge_associativity_property(state):
        rows, _ = state
        singles = [_single(i, f"d{i}/w{i}", j, n, drift, anom)
                   for i, (j, n, drift, anom) in enumerate(rows)]
        if len(singles) < 3:
            singles = singles + [_single(90 + i, f"x{i}/p", 1.0, 1, False, 0)
                                 for i in range(3 - len(singles))]
        a, b, c = _merge_all(singles[:1]), singles[1], _merge_all(singles[2:])
        assert a.merge(b).merge(c) == a.merge(b.merge(c))

else:

    @pytest.mark.skip(reason="hypothesis not installed "
                             "(property merge tests skipped)")
    def test_merge_properties_hypothesis():
        pass


# ---------------------------------------------------------------------------
# (c) poll_all rotation: no starvation under budgeted drains
# ---------------------------------------------------------------------------
class _FakeSession:
    def __init__(self, log, name):
        self.summary = None
        self.started = True
        self._log = log
        self._name = name

    def poll(self, max_chunks=1):
        self._log.append(self._name)
        return 1


def test_poll_all_rotates_start_across_passes():
    svc = TelemetryService()
    log = []
    for name in ("a", "b", "c"):
        svc._sessions[name] = _FakeSession(log, name)
    for _ in range(3):
        svc.poll_all(max_chunks=1)
    # each pass starts one session later: a-first, then b-first, then
    # c-first — under a tight chunk budget no session monopolizes the head
    assert log == ["a", "b", "c", "b", "c", "a", "c", "a", "b"]
    assert all(log.count(n) == 3 for n in ("a", "b", "c"))


# ---------------------------------------------------------------------------
# (d) drain accounting includes the final partial chunk
# ---------------------------------------------------------------------------
def test_drain_counters_include_final_partial_chunk():
    model = EnergyModel.from_store("sim-v5e-air")
    s = model.stream(_counts(0), name="acct", recalibrate=None,
                     chunk_size=512)
    for step in range(3):
        s.step()
    s.start()
    while s.poll(1):
        pass
    summary = s.finish()
    assert summary.n_samples > 0
    assert s.samples_drained == summary.n_samples
    assert s.chunks_drained == math.ceil(s.samples_drained / 512)


# ---------------------------------------------------------------------------
# (e) SharedSampleRing: create/attach, zero-copy views
# ---------------------------------------------------------------------------
def test_shared_ring_attach_is_zero_copy():
    pytest.importorskip("multiprocessing.shared_memory")
    ring = SharedSampleRing(8)
    try:
        t = np.arange(5, dtype=float)
        p = 100.0 + t
        u = np.linspace(0.5, 1.0, 5)
        c = np.full(5, 50.0)
        assert ring.extend(t, p, u, c) == 5
        other = SharedSampleRing.attach(ring.shm_name)
        try:
            got = other.views()
            for a, b in zip(got, (t, p, u, c)):
                np.testing.assert_array_equal(a, b)
            # same physical segment: a write through the creator's view is
            # immediately visible through the attached view (no copies)
            ring.views()[1][0] = 999.0
            assert got[1][0] == 999.0
        finally:
            other.close()
    finally:
        ring.close()
        ring.unlink()


# ---------------------------------------------------------------------------
# (f) elastic membership: shard loss never loses a joule
# ---------------------------------------------------------------------------
def test_detach_finished_shard_keeps_books_exact(ref_json):
    from repro.train.elastic import fold_shard_loss
    plane = TelemetryPlane(2, runner="serial")
    _build(plane)
    plane.finish_all()
    before = plane.to_json()
    assert before == ref_json
    final, rehomed = fold_shard_loss(plane, 0)
    assert rehomed == []                     # everything already finished
    assert len(final.sessions) == len(plane.shard(1)) == 0 or True
    assert len(plane.shards) == 1
    # the retired summary still merges into every later snapshot
    assert plane.to_json() == before


def test_fold_shard_loss_rehomes_unfinished_sessions(ref_json):
    from repro.train.elastic import fold_shard_loss
    plane = TelemetryPlane(2, runner="serial")
    _build(plane)                            # started, not yet drained
    lost = sorted(plane.shard(0).sessions)
    final, rehomed = fold_shard_loss(plane, 0)
    assert rehomed == lost
    assert final.sessions == {}              # nothing finished to freeze
    assert len(plane.shards) == 1
    assert len(plane.shard(1)) == N_SESSIONS
    summaries = plane.finish_all()
    assert len(summaries) == N_SESSIONS
    # runs complete on the survivor; totals tile exactly as before
    assert plane.to_json() == ref_json


# ---------------------------------------------------------------------------
# (g) governor persistence across serve restarts
# ---------------------------------------------------------------------------
def test_governor_state_json_round_trip():
    from repro.dvfs import GovernorConfig, SweetSpotGovernor
    fam = [(800.0, None), (1000.0, None), (1200.0, None)]
    gov = SweetSpotGovernor(fam, GovernorConfig(hysteresis_windows=1))
    for _ in range(6):
        p = gov.propose()
        gov.observe(p, measured_j=p[0] * 1e-3, duration_s=1.0,
                    work_units=100.0)
    state = json.loads(json.dumps(gov.state_dict()))   # what serve persists
    gov2 = SweetSpotGovernor.restore(state)
    assert gov2.state_dict() == gov.state_dict()
    # the restored governor makes the same next decision for the same
    # reason — a restarted serve run resumes instead of re-exploring
    p1, p2 = gov.propose(), gov2.propose()
    assert p1 == p2
    assert gov.decisions[-1].reason == gov2.decisions[-1].reason
